"""Mini-ResNet for the synthetic CIFAR/SVHN substitutes.

A pre-activation residual CNN scaled to CPU-PJRT budgets (DESIGN.md §3):
stem conv -> 3 stages (widths 16/32/64, one residual block each, stride-2
1x1-conv downsample between stages) -> global average pool -> dense head.

Normalization is channel LayerNorm (per spatial position) rather than
BatchNorm: it removes train/eval mode state from the artifacts while keeping
residual training stable — the selection methods only consume the per-sample
loss distribution, which this preserves.

Convolutions use lax.conv_general_dilated (L2/XLA ops); the dense head goes
through the Pallas matmul kernel so the classification artifacts contain the
L1 kernels (head matmul + persample_xent) in their HLO.
"""

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import matmul


def _conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _layernorm(x, scale, bias):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * lax.rsqrt(var + 1e-5) * scale + bias


class ResNetSpec:
    """3-stage pre-activation mini-ResNet over ``size x size x 3`` inputs."""

    kind = "resnet"

    def __init__(self, name, num_classes, size=16, widths=(16, 32, 64)):
        self.name = name
        self.num_classes = num_classes
        self.size = size
        self.widths = tuple(widths)
        self.in_dim = (size, size, 3)

    def param_specs(self):
        specs = [("stem_w", (3, 3, 3, self.widths[0]))]
        c_in = self.widths[0]
        for s, c in enumerate(self.widths):
            if c != c_in:
                specs.append((f"s{s}_down_w", (1, 1, c_in, c)))
            specs.append((f"s{s}_ln1_g", (c,)))
            specs.append((f"s{s}_ln1_b", (c,)))
            specs.append((f"s{s}_conv1_w", (3, 3, c, c)))
            specs.append((f"s{s}_ln2_g", (c,)))
            specs.append((f"s{s}_ln2_b", (c,)))
            specs.append((f"s{s}_conv2_w", (3, 3, c, c)))
            c_in = c
        specs.append(("head_w", (self.widths[-1], self.num_classes)))
        specs.append(("head_b", (self.num_classes,)))
        return specs

    def init(self, key):
        params = []
        for name, shape in self.param_specs():
            key, sub = jax.random.split(key)
            if name.endswith("_g"):
                params.append(jnp.ones(shape, jnp.float32))
            elif name.endswith("_b") and len(shape) == 1:
                params.append(jnp.zeros(shape, jnp.float32))
            elif "conv2" in name:
                # zero-init the block's closing conv: the network starts as
                # (near-)identity residual stack, which keeps early training
                # stable at SGD+momentum learning rates (standard trick).
                params.append(jnp.zeros(shape, jnp.float32))
            elif name == "head_w":
                params.append(jax.random.normal(sub, shape, jnp.float32) * 0.01)
            else:
                fan_in = 1
                for d in shape[:-1]:
                    fan_in *= d
                params.append(
                    jax.random.normal(sub, shape, jnp.float32)
                    * jnp.sqrt(2.0 / fan_in)
                )
        return params

    def apply(self, params, x):
        """x: f32[B, S, S, 3] -> (logits f32[B, C], fnorm f32[B])."""
        named = dict(zip([n for n, _ in self.param_specs()], params))
        h = _conv(x, named["stem_w"])
        c_in = self.widths[0]
        for s, c in enumerate(self.widths):
            if c != c_in:
                # stride-2 downsample into the wider stage
                h = _conv(h, named[f"s{s}_down_w"], stride=2)
                c_in = c
            z = jax.nn.relu(
                _layernorm(h, named[f"s{s}_ln1_g"], named[f"s{s}_ln1_b"])
            )
            z = _conv(z, named[f"s{s}_conv1_w"])
            z = jax.nn.relu(
                _layernorm(z, named[f"s{s}_ln2_g"], named[f"s{s}_ln2_b"])
            )
            z = _conv(z, named[f"s{s}_conv2_w"])
            h = h + z
        feat = jnp.mean(h, axis=(1, 2))  # global average pool -> (B, C_last)
        fnorm = jnp.sqrt(jnp.sum(feat * feat, axis=-1) + 1e-9)
        logits = matmul(feat, named["head_w"]) + named["head_b"]
        return logits, fnorm

"""L2 model definitions (build-time JAX; lowered to HLO by aot.py)."""

from .mlp import MlpSpec
from .resnet import ResNetSpec
from .transformer import TransformerSpec

__all__ = ["MlpSpec", "ResNetSpec", "TransformerSpec"]

"""Decoder-only Transformer LM for the WikiText-2 substitute (DESIGN.md §3).

Small enough for CPU-PJRT (vocab 256, d_model 64, 2 layers, 2 heads,
seq 32 ≈ 120k params) but structurally faithful: token+position embeddings,
pre-LN causal self-attention, GELU MLP blocks, final LN, untied output
projection. The output projection runs through the Pallas matmul kernel so
the LM artifacts carry the L1 kernels in their HLO (together with
persample_lm_xent).
"""

import jax
import jax.numpy as jnp

from ..kernels import matmul


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


class TransformerSpec:
    kind = "transformer"

    def __init__(
        self, name, vocab=256, seq_len=32, d_model=64, n_layers=2, n_heads=2, d_ff=128
    ):
        self.name = name
        self.vocab = vocab
        self.seq_len = seq_len
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff
        assert d_model % n_heads == 0

    def param_specs(self):
        d, f, v, t = self.d_model, self.d_ff, self.vocab, self.seq_len
        specs = [("tok_emb", (v, d)), ("pos_emb", (t, d))]
        for i in range(self.n_layers):
            specs += [
                (f"l{i}_ln1_g", (d,)),
                (f"l{i}_ln1_b", (d,)),
                (f"l{i}_wq", (d, d)),
                (f"l{i}_wk", (d, d)),
                (f"l{i}_wv", (d, d)),
                (f"l{i}_wo", (d, d)),
                (f"l{i}_ln2_g", (d,)),
                (f"l{i}_ln2_b", (d,)),
                (f"l{i}_mlp_w1", (d, f)),
                (f"l{i}_mlp_b1", (f,)),
                (f"l{i}_mlp_w2", (f, d)),
                (f"l{i}_mlp_b2", (d,)),
            ]
        specs += [("lnf_g", (d,)), ("lnf_b", (d,)), ("out_w", (d, v)), ("out_b", (v,))]
        return specs

    def init(self, key):
        params = []
        for name, shape in self.param_specs():
            key, sub = jax.random.split(key)
            if name.endswith("_g"):
                params.append(jnp.ones(shape, jnp.float32))
            elif name.endswith("_b") and len(shape) == 1:
                params.append(jnp.zeros(shape, jnp.float32))
            elif "emb" in name:
                params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
            else:
                fan_in = shape[0]
                params.append(
                    jax.random.normal(sub, shape, jnp.float32)
                    * jnp.sqrt(1.0 / fan_in)
                )
        return params

    def _attn(self, named, i, h):
        b, t, d = h.shape
        nh = self.n_heads
        hd = d // nh

        def proj(name):
            w = named[f"l{i}_{name}"]
            return (h.reshape(b * t, d) @ w).reshape(b, t, nh, hd).transpose(
                0, 2, 1, 3
            )

        q, k, v = proj("wq"), proj("wk"), proj("wv")
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        mask = jnp.tril(jnp.ones((t, t), bool))
        att = jnp.where(mask, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        out = out.transpose(0, 2, 1, 3).reshape(b * t, d)
        return (out @ named[f"l{i}_wo"]).reshape(b, t, d)

    def apply(self, params, x):
        """x: i32[B, T] tokens -> (logits f32[B, T, V], fnorm f32[B, T])."""
        named = dict(zip([n for n, _ in self.param_specs()], params))
        b, t = x.shape
        h = named["tok_emb"][x] + named["pos_emb"][None, :t, :]
        for i in range(self.n_layers):
            z = _layernorm(h, named[f"l{i}_ln1_g"], named[f"l{i}_ln1_b"])
            h = h + self._attn(named, i, z)
            z = _layernorm(h, named[f"l{i}_ln2_g"], named[f"l{i}_ln2_b"])
            z2 = z.reshape(b * t, -1)
            z2 = jax.nn.gelu(z2 @ named[f"l{i}_mlp_w1"] + named[f"l{i}_mlp_b1"])
            z2 = z2 @ named[f"l{i}_mlp_w2"] + named[f"l{i}_mlp_b2"]
            h = h + z2.reshape(b, t, -1)
        h = _layernorm(h, named["lnf_g"], named["lnf_b"])
        fnorm = jnp.sqrt(jnp.sum(h * h, axis=-1) + 1e-9)
        logits = matmul(h.reshape(b * t, -1), named["out_w"]) + named["out_b"]
        return logits.reshape(b, t, self.vocab), fnorm

"""MLP regression models (the paper's simple-regression and bike tasks).

Dense layers go through the L1 Pallas matmul kernel (kernels.matmul), so the
lowered HLO of both the forward and the train-step artifacts is
Pallas-backed end to end (the custom VJP keeps the backward in Pallas too).
"""

import jax
import jax.numpy as jnp

from ..kernels import matmul


class MlpSpec:
    """A plain MLP ``in_dim -> hidden... -> 1`` with ReLU activations.

    apply() returns per-sample scalar predictions plus ``fnorm`` — the L2
    norm of the last hidden layer, feeding the gradient-norm proxy.
    """

    kind = "mlp"

    def __init__(self, name, in_dim, hidden, out_dim=1):
        self.name = name
        self.in_dim = in_dim
        self.hidden = list(hidden)
        self.out_dim = out_dim

    def param_specs(self):
        dims = [self.in_dim] + self.hidden + [self.out_dim]
        specs = []
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            specs.append((f"w{i}", (a, b)))
            specs.append((f"b{i}", (b,)))
        return specs

    def init(self, key):
        params = []
        for name, shape in self.param_specs():
            key, sub = jax.random.split(key)
            if name.startswith("w"):
                fan_in = shape[0]
                params.append(
                    jax.random.normal(sub, shape, jnp.float32)
                    * jnp.sqrt(2.0 / fan_in)
                )
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        return params

    def apply(self, params, x):
        """x: f32[B, in_dim] -> (pred f32[B], fnorm f32[B])."""
        h = x
        n_layers = len(self.hidden) + 1
        for i in range(n_layers - 1):
            w, b = params[2 * i], params[2 * i + 1]
            h = jax.nn.relu(matmul(h, w) + b)
        fnorm = jnp.sqrt(jnp.sum(h * h, axis=-1) + 1e-9)
        w, b = params[-2], params[-1]
        pred = (matmul(h, w) + b)[:, 0]
        return pred, fnorm

"""L2 graph builders: every artifact the rust coordinator executes.

For each model *family* (a model spec + batch geometry) we emit:

  init_{F}            (seed)                          -> (*params, *mom)
  fwd_{F}_b{B}        (*params, x[B], y[B])           -> (loss[B], gnorm[B])
  train_{F}_n{K}      (*params, *mom, x[K], y[K], lr) -> (*params', *mom', mean_loss)
  eval_{F}_b{B}       (*params, x[B], y[B], mask[B])  -> (loss_sum, correct_sum)

plus one shared scoring artifact per batch size:

  score_b{B}          (loss[B], gnorm[B], w[M], knobs[3]) -> (s[B], alpha[M,B])

The train-step subset sizes K are ceil(γ·B) for the paper's sampling-rate
grid γ ∈ {0.1..0.5} plus K = B (the no-sampling benchmark). All functions
take FLAT positional arguments so the lowered HLO has a stable positional
parameter layout that `artifacts/manifest.json` describes to rust.
"""

import math

import jax
import jax.numpy as jnp

from .kernels import (
    persample_xent,
    persample_sqerr,
    persample_lm_xent,
)
from .models import MlpSpec, ResNetSpec, TransformerSpec

MOMENTUM = 0.9
GRAD_CLIP = 5.0  # global-norm clip in the train-step artifact
GAMMA_GRID = (0.1, 0.2, 0.3, 0.4, 0.5)


class Family:
    """A model spec bound to a task type and batch geometry."""

    def __init__(self, spec, task, batch):
        self.spec = spec
        self.task = task  # "regression" | "classification" | "lm"
        self.batch = batch

    @property
    def name(self):
        return self.spec.name

    def train_sizes(self):
        ks = sorted({int(math.ceil(g * self.batch)) for g in GAMMA_GRID})
        ks.append(self.batch)
        return ks

    # ---- data shapes -----------------------------------------------------
    def x_sds(self, n):
        if self.task == "regression":
            return jax.ShapeDtypeStruct((n, self.spec.in_dim), jnp.float32)
        if self.task == "classification":
            return jax.ShapeDtypeStruct((n,) + self.spec.in_dim, jnp.float32)
        return jax.ShapeDtypeStruct((n, self.spec.seq_len), jnp.int32)

    def y_sds(self, n):
        if self.task == "regression":
            return jax.ShapeDtypeStruct((n,), jnp.float32)
        if self.task == "classification":
            return jax.ShapeDtypeStruct((n,), jnp.int32)
        return jax.ShapeDtypeStruct((n, self.spec.seq_len), jnp.int32)

    def param_sds(self):
        return [
            jax.ShapeDtypeStruct(shape, jnp.float32)
            for _, shape in self.spec.param_specs()
        ]

    # ---- per-sample loss through the L1 kernels ---------------------------
    def persample_loss(self, params, x, y):
        out, fnorm = self.spec.apply(params, x)
        if self.task == "regression":
            return persample_sqerr(out, y, fnorm)
        if self.task == "classification":
            return persample_xent(out, y, fnorm)
        return persample_lm_xent(out, y, fnorm)

    # ---- artifact functions (flat positional signatures) ------------------
    def n_params(self):
        return len(self.spec.param_specs())

    def fwd_fn(self):
        np_ = self.n_params()

        def f(*args):
            params, x, y = list(args[:np_]), args[np_], args[np_ + 1]
            loss, gnorm = self.persample_loss(params, x, y)
            return (loss, gnorm)

        return f

    def fwd_score_fn(self):
        """Fused selection pass: forward + AdaSelection scorer in ONE HLO
        module (perf: halves the host→device roundtrips per iteration vs
        separate fwd and score calls; the scorer fuses into the same
        program so XLA can overlap it with the loss epilogue)."""
        from .kernels import adaselection_score

        np_ = self.n_params()

        def f(*args):
            params = list(args[:np_])
            x, y, w, knobs = args[np_], args[np_ + 1], args[np_ + 2], args[np_ + 3]
            loss, gnorm = self.persample_loss(params, x, y)
            s, alpha = adaselection_score(loss, gnorm, w, knobs)
            return (loss, gnorm, s, alpha)

        return f

    def train_fn(self):
        np_ = self.n_params()

        def f(*args):
            params = list(args[:np_])
            mom = list(args[np_ : 2 * np_])
            x, y, lr = args[2 * np_], args[2 * np_ + 1], args[2 * np_ + 2]

            def batch_loss(ps):
                loss, _ = self.persample_loss(ps, x, y)
                return jnp.mean(loss)

            loss, grads = jax.value_and_grad(batch_loss)(params)
            # global-norm gradient clipping: subsampling policies that chase
            # high-loss outliers (Big Loss on corrupted labels) otherwise
            # diverge at practical momentum-SGD learning rates
            gnorm = jnp.sqrt(
                sum(jnp.sum(g * g) for g in grads) + 1e-12
            )
            scale = jnp.minimum(1.0, GRAD_CLIP / gnorm)
            grads = [g * scale for g in grads]
            new_mom = [MOMENTUM * m + g for m, g in zip(mom, grads)]
            new_params = [p - lr * m for p, m in zip(params, new_mom)]
            return tuple(new_params) + tuple(new_mom) + (loss,)

        return f

    def eval_fn(self):
        np_ = self.n_params()

        def f(*args):
            params = list(args[:np_])
            x, y, mask = args[np_], args[np_ + 1], args[np_ + 2]
            loss, _ = self.persample_loss(params, x, y)
            loss_sum = jnp.sum(loss * mask)
            if self.task == "classification":
                out, _ = self.spec.apply(params, x)
                correct = jnp.sum(
                    (jnp.argmax(out, axis=-1) == y).astype(jnp.float32) * mask
                )
            elif self.task == "lm":
                out, _ = self.spec.apply(params, x)
                tok_acc = jnp.mean(
                    (jnp.argmax(out, axis=-1) == y).astype(jnp.float32), axis=-1
                )
                correct = jnp.sum(tok_acc * mask)
            else:
                correct = jnp.array(0.0, jnp.float32)
            return (loss_sum, correct)

        return f

    def init_fn(self):
        def f(seed):
            key = jax.random.PRNGKey(seed)
            params = self.spec.init(key)
            mom = [jnp.zeros_like(p) for p in params]
            return tuple(params) + tuple(mom)

        return f


def make_families():
    """The five model families of Table 2 (post-substitution, DESIGN.md §3)."""
    return {
        "mlp_simple": Family(MlpSpec("mlp_simple", 1, [32]), "regression", 100),
        "mlp_bike": Family(MlpSpec("mlp_bike", 8, [64, 64]), "regression", 100),
        "resnet_c10": Family(ResNetSpec("resnet_c10", 10), "classification", 128),
        "resnet_c100": Family(ResNetSpec("resnet_c100", 100), "classification", 128),
        "transformer": Family(TransformerSpec("transformer"), "lm", 64),
    }

"""AOT compiler: lower every artifact to HLO *text* + write the manifest.

Run as ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

The build is incremental: a sha256 over ``python/compile/**/*.py`` is stored
in ``artifacts/.srchash`` and the whole step is skipped when unchanged, so
python never runs on the request path and ``make artifacts`` is a no-op on a
built tree.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import METHOD_ORDER, NUM_METHODS, adaselection_score
from .kernels.matmul import vmem_report
from .model import GAMMA_GRID, MOMENTUM, make_families


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dt(sds) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[sds.dtype]


def _io_entry(name, sds):
    return {"name": name, "shape": list(sds.shape), "dtype": _dt(sds)}


def _src_hash() -> str:
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                p = os.path.join(dirpath, fn)
                h.update(p.encode())
                h.update(open(p, "rb").read())
    return h.hexdigest()


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.artifacts = {}

    def emit(self, name, fn, in_specs):
        """Lower fn(*in_specs) and write ``{name}.hlo.txt`` + manifest entry."""
        t0 = time.time()
        sds = [s for _, s in in_specs]
        lowered = jax.jit(fn).lower(*sds)
        text = to_hlo_text(lowered)
        out_sds = jax.eval_shape(fn, *sds)
        if not isinstance(out_sds, tuple):
            out_sds = (out_sds,)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        self.artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_io_entry(n, s) for n, s in in_specs],
            "outputs": [_io_entry(f"o{i}", s) for i, s in enumerate(out_sds)],
        }
        print(
            f"  [{time.time() - t0:6.1f}s] {name:28s} "
            f"{len(text) / 1e6:6.2f} MB  "
            f"in={len(in_specs)} out={len(out_sds)}"
        )


def build(out_dir, families=None, force=False):
    os.makedirs(out_dir, exist_ok=True)
    hash_path = os.path.join(out_dir, ".srchash")
    manifest_path = os.path.join(out_dir, "manifest.json")
    src = _src_hash()
    if (
        not force
        and os.path.exists(hash_path)
        and os.path.exists(manifest_path)
        and open(hash_path).read().strip() == src
    ):
        print("artifacts up to date (source hash match); skipping")
        return

    fams = make_families()
    if families:
        fams = {k: v for k, v in fams.items() if k in families}
    b = Builder(out_dir)
    manifest = {
        "version": 1,
        "method_order": list(METHOD_ORDER),
        "momentum": MOMENTUM,
        "gamma_grid": list(GAMMA_GRID),
        "families": {},
        "score": {},
        "artifacts": b.artifacts,
    }

    # --- shared scoring artifacts, one per batch size ----------------------
    batches = sorted({f.batch for f in fams.values()})
    for bs in batches:
        name = f"score_b{bs}"
        f32 = jnp.float32
        b.emit(
            name,
            adaselection_score,
            [
                ("loss", jax.ShapeDtypeStruct((bs,), f32)),
                ("gnorm", jax.ShapeDtypeStruct((bs,), f32)),
                ("w", jax.ShapeDtypeStruct((NUM_METHODS,), f32)),
                ("knobs", jax.ShapeDtypeStruct((3,), f32)),
            ],
        )
        manifest["score"][str(bs)] = name

    # --- per-family artifacts ----------------------------------------------
    for fname, fam in fams.items():
        print(f"family {fname} (task={fam.task}, B={fam.batch})")
        p_specs = fam.spec.param_specs()
        p_sds = fam.param_sds()
        p_in = [(n, s) for (n, _), s in zip(p_specs, p_sds)]
        m_in = [(f"mom_{n}", s) for (n, _), s in zip(p_specs, p_sds)]
        bsz = fam.batch

        entry = {
            "task": fam.task,
            "batch": bsz,
            "train_sizes": fam.train_sizes(),
            "params": [
                {"name": n, "shape": list(shape)} for n, shape in p_specs
            ],
            "artifacts": {"train": {}},
        }
        if fam.task == "classification":
            entry["input_shape"] = list(fam.spec.in_dim)
            entry["num_classes"] = fam.spec.num_classes
        elif fam.task == "regression":
            entry["input_shape"] = [fam.spec.in_dim]
        else:
            entry["seq_len"] = fam.spec.seq_len
            entry["vocab"] = fam.spec.vocab

        name = f"init_{fname}"
        b.emit(
            name,
            fam.init_fn(),
            [("seed", jax.ShapeDtypeStruct((), jnp.int32))],
        )
        entry["artifacts"]["init"] = name

        name = f"fwd_{fname}_b{bsz}"
        b.emit(
            name,
            fam.fwd_fn(),
            p_in + [("x", fam.x_sds(bsz)), ("y", fam.y_sds(bsz))],
        )
        entry["artifacts"]["fwd"] = name

        name = f"fwdscore_{fname}_b{bsz}"
        b.emit(
            name,
            fam.fwd_score_fn(),
            p_in
            + [
                ("x", fam.x_sds(bsz)),
                ("y", fam.y_sds(bsz)),
                ("w", jax.ShapeDtypeStruct((NUM_METHODS,), jnp.float32)),
                ("knobs", jax.ShapeDtypeStruct((3,), jnp.float32)),
            ],
        )
        entry["artifacts"]["fwd_score"] = name

        name = f"eval_{fname}_b{bsz}"
        b.emit(
            name,
            fam.eval_fn(),
            p_in
            + [
                ("x", fam.x_sds(bsz)),
                ("y", fam.y_sds(bsz)),
                ("mask", jax.ShapeDtypeStruct((bsz,), jnp.float32)),
            ],
        )
        entry["artifacts"]["eval"] = name

        for k in fam.train_sizes():
            name = f"train_{fname}_n{k}"
            b.emit(
                name,
                fam.train_fn(),
                p_in
                + m_in
                + [
                    ("x", fam.x_sds(k)),
                    ("y", fam.y_sds(k)),
                    ("lr", jax.ShapeDtypeStruct((), jnp.float32)),
                ],
            )
            entry["artifacts"]["train"][str(k)] = name

        manifest["families"][fname] = entry

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    with open(hash_path, "w") as f:
        f.write(src)
    print(f"wrote {manifest_path} ({len(b.artifacts)} artifacts)")


def report():
    """Static VMEM/MXU estimates for the kernel BlockSpecs (DESIGN.md §9)."""
    shapes = [
        ("mlp hidden (100x8 @ 8x64)", 100, 8, 64),
        ("resnet head (128x64 @ 64x100)", 128, 64, 100),
        ("lm out-proj (2048x64 @ 64x256)", 2048, 64, 256),
    ]
    for label, m, k, n in shapes:
        print(label, vmem_report(m, k, n))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--family", action="append", help="limit to families")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--report", action="store_true")
    args = ap.parse_args()
    if args.report:
        report()
        return
    build(args.out_dir, families=args.family, force=args.force)


if __name__ == "__main__":
    main()

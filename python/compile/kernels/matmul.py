"""L1 Pallas tiled matmul kernel.

Authored for TPU geometry (MXU-shaped 128x128 blocks, VMEM-resident tiles,
K-innermost accumulation grid) but executed through ``interpret=True`` on the
CPU PJRT backend — real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot run (see DESIGN.md §Hardware-Adaptation).

The public entry point is :func:`matmul`, a ``jax.custom_vjp`` function whose
backward pass is expressed with the *same* Pallas kernel (dx = g @ W^T,
dW = x^T @ g), so train-step artifacts stay Pallas-backed end to end.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile. f32[128,128] x 3 tiles = 192 KiB VMEM per grid
# step — comfortably inside the ~16 MiB per-core budget (DESIGN.md §9).
_BLOCK = 128


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _mm_kernel(x_ref, w_ref, o_ref):
    """One (bm, bk) x (bk, bn) tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _block_sizes(m: int, k: int, n: int):
    """Full-array blocks for small operands, 128-tiles once dims exceed it."""
    bm = m if m < _BLOCK else _BLOCK
    bk = k if k < _BLOCK else _BLOCK
    bn = n if n < _BLOCK else _BLOCK
    return bm, bk, bn


@functools.partial(jax.jit, static_argnames=("interpret",))
def _matmul_raw(x, w, interpret=True):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bk, bn = _block_sizes(m, k, n)
    # Zero-pad to block multiples: interpret-mode pallas does not zero-fill
    # edge blocks, and zero padding is exact for matmul.
    mp, kp, np_ = _cdiv(m, bm) * bm, _cdiv(k, bk) * bk, _cdiv(n, bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    grid = (_cdiv(m, bm), _cdiv(n, bn), _cdiv(k, bk))
    out = _mm_call(x, w, bm, bk, bn, grid, interpret)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def _mm_call(x, w, bm, bk, bn, grid, interpret):
    m, n = x.shape[0], w.shape[1]
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w)


@jax.custom_vjp
def matmul(x, w):
    """``x @ w`` via the Pallas tile kernel; differentiable (custom VJP)."""
    return _matmul_raw(x, w)


def _matmul_fwd(x, w):
    return _matmul_raw(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_raw(g, w.T)
    dw = _matmul_raw(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_report(m: int, k: int, n: int) -> dict:
    """Static VMEM/MXU estimate for the chosen BlockSpec (DESIGN.md §9)."""
    bm, bk, bn = _block_sizes(m, k, n)
    tile_bytes = 4 * (bm * bk + bk * bn + bm * bn)
    # MXU utilization proxy: fraction of the 128x128 systolic array covered
    # by the inner tile (bf16 would double throughput; we author f32).
    mxu = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
    return {
        "block": (bm, bk, bn),
        "grid": (_cdiv(m, bm), _cdiv(n, bn), _cdiv(k, bk)),
        "vmem_bytes_per_step": tile_bytes,
        "mxu_coverage": mxu,
    }

"""L1 fused AdaSelection scoring kernel (the paper's eq. 2/4/5 hot path).

Given the per-sample losses and gradient-norm proxies from the forward pass
plus the current method weights ``w_t^m``, compute in ONE VMEM pass:

  * α_{i,t}^m  for all M = 7 candidate methods (eq. 2; DESIGN.md §5.1), and
  * s_{i,t} = r_t(x_i) · Σ_m w_t^m α_{i,t}^m   (eq. 5, with the optional
    curriculum-learning reward r_t of eq. 4).

Method order is FIXED and shared with the rust coordinator through
``artifacts/manifest.json``:

  0 uniform · 1 big_loss · 2 small_loss · 3 grad_norm · 4 adaboost
  · 5 coreset1 · 6 coreset2

Each ordering statistic is standardized ((v − mean)/(std + ε)) before the
softmax so α is scale-free in the loss units; see DESIGN.md §5 for the
ambiguity log. The kernel is one block (B ≤ 128 lanes, M = 7 sublanes ⇒
~4 KiB VMEM), interpret=True on CPU PJRT.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

METHOD_ORDER = (
    "uniform",
    "big_loss",
    "small_loss",
    "grad_norm",
    "adaboost",
    "coreset1",
    "coreset2",
)
NUM_METHODS = len(METHOD_ORDER)

_EPS = 1e-6


def _standardize(v):
    mu = jnp.mean(v)
    sd = jnp.sqrt(jnp.mean((v - mu) ** 2) + 1e-12)
    return (v - mu) / (sd + _EPS)


def _softmax(v):
    v = v - jnp.max(v)
    e = jnp.exp(v)
    return e / jnp.sum(e)


def _score_kernel(loss_ref, gnorm_ref, w_ref, knobs_ref, s_ref, alpha_ref):
    l = loss_ref[...]
    g = gnorm_ref[...]
    b = l.shape[0]

    # AdaBoost statistic: map loss into (0, 1) then the half-log-odds of eq. 1.
    lhat = jnp.clip(l / (jnp.max(l) + 1e-9), 0.0, 1.0 - 1e-3)
    ada = 0.5 * jnp.log((1.0 + lhat) / (1.0 - lhat))
    dev = jnp.abs(l - jnp.mean(l))  # coreset distance-to-batch-mean

    a_uni = jnp.full((b,), 1.0 / b, l.dtype)
    a_big = _softmax(_standardize(l))
    a_small = _softmax(_standardize(-l))
    a_gn = _softmax(_standardize(g))
    a_ada = _softmax(_standardize(ada))
    a_c1 = _softmax(_standardize(dev))
    a_c2 = _softmax(_standardize(-dev))
    alpha = jnp.stack([a_uni, a_big, a_small, a_gn, a_ada, a_c1, a_c2])

    base = jnp.sum(alpha * w_ref[...][:, None], axis=0)

    # Curriculum reward (eq. 4): r ∝ exp(−t^p · l_i / Σ l²); normalized to
    # mean 1 so it re-weights rather than re-scales; knobs[2] gates it.
    t = jnp.maximum(knobs_ref[0], 1.0)
    p = knobs_ref[1]
    on = knobs_ref[2]
    r = jnp.exp(-jnp.power(t, p) * l / (jnp.sum(l * l) + 1e-9))
    r = r * (b / jnp.sum(r))
    r = on * r + (1.0 - on)

    s_ref[...] = r * base
    alpha_ref[...] = alpha


@jax.jit
def adaselection_score(loss, gnorm, w, knobs):
    """Fused scorer.

    Args:
      loss:  f32[B] per-sample losses from the forward pass.
      gnorm: f32[B] per-sample gradient-norm proxies.
      w:     f32[M] method weights w_t^m (M = 7, METHOD_ORDER).
      knobs: f32[3] = [t (iteration, ≥1), cl_power p, cl_on ∈ {0,1}].

    Returns:
      (s f32[B], alpha f32[M, B])
    """
    b = loss.shape[0]
    return pl.pallas_call(
        _score_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), loss.dtype),
            jax.ShapeDtypeStruct((NUM_METHODS, b), loss.dtype),
        ),
        interpret=True,
    )(loss, gnorm, w, knobs)

"""L1 Pallas kernels for the AdaSelection hot path (build-time only)."""

from .matmul import matmul, vmem_report
from .losses import persample_xent, persample_sqerr, persample_lm_xent
from .score import adaselection_score, METHOD_ORDER, NUM_METHODS

__all__ = [
    "matmul",
    "vmem_report",
    "persample_xent",
    "persample_sqerr",
    "persample_lm_xent",
    "adaselection_score",
    "METHOD_ORDER",
    "NUM_METHODS",
]

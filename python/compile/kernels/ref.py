"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness references: pytest asserts kernel-vs-ref
``allclose`` for values AND gradients (where the kernel is differentiable).
They are also the documentation of the exact math each kernel implements.
"""

import jax
import jax.numpy as jnp

_EPS = 1e-9


def matmul(x, w):
    return jnp.dot(x, w)


def persample_xent(logits, labels, fnorm):
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    loss = -jnp.sum(onehot * logp, axis=-1)
    p = jnp.exp(logp)
    gnorm = jnp.sqrt(jnp.sum((p - onehot) ** 2, axis=-1) + _EPS) * fnorm
    return loss, gnorm


def persample_sqerr(pred, y, fnorm):
    r = pred - y
    return 0.5 * r * r, jnp.abs(r) * fnorm


def persample_lm_xent(logits, labels, fnorm):
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    tok_loss = -jnp.sum(onehot * logp, axis=-1)
    p = jnp.exp(logp)
    tok_g = jnp.sqrt(jnp.sum((p - onehot) ** 2, axis=-1) + _EPS)
    return jnp.mean(tok_loss, axis=-1), jnp.mean(tok_g * fnorm, axis=-1)


def _standardize(v):
    mu = jnp.mean(v)
    sd = jnp.sqrt(jnp.mean((v - mu) ** 2) + 1e-12)
    return (v - mu) / (sd + 1e-6)


def _softmax(v):
    v = v - jnp.max(v)
    e = jnp.exp(v)
    return e / jnp.sum(e)


def method_alphas(loss, gnorm):
    """α_{i}^m for the 7 methods, METHOD_ORDER rows (see score.py)."""
    b = loss.shape[0]
    lhat = jnp.clip(loss / (jnp.max(loss) + 1e-9), 0.0, 1.0 - 1e-3)
    ada = 0.5 * jnp.log((1.0 + lhat) / (1.0 - lhat))
    dev = jnp.abs(loss - jnp.mean(loss))
    return jnp.stack(
        [
            jnp.full((b,), 1.0 / b, loss.dtype),
            _softmax(_standardize(loss)),
            _softmax(_standardize(-loss)),
            _softmax(_standardize(gnorm)),
            _softmax(_standardize(ada)),
            _softmax(_standardize(dev)),
            _softmax(_standardize(-dev)),
        ]
    )


def cl_reward(loss, t, p):
    """Curriculum reward of eq. 4, normalized to mean 1."""
    b = loss.shape[0]
    t = jnp.maximum(t, 1.0)
    r = jnp.exp(-jnp.power(t, p) * loss / (jnp.sum(loss * loss) + 1e-9))
    return r * (b / jnp.sum(r))


def adaselection_score(loss, gnorm, w, knobs):
    alpha = method_alphas(loss, gnorm)
    base = jnp.sum(alpha * w[:, None], axis=0)
    r = cl_reward(loss, knobs[0], knobs[1])
    r = knobs[2] * r + (1.0 - knobs[2])
    return r * base, alpha

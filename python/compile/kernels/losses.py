"""L1 Pallas per-sample loss kernels.

These are the kernels AdaSelection adds to the training hot path: one cheap
full-batch forward that must produce, *per sample*,

  * the loss  ``l_i``  (eq. 2's ordering statistic for most methods), and
  * the Katharopoulos–Fleuret last-layer gradient-norm upper bound
    ``g_i ≈ ||softmax(z_i) − onehot(y_i)||_2 · ||h_i||_2``
    (the Gradient Norm baseline, computed without a per-sample backward).

All kernels are single-VMEM-block (batch ≤ 128, classes ≤ 256 ⇒ ≤ 2 MiB of
f32 per operand) and run under ``interpret=True`` on CPU PJRT (see
DESIGN.md §Hardware-Adaptation).

Each public entry point is a ``jax.custom_vjp`` function so that the same
Pallas forward participates in the train-step artifact's backward pass
(the VJP of softmax-CE is recovered from the saved probabilities; the
gnorm output is treated as non-differentiable — it only feeds the scorer).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-9


# ---------------------------------------------------------------------------
# classification: per-sample softmax cross-entropy (+ fused gnorm proxy)
# ---------------------------------------------------------------------------


def _xent_kernel(logits_ref, labels_ref, fnorm_ref, loss_ref, gnorm_ref, p_ref):
    z = logits_ref[...]
    y = labels_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    logp = z - zmax - jnp.log(denom)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y[:, None]
    ).astype(z.dtype)
    p = ez / denom
    loss_ref[...] = -jnp.sum(onehot * logp, axis=-1)
    gnorm_ref[...] = (
        jnp.sqrt(jnp.sum((p - onehot) ** 2, axis=-1) + _EPS) * fnorm_ref[...]
    )
    p_ref[...] = p


def _xent_call(logits, labels, fnorm):
    b, c = logits.shape
    return pl.pallas_call(
        _xent_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b, c), logits.dtype),
        ),
        interpret=True,
    )(logits, labels, fnorm)


@jax.custom_vjp
def persample_xent(logits, labels, fnorm):
    """Per-sample CE loss and grad-norm proxy.

    Args:
      logits: f32[B, C]
      labels: i32[B]
      fnorm:  f32[B] — ‖h‖₂ of the pre-head features (for the gnorm proxy).

    Returns:
      (loss f32[B], gnorm f32[B])
    """
    loss, gnorm, _ = _xent_call(logits, labels, fnorm)
    return loss, gnorm


def _persample_xent_fwd(logits, labels, fnorm):
    loss, gnorm, p = _xent_call(logits, labels, fnorm)
    return (loss, gnorm), (p, labels)


def _persample_xent_bwd(res, cts):
    p, labels = res
    gl, _ = cts  # gnorm feeds the scorer only; treat as constant.
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, p.shape, 1) == labels[:, None]
    ).astype(p.dtype)
    dlogits = (p - onehot) * gl[:, None]
    return dlogits, None, jnp.zeros((p.shape[0],), p.dtype)


persample_xent.defvjp(_persample_xent_fwd, _persample_xent_bwd)


# ---------------------------------------------------------------------------
# regression: per-sample squared error (+ gnorm proxy |r| * ||h||)
# ---------------------------------------------------------------------------


def _sqerr_kernel(pred_ref, y_ref, fnorm_ref, loss_ref, gnorm_ref):
    r = pred_ref[...] - y_ref[...]
    loss_ref[...] = 0.5 * r * r
    gnorm_ref[...] = jnp.abs(r) * fnorm_ref[...]


def _sqerr_call(pred, y, fnorm):
    b = pred.shape[0]
    return pl.pallas_call(
        _sqerr_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), pred.dtype),
            jax.ShapeDtypeStruct((b,), pred.dtype),
        ),
        interpret=True,
    )(pred, y, fnorm)


@jax.custom_vjp
def persample_sqerr(pred, y, fnorm):
    """Per-sample 0.5·(pred−y)² and gnorm proxy |pred−y|·‖h‖."""
    return _sqerr_call(pred, y, fnorm)


def _persample_sqerr_fwd(pred, y, fnorm):
    out = _sqerr_call(pred, y, fnorm)
    return out, (pred, y)


def _persample_sqerr_bwd(res, cts):
    pred, y = res
    gl, _ = cts
    r = pred - y
    return r * gl, -r * gl, jnp.zeros_like(pred)


persample_sqerr.defvjp(_persample_sqerr_fwd, _persample_sqerr_bwd)


# ---------------------------------------------------------------------------
# language modeling: per-sequence mean token CE (+ gnorm proxy)
# ---------------------------------------------------------------------------


def _lm_kernel(logits_ref, labels_ref, fnorm_ref, loss_ref, gnorm_ref, p_ref):
    z = logits_ref[...]  # (B, T, V)
    y = labels_ref[...]  # (B, T)
    zmax = jnp.max(z, axis=-1, keepdims=True)
    ez = jnp.exp(z - zmax)
    denom = jnp.sum(ez, axis=-1, keepdims=True)
    logp = z - zmax - jnp.log(denom)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, z.shape, 2) == y[..., None]
    ).astype(z.dtype)
    tok_loss = -jnp.sum(onehot * logp, axis=-1)  # (B, T)
    p = ez / denom
    tok_g = jnp.sqrt(jnp.sum((p - onehot) ** 2, axis=-1) + _EPS)  # (B, T)
    loss_ref[...] = jnp.mean(tok_loss, axis=-1)
    gnorm_ref[...] = jnp.mean(tok_g * fnorm_ref[...], axis=-1)
    p_ref[...] = p


def _lm_call(logits, labels, fnorm):
    b, t, v = logits.shape
    return pl.pallas_call(
        _lm_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b,), logits.dtype),
            jax.ShapeDtypeStruct((b, t, v), logits.dtype),
        ),
        interpret=True,
    )(logits, labels, fnorm)


@jax.custom_vjp
def persample_lm_xent(logits, labels, fnorm):
    """Per-sequence mean CE and gnorm proxy.

    Args:
      logits: f32[B, T, V]
      labels: i32[B, T]
      fnorm:  f32[B, T]
    Returns:
      (loss f32[B], gnorm f32[B])
    """
    loss, gnorm, _ = _lm_call(logits, labels, fnorm)
    return loss, gnorm


def _persample_lm_fwd(logits, labels, fnorm):
    loss, gnorm, p = _lm_call(logits, labels, fnorm)
    return (loss, gnorm), (p, labels)


def _persample_lm_bwd(res, cts):
    p, labels = res
    gl, _ = cts
    t = p.shape[1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, p.shape, 2) == labels[..., None]
    ).astype(p.dtype)
    dlogits = (p - onehot) * (gl[:, None, None] / t)
    return dlogits, None, jnp.zeros(p.shape[:2], p.dtype)


persample_lm_xent.defvjp(_persample_lm_fwd, _persample_lm_bwd)

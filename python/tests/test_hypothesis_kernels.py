"""Hypothesis sweeps over the Pallas kernels' shape/value space.

These complement the fixed-shape tests in test_kernels.py: hypothesis drives
batch sizes, class counts, tile-boundary shapes and value scales, asserting
kernel-vs-ref allclose everywhere (the L1 contract the rust layer builds on).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    matmul,
    persample_xent,
    persample_sqerr,
    adaselection_score,
    NUM_METHODS,
)
from compile.kernels import ref

_SETTINGS = dict(max_examples=25, deadline=None)


def _arr(key, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


def assert_close_normed(got, want, tol=1e-5):
    """Error relative to the result's max magnitude — the right metric for
    f32 matmuls where accumulation-order noise hits near-zero elements."""
    scale = float(jnp.max(jnp.abs(want))) + 1e-30
    err = float(jnp.max(jnp.abs(got - want))) / scale
    assert err < tol, f"norm-relative error {err} >= {tol}"


@settings(**_SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 150),
    n=st.integers(1, 200),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**16),
)
def test_matmul_any_shape(m, k, n, scale, seed):
    x = _arr(seed, (m, k), scale)
    w = _arr(seed + 1, (k, n), scale)
    assert_close_normed(matmul(x, w), ref.matmul(x, w))


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 160),
    c=st.integers(2, 128),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
    seed=st.integers(0, 2**16),
)
def test_xent_any_shape(b, c, scale, seed):
    logits = _arr(seed, (b, c), scale)
    labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, c)
    fnorm = jnp.abs(_arr(seed + 2, (b,), 1.0)) + 0.01
    l_k, g_k = persample_xent(logits, labels, fnorm)
    l_r, g_r = ref.persample_xent(logits, labels, fnorm)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(l_k >= -1e-6))


@settings(**_SETTINGS)
@given(b=st.integers(1, 500), seed=st.integers(0, 2**16))
def test_sqerr_any_shape(b, seed):
    pred = _arr(seed, (b,), 5.0)
    y = _arr(seed + 1, (b,), 5.0)
    fn = jnp.abs(_arr(seed + 2, (b,), 1.0))
    l_k, g_k = persample_sqerr(pred, y, fn)
    l_r, g_r = ref.persample_sqerr(pred, y, fn)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-6, atol=1e-6)


@settings(**_SETTINGS)
@given(
    b=st.integers(2, 128),
    t=st.floats(1.0, 1e5),
    p=st.floats(-1.0, 0.0),
    cl_on=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**16),
)
def test_score_invariants_any_batch(b, t, p, cl_on, seed):
    loss = jnp.abs(_arr(seed, (b,), 2.0)) + 1e-4
    gnorm = jnp.abs(_arr(seed + 1, (b,), 2.0)) + 1e-4
    w = jnp.abs(_arr(seed + 2, (NUM_METHODS,), 1.0)) + 0.05
    knobs = jnp.array([t, p, cl_on], jnp.float32)
    s_k, a_k = adaselection_score(loss, gnorm, w, knobs)
    s_r, a_r = ref.adaselection_score(loss, gnorm, w, knobs)
    np.testing.assert_allclose(s_k, s_r, rtol=2e-4, atol=1e-6)
    # invariants the coordinator relies on
    assert bool(jnp.all(s_k >= -1e-7)), "scores must be non-negative"
    assert bool(jnp.all(jnp.isfinite(s_k)))
    np.testing.assert_allclose(
        jnp.sum(a_k, axis=1), jnp.ones(NUM_METHODS), rtol=1e-4
    )

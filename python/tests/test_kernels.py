"""Kernel-vs-ref correctness: the CORE L1 signal (values AND gradients)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (
    matmul,
    persample_xent,
    persample_sqerr,
    persample_lm_xent,
)
from compile.kernels import ref


def _key(i=0):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (4, 7, 3), (100, 8, 64), (128, 64, 100), (256, 128, 256), (130, 70, 50)],
)
def test_matmul_matches_ref(m, k, n):
    k1, k2 = jax.random.split(_key(m * 1000 + k * 10 + n))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    _assert_normed(matmul(x, w), ref.matmul(x, w))


def _assert_normed(got, want, tol=1e-5):
    scale = float(jnp.max(jnp.abs(want))) + 1e-30
    err = float(jnp.max(jnp.abs(got - want))) / scale
    assert err < tol, f"norm-relative error {err} >= {tol}"


def test_matmul_grad_matches_ref():
    k1, k2 = jax.random.split(_key(7))
    x = jax.random.normal(k1, (32, 16), jnp.float32)
    w = jax.random.normal(k2, (16, 8), jnp.float32)

    def f_k(x, w):
        return jnp.sum(jnp.tanh(matmul(x, w)))

    def f_r(x, w):
        return jnp.sum(jnp.tanh(ref.matmul(x, w)))

    gx_k, gw_k = jax.grad(f_k, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_r, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-5)


def test_matmul_identity():
    x = jax.random.normal(_key(1), (16, 16), jnp.float32)
    np.testing.assert_allclose(matmul(x, jnp.eye(16)), x, rtol=1e-6, atol=1e-6)


def test_matmul_zero():
    x = jax.random.normal(_key(2), (8, 4), jnp.float32)
    out = matmul(x, jnp.zeros((4, 5), jnp.float32))
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_matmul_large_tiled_grid():
    # forces a multi-step K/M/N grid (dims > 128 block)
    k1, k2 = jax.random.split(_key(3))
    x = jax.random.normal(k1, (256, 192), jnp.float32)
    w = jax.random.normal(k2, (192, 160), jnp.float32)
    _assert_normed(matmul(x, w), ref.matmul(x, w))


def test_matmul_nondividing_edge_blocks():
    # exercises the zero-padding path (dims just over the 128 block)
    k1, k2 = jax.random.split(_key(9))
    x = jax.random.normal(k1, (129, 130), jnp.float32)
    w = jax.random.normal(k2, (130, 131), jnp.float32)
    out = matmul(x, w)
    assert bool(jnp.all(jnp.isfinite(out)))
    _assert_normed(out, ref.matmul(x, w))


# ---------------------------------------------------------------------------
# per-sample softmax cross-entropy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,c", [(1, 2), (16, 10), (128, 10), (128, 100), (64, 256)])
def test_xent_matches_ref(b, c):
    k1, k2, k3 = jax.random.split(_key(b + c), 3)
    logits = jax.random.normal(k1, (b, c), jnp.float32) * 3.0
    labels = jax.random.randint(k2, (b,), 0, c)
    fnorm = jnp.abs(jax.random.normal(k3, (b,))) + 0.1
    l_k, g_k = persample_xent(logits, labels, fnorm)
    l_r, g_r = ref.persample_xent(logits, labels, fnorm)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-5, atol=1e-5)


def test_xent_perfect_prediction_low_loss():
    logits = jnp.eye(8, dtype=jnp.float32) * 20.0
    labels = jnp.arange(8)
    loss, gnorm = persample_xent(logits, labels, jnp.ones(8))
    assert float(jnp.max(loss)) < 1e-3
    assert float(jnp.max(gnorm)) < 1e-3  # p ≈ onehot ⇒ tiny grad norm


def test_xent_grad_matches_ref():
    k1, k2 = jax.random.split(_key(11))
    logits = jax.random.normal(k1, (32, 10), jnp.float32)
    labels = jax.random.randint(k2, (32,), 0, 10)
    fn = jnp.ones(32)
    g_k = jax.grad(lambda z: jnp.mean(persample_xent(z, labels, fn)[0]))(logits)
    g_r = jax.grad(lambda z: jnp.mean(ref.persample_xent(z, labels, fn)[0]))(logits)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-4, atol=1e-6)


def test_xent_shift_invariance():
    # softmax-CE is invariant to adding a constant to all logits
    k1, k2 = jax.random.split(_key(12))
    logits = jax.random.normal(k1, (16, 5), jnp.float32)
    labels = jax.random.randint(k2, (16,), 0, 5)
    fn = jnp.ones(16)
    l1, _ = persample_xent(logits, labels, fn)
    l2, _ = persample_xent(logits + 100.0, labels, fn)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_xent_gnorm_scales_with_fnorm():
    k1, k2 = jax.random.split(_key(13))
    logits = jax.random.normal(k1, (16, 5), jnp.float32)
    labels = jax.random.randint(k2, (16,), 0, 5)
    _, g1 = persample_xent(logits, labels, jnp.ones(16))
    _, g2 = persample_xent(logits, labels, 3.0 * jnp.ones(16))
    np.testing.assert_allclose(g2, 3.0 * g1, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# per-sample squared error
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b", [1, 10, 100, 257])
def test_sqerr_matches_ref(b):
    k1, k2, k3 = jax.random.split(_key(b), 3)
    pred = jax.random.normal(k1, (b,), jnp.float32)
    y = jax.random.normal(k2, (b,), jnp.float32)
    fn = jnp.abs(jax.random.normal(k3, (b,)))
    l_k, g_k = persample_sqerr(pred, y, fn)
    l_r, g_r = ref.persample_sqerr(pred, y, fn)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-6, atol=1e-7)


def test_sqerr_zero_residual():
    y = jnp.arange(8.0)
    loss, gnorm = persample_sqerr(y, y, jnp.ones(8))
    assert float(jnp.max(loss)) == 0.0
    assert float(jnp.max(gnorm)) == 0.0


def test_sqerr_grad_is_residual():
    pred = jnp.array([3.0, -1.0])
    y = jnp.array([1.0, 1.0])
    g = jax.grad(lambda p: jnp.sum(persample_sqerr(p, y, jnp.ones(2))[0]))(pred)
    np.testing.assert_allclose(g, pred - y, rtol=1e-6)


# ---------------------------------------------------------------------------
# per-sequence LM cross-entropy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,t,v", [(1, 4, 8), (8, 16, 32), (64, 32, 256)])
def test_lm_xent_matches_ref(b, t, v):
    k1, k2, k3 = jax.random.split(_key(b * t + v), 3)
    logits = jax.random.normal(k1, (b, t, v), jnp.float32)
    labels = jax.random.randint(k2, (b, t), 0, v)
    fn = jnp.abs(jax.random.normal(k3, (b, t))) + 0.1
    l_k, g_k = persample_lm_xent(logits, labels, fn)
    l_r, g_r = ref.persample_lm_xent(logits, labels, fn)
    np.testing.assert_allclose(l_k, l_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-5, atol=1e-5)


def test_lm_xent_grad_matches_ref():
    k1, k2 = jax.random.split(_key(21))
    logits = jax.random.normal(k1, (4, 8, 16), jnp.float32)
    labels = jax.random.randint(k2, (4, 8), 0, 16)
    fn = jnp.ones((4, 8))
    g_k = jax.grad(lambda z: jnp.mean(persample_lm_xent(z, labels, fn)[0]))(logits)
    g_r = jax.grad(lambda z: jnp.mean(ref.persample_lm_xent(z, labels, fn)[0]))(logits)
    np.testing.assert_allclose(g_k, g_r, rtol=1e-4, atol=1e-6)


def test_lm_xent_uniform_logits_loss_is_log_v():
    b, t, v = 4, 8, 32
    logits = jnp.zeros((b, t, v), jnp.float32)
    labels = jnp.zeros((b, t), jnp.int32)
    loss, _ = persample_lm_xent(logits, labels, jnp.ones((b, t)))
    np.testing.assert_allclose(loss, jnp.full((b,), jnp.log(v)), rtol=1e-5)

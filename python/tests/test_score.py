"""AdaSelection fused scorer: kernel-vs-ref plus the selection invariants
that the rust coordinator relies on (also property-tested there)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import adaselection_score, METHOD_ORDER, NUM_METHODS
from compile.kernels import ref


def _inputs(seed, b=128):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    loss = jnp.abs(jax.random.normal(k1, (b,), jnp.float32)) + 1e-3
    gnorm = jnp.abs(jax.random.normal(k2, (b,), jnp.float32)) + 1e-3
    return loss, gnorm


@pytest.mark.parametrize("b", [4, 64, 100, 128])
@pytest.mark.parametrize("cl_on", [0.0, 1.0])
def test_score_matches_ref(b, cl_on):
    loss, gnorm = _inputs(b, b)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (NUM_METHODS,))) + 0.1
    knobs = jnp.array([17.0, -0.5, cl_on], jnp.float32)
    s_k, a_k = adaselection_score(loss, gnorm, w, knobs)
    s_r, a_r = ref.adaselection_score(loss, gnorm, w, knobs)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a_k, a_r, rtol=1e-5, atol=1e-6)


def test_method_order_is_frozen():
    # the rust coordinator hard-codes this order via manifest.json
    assert METHOD_ORDER == (
        "uniform",
        "big_loss",
        "small_loss",
        "grad_norm",
        "adaboost",
        "coreset1",
        "coreset2",
    )


def test_alphas_are_simplex_rows():
    loss, gnorm = _inputs(3)
    _, alpha = adaselection_score(
        loss, gnorm, jnp.ones(NUM_METHODS) / NUM_METHODS, jnp.array([1.0, -0.5, 0.0])
    )
    np.testing.assert_allclose(jnp.sum(alpha, axis=1), jnp.ones(NUM_METHODS), rtol=1e-5)
    assert float(jnp.min(alpha)) >= 0.0


def test_big_loss_alpha_orders_like_loss():
    loss, gnorm = _inputs(4)
    _, alpha = adaselection_score(
        loss, gnorm, jnp.ones(NUM_METHODS), jnp.array([1.0, -0.5, 0.0])
    )
    big = alpha[1]
    small = alpha[2]
    order = jnp.argsort(loss)
    assert jnp.all(jnp.diff(big[order]) >= -1e-9), "big_loss must be ↑ in loss"
    assert jnp.all(jnp.diff(small[order]) <= 1e-9), "small_loss must be ↓ in loss"


def test_single_method_weight_reduces_to_that_method():
    loss, gnorm = _inputs(5)
    w = jnp.zeros(NUM_METHODS).at[1].set(1.0)  # pure big_loss
    knobs = jnp.array([1.0, -0.5, 0.0])
    s, alpha = adaselection_score(loss, gnorm, w, knobs)
    np.testing.assert_allclose(s, alpha[1], rtol=1e-6, atol=1e-8)


def test_uniform_alpha_is_constant():
    loss, gnorm = _inputs(6, b=64)
    _, alpha = adaselection_score(
        loss, gnorm, jnp.ones(NUM_METHODS), jnp.array([1.0, -0.5, 0.0])
    )
    np.testing.assert_allclose(alpha[0], jnp.full(64, 1.0 / 64), rtol=1e-6)


def test_score_linear_in_w():
    # s(w1 + w2) = s(w1) + s(w2) with CL off
    loss, gnorm = _inputs(7)
    knobs = jnp.array([1.0, -0.5, 0.0])
    w1 = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (NUM_METHODS,)))
    w2 = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (NUM_METHODS,)))
    s1, _ = adaselection_score(loss, gnorm, w1, knobs)
    s2, _ = adaselection_score(loss, gnorm, w2, knobs)
    s12, _ = adaselection_score(loss, gnorm, w1 + w2, knobs)
    np.testing.assert_allclose(s12, s1 + s2, rtol=1e-4, atol=1e-6)


def test_cl_reward_mean_one_and_favors_small_loss():
    loss, _ = _inputs(8)
    r = ref.cl_reward(loss, jnp.array(1.0), jnp.array(-0.5))
    np.testing.assert_allclose(jnp.mean(r), 1.0, rtol=1e-5)
    i_small = int(jnp.argmin(loss))
    i_big = int(jnp.argmax(loss))
    assert float(r[i_small]) > float(r[i_big])


def test_cl_reward_fades_with_iteration():
    # with p < 0 the reward flattens toward 1 as t grows (DESIGN.md §5.3)
    loss, _ = _inputs(9)
    r_early = ref.cl_reward(loss, jnp.array(1.0), jnp.array(-0.5))
    r_late = ref.cl_reward(loss, jnp.array(1e6), jnp.array(-0.5))
    spread_early = float(jnp.max(r_early) - jnp.min(r_early))
    spread_late = float(jnp.max(r_late) - jnp.min(r_late))
    assert spread_late < spread_early


def test_constant_losses_degenerate_to_uniform():
    b = 32
    loss = jnp.full((b,), 0.7, jnp.float32)
    gnorm = jnp.full((b,), 0.3, jnp.float32)
    s, alpha = adaselection_score(
        loss, gnorm, jnp.ones(NUM_METHODS) / NUM_METHODS, jnp.array([1.0, -0.5, 1.0])
    )
    np.testing.assert_allclose(alpha, jnp.full_like(alpha, 1.0 / b), rtol=1e-4)
    np.testing.assert_allclose(s, jnp.full((b,), 1.0 / b), rtol=1e-4)

"""Model shape/init sanity + train-step behaviour for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import make_families

FAMILIES = make_families()


def _data(fam, n, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    if fam.task == "regression":
        x = jax.random.normal(k1, (n, fam.spec.in_dim), jnp.float32)
        y = 2.0 * x[:, 0] + 1.0
    elif fam.task == "classification":
        x = jax.random.normal(k1, (n,) + fam.spec.in_dim, jnp.float32)
        y = jax.random.randint(k2, (n,), 0, fam.spec.num_classes)
    else:
        x = jax.random.randint(k1, (n, fam.spec.seq_len), 0, fam.spec.vocab)
        y = jnp.roll(x, -1, axis=1)
    return x, y


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_init_matches_param_specs(name):
    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(0))
    specs = fam.spec.param_specs()
    assert len(params) == len(specs)
    for p, (pname, shape) in zip(params, specs):
        assert p.shape == tuple(shape), pname
        assert p.dtype == jnp.float32
        assert bool(jnp.all(jnp.isfinite(p))), pname


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_fwd_shapes_and_finite(name):
    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(1))
    x, y = _data(fam, fam.batch)
    loss, gnorm = fam.fwd_fn()(*params, x, y)
    assert loss.shape == (fam.batch,)
    assert gnorm.shape == (fam.batch,)
    assert bool(jnp.all(jnp.isfinite(loss)))
    assert bool(jnp.all(loss >= 0.0))
    assert bool(jnp.all(gnorm >= 0.0))


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_train_step_updates_params_and_momentum(name):
    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(2))
    mom = [jnp.zeros_like(p) for p in params]
    k = fam.train_sizes()[0]
    x, y = _data(fam, k)
    out = fam.train_fn()(*params, *mom, x, y, jnp.float32(0.01))
    n = fam.n_params()
    new_params, new_mom, loss = out[:n], out[n : 2 * n], out[-1]
    assert float(loss) > 0.0
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(new_params, params)
    )
    assert changed, "train step must move parameters"
    # momentum after first step == gradient, so some must be nonzero
    assert any(float(jnp.max(jnp.abs(m))) > 0 for m in new_mom)


@pytest.mark.parametrize("name", ["mlp_simple", "mlp_bike"])
def test_repeated_steps_decrease_regression_loss(name):
    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(3))
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _data(fam, fam.batch)
    train = jax.jit(fam.train_fn())
    losses = []
    n = fam.n_params()
    for _ in range(60):
        out = train(*params, *mom, x, y, jnp.float32(0.05))
        params, mom, loss = list(out[:n]), list(out[n : 2 * n]), out[-1]
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_resnet_steps_decrease_loss():
    fam = FAMILIES["resnet_c10"]
    params = fam.spec.init(jax.random.PRNGKey(4))
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _data(fam, 32)
    # overfit a fixed 32-sample batch: loss must fall significantly
    train = jax.jit(fam.train_fn())
    n = fam.n_params()
    first = None
    for i in range(30):
        out = train(*params, *mom, x, y, jnp.float32(0.05))
        params, mom, loss = list(out[:n]), list(out[n : 2 * n]), out[-1]
        if first is None:
            first = float(loss)
    assert float(loss) < 0.7 * first, (first, float(loss))


def test_transformer_steps_decrease_loss():
    fam = FAMILIES["transformer"]
    params = fam.spec.init(jax.random.PRNGKey(5))
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _data(fam, 16)
    train = jax.jit(fam.train_fn())
    n = fam.n_params()
    first = None
    for i in range(25):
        out = train(*params, *mom, x, y, jnp.float32(0.1))
        params, mom, loss = list(out[:n]), list(out[n : 2 * n]), out[-1]
        if first is None:
            first = float(loss)
    assert float(loss) < 0.9 * first, (first, float(loss))


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_eval_fn_mask_and_ranges(name):
    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(6))
    x, y = _data(fam, fam.batch)
    mask = jnp.ones(fam.batch).at[fam.batch // 2 :].set(0.0)
    loss_sum, correct = fam.eval_fn()(*params, x, y, mask)
    assert float(loss_sum) >= 0.0
    assert 0.0 <= float(correct) <= float(jnp.sum(mask))
    # zero mask ⇒ zero sums
    z_loss, z_corr = fam.eval_fn()(*params, x, y, jnp.zeros(fam.batch))
    assert float(z_loss) == 0.0 and float(z_corr) == 0.0


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_init_fn_momentum_zero_and_deterministic(name):
    fam = FAMILIES[name]
    out1 = fam.init_fn()(jnp.int32(42))
    out2 = fam.init_fn()(jnp.int32(42))
    out3 = fam.init_fn()(jnp.int32(43))
    n = fam.n_params()
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)
    assert any(
        float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(out1[:n], out3[:n])
    ), "different seeds must differ"
    for m in out1[n:]:
        assert float(jnp.max(jnp.abs(m))) == 0.0


def test_fwd_loss_identifies_mislabeled_outliers():
    """The property AdaSelection exploits: corrupted labels ⇒ larger loss."""
    fam = FAMILIES["resnet_c10"]
    params = fam.spec.init(jax.random.PRNGKey(7))
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _data(fam, 64, seed=8)
    train = jax.jit(fam.train_fn())
    n = fam.n_params()
    for _ in range(25):
        out = train(*params, *mom, x, y, jnp.float32(0.05))
        params, mom = list(out[:n]), list(out[n : 2 * n])
    y_bad = y.at[:8].set((y[:8] + 1) % 10)
    loss, _ = fam.fwd_fn()(*params, x, y_bad)
    assert float(jnp.mean(loss[:8])) > float(jnp.mean(loss[8:]))


@pytest.mark.parametrize("name", sorted(FAMILIES))
def test_fwd_score_fused_matches_separate(name):
    """The fused fwd+score artifact must equal fwd followed by the scorer."""
    import jax.numpy as jnp
    from compile.kernels import adaselection_score, NUM_METHODS

    fam = FAMILIES[name]
    params = fam.spec.init(jax.random.PRNGKey(8))
    x, y = _data(fam, fam.batch)
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (NUM_METHODS,))) + 0.1
    knobs = jnp.array([3.0, -0.5, 1.0], jnp.float32)

    loss_f, gnorm_f, s_f, alpha_f = fam.fwd_score_fn()(*params, x, y, w, knobs)
    loss_s, gnorm_s = fam.fwd_fn()(*params, x, y)
    s_s, alpha_s = adaselection_score(loss_s, gnorm_s, w, knobs)
    np.testing.assert_allclose(loss_f, loss_s, rtol=1e-6)
    np.testing.assert_allclose(gnorm_f, gnorm_s, rtol=1e-6)
    np.testing.assert_allclose(s_f, s_s, rtol=1e-6)
    np.testing.assert_allclose(alpha_f, alpha_s, rtol=1e-6)

"""Manifest schema + artifact-tree integrity (what rust deserializes)."""

import json
import math
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def _load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_top_level_schema():
    m = _load()
    for key in ("version", "method_order", "momentum", "families", "score", "artifacts"):
        assert key in m, key
    assert m["version"] == 1
    assert m["momentum"] == 0.9
    assert m["method_order"][0] == "uniform"
    assert len(m["method_order"]) == 7


def test_every_artifact_file_exists_and_parses_header():
    m = _load()
    for name, art in m["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), name
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} missing HloModule header"


def test_family_artifact_references_resolve():
    m = _load()
    arts = m["artifacts"]
    for fname, fam in m["families"].items():
        a = fam["artifacts"]
        assert a["init"] in arts
        assert a["fwd"] in arts
        assert a["eval"] in arts
        for k, nm in a["train"].items():
            assert nm in arts, (fname, k)
            assert int(k) in fam["train_sizes"]


def test_train_sizes_are_gamma_grid():
    m = _load()
    for fname, fam in m["families"].items():
        b = fam["batch"]
        want = sorted({int(math.ceil(g * b)) for g in m["gamma_grid"]}) + [b]
        assert fam["train_sizes"] == want, fname


def test_io_shapes_consistent_with_params():
    m = _load()
    for fname, fam in m["families"].items():
        n = len(fam["params"])
        fwd = m["artifacts"][fam["artifacts"]["fwd"]]
        # fwd inputs = params + x + y
        assert len(fwd["inputs"]) == n + 2, fname
        for p, inp in zip(fam["params"], fwd["inputs"]):
            assert inp["shape"] == p["shape"], (fname, p["name"])
        # fwd outputs: two B-vectors
        b = fam["batch"]
        assert [o["shape"] for o in fwd["outputs"]] == [[b], [b]]
        # train: params + mom + x + y + lr -> params' + mom' + loss
        k0 = fam["train_sizes"][0]
        tr = m["artifacts"][fam["artifacts"]["train"][str(k0)]]
        assert len(tr["inputs"]) == 2 * n + 3, fname
        assert len(tr["outputs"]) == 2 * n + 1, fname
        assert tr["outputs"][-1]["shape"] == []


def test_score_artifacts_per_batch():
    m = _load()
    batches = {str(f["batch"]) for f in m["families"].values()}
    assert set(m["score"].keys()) == batches
    for bs, name in m["score"].items():
        art = m["artifacts"][name]
        assert art["inputs"][0]["shape"] == [int(bs)]
        assert art["outputs"][1]["shape"] == [7, int(bs)]

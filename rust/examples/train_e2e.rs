//! End-to-end driver (the DESIGN.md §7 validation run, recorded in
//! EXPERIMENTS.md): stream the synthetic CIFAR10 workload through the full
//! pipeline and train the mini-ResNet twice —
//!
//!   1. benchmark: no subsampling (train on every sample), and
//!   2. AdaSelection at γ = 0.3,
//!
//! logging the loss curve per epoch and reporting the paper's headline
//! metric: wall-clock training-time saving at comparable test accuracy.
//!
//! Run: cargo run --release --example train_e2e
//! Env: E2E_EPOCHS / E2E_SCALE to resize (defaults: 6 epochs, 0.04 scale
//! ⇒ 2000 train / 400 test images).

use adaselection::config::RunConfig;
use adaselection::runtime::NativeBackend;
use adaselection::train;
use adaselection::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();
    let epochs: usize = std::env::var("E2E_EPOCHS").ok().and_then(|s| s.parse().ok()).unwrap_or(6);
    let scale: f64 = std::env::var("E2E_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.04);

    let base = {
        let mut c = RunConfig::default();
        c.dataset = "cifar10".into();
        c.epochs = epochs;
        c.lr = 0.05;
        c.data_scale = scale;
        c.workers = 2;
        c
    };
    let mut backend = NativeBackend::new();

    println!("=== benchmark (no subsampling) ===");
    let mut bench_cfg = base.clone();
    bench_cfg.selector = "benchmark".into();
    let bench = train::run_with(&mut backend, bench_cfg)?;
    print_curve(&bench);

    println!("\n=== AdaSelection γ = 0.3 (big_loss + small_loss + uniform) ===");
    let mut ada_cfg = base.clone();
    ada_cfg.selector = "adaselection:big_loss+small_loss+uniform".into();
    ada_cfg.gamma = 0.3;
    let ada = train::run_with(&mut backend, ada_cfg)?;
    print_curve(&ada);

    let saving = 100.0 * (1.0 - ada.train_time_s() / bench.train_time_s());
    println!("\n=== headline ===");
    println!(
        "benchmark: acc={:.4} time={:.2}s | adaselection: acc={:.4} time={:.2}s",
        bench.final_test_acc(),
        bench.train_time_s(),
        ada.final_test_acc(),
        ada.train_time_s()
    );
    println!(
        "training-time saving: {saving:.1}%  (paper claims ≥20% at γ ≤ 0.5, Fig 3)"
    );
    println!(
        "accuracy gap vs benchmark: {:+.2} points",
        100.0 * (ada.final_test_acc() - bench.final_test_acc())
    );
    println!("\nada phases:   {}", ada.phases.summary());
    println!("bench phases: {}", bench.phases.summary());
    if let Some(w) = ada.weight_trace.last() {
        println!("final method weights {:?} = {w:?}", ada.weight_names);
    }
    Ok(())
}

fn print_curve(r: &adaselection::metrics::RunResult) {
    println!(
        "{:<8} {:>12} {:>12} {:>10} {:>10}",
        "epoch", "train_loss", "test_loss", "test_acc", "time_s"
    );
    for e in &r.epochs {
        println!(
            "{:<8} {:>12.4} {:>12.4} {:>10.4} {:>10.2}",
            e.epoch, e.train_loss, e.test_loss, e.test_acc, e.train_time_s
        );
    }
}

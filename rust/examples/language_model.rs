//! Transformer language model on the WikiText-2 substitute (Fig 9 workload):
//! AdaSelection vs uniform vs big-loss subsampling for next-token training.
//! Note grad_norm is excluded, matching the paper's footnote 4.
//!
//! Run: cargo run --release --example language_model

use adaselection::config::RunConfig;
use adaselection::runtime::NativeBackend;
use adaselection::train;
use adaselection::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();
    let base = {
        let mut c = RunConfig::default();
        c.dataset = "wikitext".into();
        c.gamma = 0.3;
        c.epochs = 4;
        c.lr = 0.1;
        c.data_scale = 0.01; // ~20k train tokens → ~650 windows
        c
    };
    let mut backend = NativeBackend::new();

    println!("{:<45} {:>10} {:>10} {:>10}", "selector", "test_loss", "tok_acc", "time_s");
    for sel in [
        "benchmark",
        "adaselection:big_loss+small_loss+uniform",
        "uniform",
        "big_loss",
        "small_loss",
    ] {
        let mut cfg = base.clone();
        cfg.selector = sel.into();
        let r = train::run_with(&mut backend, cfg)?;
        println!(
            "{:<45} {:>10.4} {:>10.4} {:>10.2}",
            r.selector,
            r.final_test_loss(),
            r.final_test_acc(),
            r.train_time_s()
        );
    }
    println!("\n(untrained loss would be ln 256 ≈ 5.55 — the paper's Table 4 row is ~5.5)");
    Ok(())
}

//! Bike-sharing regression (the paper's Fig 6 workload): compare every
//! baseline against AdaSelection on a small tabular task with storm-day
//! outliers — the regime where Big Loss chases corrupted targets and the
//! coreset approximations shine.
//!
//! Run: cargo run --release --example regression_bike

use adaselection::config::RunConfig;
use adaselection::runtime::NativeBackend;
use adaselection::train;
use adaselection::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();
    let selectors = [
        "benchmark",
        "adaselection:big_loss+small_loss+uniform",
        "uniform",
        "big_loss",
        "small_loss",
        "coreset1",
        "coreset2",
    ];
    let base = {
        let mut c = RunConfig::default();
        c.dataset = "bike".into();
        c.gamma = 0.3;
        c.epochs = 20; // 730 rows → 5 batches/epoch: cheap
        c.lr = 0.02;
        c
    };
    let mut backend = NativeBackend::new();

    println!(
        "{:<45} {:>10} {:>10}",
        "selector", "test_loss", "time_s"
    );
    let mut rows = Vec::new();
    for sel in selectors {
        let mut cfg = base.clone();
        cfg.selector = sel.into();
        let r = train::run_with(&mut backend, cfg)?;
        println!(
            "{:<45} {:>10.4} {:>10.2}",
            r.selector,
            r.final_test_loss(),
            r.train_time_s()
        );
        rows.push(r);
    }

    // the paper's point: AdaSelection tracks the best candidate
    let ada = rows.iter().find(|r| r.selector.starts_with("adaselection")).unwrap();
    let best_single = rows
        .iter()
        .filter(|r| !r.selector.starts_with("adaselection") && r.selector != "benchmark")
        .min_by(|a, b| a.final_test_loss().partial_cmp(&b.final_test_loss()).unwrap())
        .unwrap();
    println!(
        "\nAdaSelection {:.4} vs best single method {} {:.4}",
        ada.final_test_loss(),
        best_single.selector,
        best_single.final_test_loss()
    );
    Ok(())
}

//! Policy playground: feed synthetic per-sample loss streams to the
//! AdaSelection policy (no XLA engine needed) and watch the method weights
//! (eq. 3) adapt as the loss landscape shifts.
//!
//! Three phases are simulated:
//!   1. warmup  — losses shrink uniformly (easy data): stable ℓ^m
//!   2. noise   — a cluster of persistent outliers appears: Big Loss's
//!                hypothetical pick becomes volatile
//!   3. plateau — everything converges
//!
//! Run: cargo run --release --example policy_playground

use adaselection::selection::{AdaConfig, AdaSelection, Arm, Method};
use adaselection::util::rng::Pcg64;

fn main() {
    let mut ada = AdaSelection::new(AdaConfig {
        candidates: vec![
            Arm::Kernel(Method::BigLoss),
            Arm::Kernel(Method::SmallLoss),
            Arm::Kernel(Method::Uniform),
        ],
        beta: 0.5,
        cl_on: true,
        cl_power: -0.5,
        rule: None,
        obftf_k: 10,
    });
    let mut rng = Pcg64::new(7);
    let b = 128;
    let k = 26;

    println!(
        "{:>5} {:>9} {:>10} {:>10} {:>9}  phase",
        "iter", "w_big", "w_small", "w_uniform", "sel_loss"
    );
    for t in 0..150usize {
        let phase = match t {
            0..=49 => "warmup",
            50..=99 => "noise",
            _ => "plateau",
        };
        let base = match phase {
            "warmup" => 2.0 * (-0.02 * t as f32).exp(),
            "noise" => 0.8,
            _ => 0.3,
        };
        let loss: Vec<f32> = (0..b)
            .map(|i| {
                let mut l = base * (0.5 + rng.next_f32());
                if phase == "noise" && i % 10 == 0 {
                    // persistent mislabeled cluster: large, erratic losses
                    l += 4.0 + 3.0 * rng.next_f32();
                }
                l
            })
            .collect();
        let gnorm: Vec<f32> = loss.iter().map(|&l| l * (0.8 + 0.4 * rng.next_f32())).collect();

        let out = ada.step_host(&loss, &gnorm, k);
        if t % 10 == 0 {
            let sel_loss: f32 =
                out.selected.iter().map(|&i| loss[i]).sum::<f32>() / k as f32;
            let w = ada.weights();
            println!(
                "{t:>5} {:>9.4} {:>10.4} {:>10.4} {sel_loss:>9.3}  {phase}",
                w[0], w[1], w[2]
            );
        }
    }
    println!("\nfinal weights: {:?}", ada.weights());
}

//! Quickstart: train a small MLP on the paper's y = 2x + 1 regression task
//! with AdaSelection at a 20% sampling rate, in ~10 lines of API.
//!
//!   cargo run --release --example quickstart   (pure Rust, no artifacts)

use adaselection::config::RunConfig;
use adaselection::train;
use adaselection::util::logging;

fn main() -> anyhow::Result<()> {
    logging::init();

    let mut cfg = RunConfig::default();
    cfg.dataset = "simple".into(); // y = 2x + 1 (+ outliers in train)
    cfg.selector = "adaselection:big_loss+small_loss+uniform".into();
    cfg.gamma = 0.2; // train on the top-scored 20% of each batch
    cfg.epochs = 5;
    cfg.lr = 0.05;
    cfg.data_scale = 0.1;

    let result = train::run(cfg)?;

    println!("\nAdaSelection quickstart — simple regression, γ = 0.2");
    println!("{:<8} {:>12} {:>12}", "epoch", "train_loss", "test_loss");
    for e in &result.epochs {
        println!("{:<8} {:>12.4} {:>12.4}", e.epoch, e.train_loss, e.test_loss);
    }
    println!(
        "\nfinal method weights {:?} -> {:?}",
        result.weight_names,
        result
            .weight_trace
            .last()
            .map(|w| w.iter().map(|x| format!("{x:.3}")).collect::<Vec<_>>())
    );
    println!("phases: {}", result.phases.summary());
    Ok(())
}

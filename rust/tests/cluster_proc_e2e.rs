//! Multi-process cluster workers end to end: a `--workers processes` run
//! must be bit-identical to the equivalent in-process `--transport tcp`
//! run (digests, rolling metrics, remap accounting), and a worker
//! SIGKILLed mid-run must be converted into kill-churn — bounded remap,
//! survivor backfill, full arrival coverage — instead of sinking the job.
//!
//! Worker processes are spawned from the real `adaselection` binary
//! (`CARGO_BIN_EXE_adaselection`): this test binary has no `worker`
//! subcommand.

use std::path::Path;

use adaselection::cluster::{self, proc};
use adaselection::config::ClusterConfig;
use adaselection::stream::{build_source, StreamKnobs};

fn worker_exe() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_adaselection"))
}

fn base_cfg(nodes: usize, ticks: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = nodes;
    cfg.vnodes = 128;
    cfg.gossip_every = 8;
    cfg.merge_every = 4;
    cfg.stream.dataset = "drift-class".into();
    cfg.stream.selector = "adaselection".into();
    cfg.stream.gamma = 0.5;
    cfg.stream.seed = 7;
    cfg.stream.max_ticks = ticks;
    cfg.stream.window = 60;
    cfg.stream.eval_every = 1;
    cfg.stream.workers = 1;
    cfg.stream.drift_period = 120;
    cfg
}

fn total_arrivals(cfg: &ClusterConfig) -> u64 {
    let source = build_source(
        &cfg.stream.dataset,
        StreamKnobs {
            seed: cfg.stream.seed,
            drift_period: cfg.stream.drift_period,
            burst_period: cfg.stream.burst_period,
            burst_min: cfg.stream.burst_min,
        },
    )
    .unwrap();
    (0..cfg.stream.max_ticks as u64)
        .map(|t| source.gen_chunk(t, 128).ids.len() as u64)
        .sum()
}

#[test]
fn process_workers_are_bit_identical_to_in_process_tcp() {
    // the acceptance bar: same seed, same barrier schedule, scheduled
    // kill + join churn, delta gossip with its periodic full fallback,
    // replay steering training through the gossiped stores — once through
    // in-process tcp nodes, once through 4 real worker processes
    let ticks = 140;
    let mk = || {
        let mut cfg = base_cfg(4, ticks);
        cfg.gossip = "delta".into();
        cfg.stream.replay = true;
        cfg.kill_at = 50;
        cfg.kill_node = 1;
        cfg.join_at = 90;
        cfg
    };
    let mut thread_cfg = mk();
    thread_cfg.transport = "tcp".into();
    let threads = cluster::run(&thread_cfg).unwrap();

    let procs = proc::run_with_exe(&mk(), worker_exe()).unwrap();

    assert_eq!(
        procs.digest, threads.digest,
        "process workers diverged from the in-process run"
    );
    assert_eq!(procs.samples_seen, threads.samples_seen);
    assert_eq!(procs.samples_trained, threads.samples_trained);
    assert_eq!(procs.samples_replayed, threads.samples_replayed);
    assert_eq!(procs.drift_detections, threads.drift_detections);
    assert_eq!(procs.remaps, threads.remaps, "remap accounting diverged");
    assert_eq!(procs.gossip_rounds, threads.gossip_rounds);
    assert_eq!(procs.merges, threads.merges);
    assert_eq!(
        procs.gossip_bytes, threads.gossip_bytes,
        "relayed gossip must ship the same frames the mesh ships"
    );
    assert_eq!(
        procs.final_rolling_loss.to_bits(),
        threads.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(procs.rolling.len(), threads.rolling.len());
    for (a, b) in procs.rolling.iter().zip(threads.rolling.iter()) {
        assert_eq!(a.tick, b.tick);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.acc.to_bits(), b.acc.to_bits());
    }
    // per-node accounting lines up too (4 starters + 1 joiner)
    assert_eq!(procs.node_summaries.len(), threads.node_summaries.len());
    for (a, b) in procs.node_summaries.iter().zip(threads.node_summaries.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.ticks_processed, b.ticks_processed, "node {}", a.id);
        assert_eq!(a.samples_seen, b.samples_seen, "node {}", a.id);
        assert_eq!(a.samples_trained, b.samples_trained, "node {}", a.id);
        assert_eq!(a.alive_at_end, b.alive_at_end, "node {}", a.id);
    }
}

#[test]
fn sigkilled_worker_becomes_kill_churn_with_full_coverage() {
    // no scheduled churn at all: the only membership change is the
    // coordinator SIGKILLing worker 2 mid-segment; the run must convert
    // it to churn (bounded remap), backfill the lost segment share, and
    // finish with exact arrival coverage
    let mut cfg = base_cfg(4, 160);
    cfg.worker_mode = "processes".into();
    cfg.chaos_kill_at = 60;
    cfg.chaos_kill_node = 2;
    let r = proc::run_with_exe(&cfg, worker_exe()).unwrap();

    assert!(r.final_rolling_loss.is_finite(), "training halted");
    assert_eq!(
        r.samples_seen,
        total_arrivals(&cfg),
        "crash conversion dropped or duplicated arrivals"
    );
    assert_eq!(r.remaps.len(), 1, "expected exactly the crash churn event");
    let (tick, frac) = r.remaps[0];
    assert!(tick < 160, "churn epoch {tick} outside the run");
    assert!(
        frac > 0.05 && frac < 0.6,
        "crash remapped an unbounded key fraction: {frac}"
    );

    assert_eq!(r.node_summaries.len(), 4);
    let victim = r.node_summaries.iter().find(|n| n.id == 2).unwrap();
    assert!(!victim.alive_at_end, "victim reported alive");
    assert!(
        victim.ticks_processed < 160,
        "victim 'processed' the whole run after dying"
    );
    for n in r.node_summaries.iter().filter(|n| n.id != 2) {
        assert!(n.alive_at_end, "survivor {} died", n.id);
        assert_eq!(n.ticks_processed, 160, "survivor {} stalled", n.id);
    }
    assert!(r.samples_trained > 0);
}

#[test]
fn traced_process_run_is_digest_neutral_and_analyzable() {
    // round-scoped tracing, fleet health rules, the flight ring and the
    // continuous kernel profiler must not perturb training (the
    // zero-interference contract), and the journals the run writes
    // (coordinator + one per worker process) must analyze into a
    // byte-stable report with spans for every barrier round
    let ticks = 100;
    let plain = proc::run_with_exe(&base_cfg(4, ticks), worker_exe()).unwrap();

    let dir = std::env::temp_dir().join(format!("ada_proc_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");
    let mut cfg = base_cfg(4, ticks);
    cfg.stream.trace = Some(trace.clone());
    cfg.stream.health = "warn".into();
    let traced = proc::run_with_exe(&cfg, worker_exe()).unwrap();

    assert_eq!(plain.digest, traced.digest, "tracing changed the cluster digest");
    assert_eq!(plain.samples_seen, traced.samples_seen);
    assert_eq!(plain.samples_trained, traced.samples_trained);
    assert_eq!(
        plain.final_rolling_loss.to_bits(),
        traced.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical under tracing"
    );

    let mut paths = vec![trace.clone()];
    for i in 0..4 {
        let p = dir.join(format!("trace.jsonl.node{i}"));
        assert!(p.exists(), "missing worker journal {}", p.display());
        paths.push(p);
    }
    let report = adaselection::obs::analyze::analyze_files(&paths).unwrap();
    let again = adaselection::obs::analyze::analyze_files(&paths).unwrap();
    assert_eq!(report.to_string(), again.to_string(), "report not byte-identical");

    // every barrier round carries a span with per-node ready lags, and
    // the straggler table is populated from them
    let rounds = report.at(&["barriers", "rounds"]).unwrap().as_usize().unwrap();
    assert!(rounds > 0, "no barrier rounds in the report");
    let per_round = report.at(&["barriers", "per_round"]).unwrap().as_arr().unwrap();
    assert_eq!(per_round.len(), rounds);
    for r in per_round {
        assert!(r.at(&["duration"]).is_ok(), "round without a barrier span");
        let ready = r.at(&["ready"]).unwrap().as_arr().unwrap();
        assert!(!ready.is_empty(), "round without per-node ready lags");
    }
    let stragglers = report.at(&["barriers", "stragglers"]).unwrap().as_arr().unwrap();
    assert!(!stragglers.is_empty(), "empty straggler table");

    // per-arm attribution covers every arm the bandit posted weights for
    let arms = report.at(&["arms", "totals"]).unwrap().as_obj().unwrap();
    let node0 = std::fs::read_to_string(dir.join("trace.jsonl.node0")).unwrap();
    let first = adaselection::util::json::Json::parse(node0.lines().next().unwrap()).unwrap();
    let posted = first.at(&["weights"]).unwrap().as_obj().unwrap();
    assert!(!posted.is_empty(), "adaselection run posted no arm weights");
    for arm in posted.keys() {
        assert!(arms.contains_key(arm), "arm {arm} missing from attribution");
    }

    // wire traffic is attributed (gossip every 8 + merge every 4 ticks)
    let gossip = report
        .at(&["bandwidth", "gossip_bytes_total"])
        .unwrap()
        .as_usize()
        .unwrap();
    let merge =
        report.at(&["bandwidth", "merge_bytes_total"]).unwrap().as_usize().unwrap();
    assert!(gossip > 0, "no gossip bytes attributed");
    assert!(merge > 0, "no merge bytes attributed");

    // the continuous profiler rides the worker tick lines: the merged
    // report rebuilds per-kernel quantiles from the `kernel:` phases
    let kernels = report.at(&["kernels"]).unwrap().as_obj().unwrap();
    assert!(
        kernels.contains_key("sgd_step"),
        "no sgd_step kernel quantiles in the report: {:?}",
        kernels.keys().collect::<Vec<_>>()
    );
    for (k, row) in kernels {
        let p50 = row.at(&["p50_seconds"]).unwrap().as_f64().unwrap();
        let p99 = row.at(&["p99_seconds"]).unwrap().as_f64().unwrap();
        let n = row.at(&["ticks"]).unwrap().as_usize().unwrap();
        assert!(n > 0, "{k}: quantiles over zero ticks");
        assert!(p50 <= p99, "{k}: p50 {p50} > p99 {p99}");
    }
    // the health alert timeline is part of the report (a healthy local
    // run normally keeps it empty, but scheduler noise may fire a
    // transient straggler — presence, not emptiness, is the contract)
    report.at(&["alerts", "events"]).unwrap().as_arr().unwrap();

    for p in &paths {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn chaos_kill_dumps_a_validating_flight_journal() {
    // the crash flight recorder: a SIGKILLed worker cannot dump anything
    // itself, so the coordinator's always-on flight ring must land on
    // disk when the crash is converted to churn — and the dump's last
    // rounds must pin the victim's final completed BarrierReady. The
    // ring and its dump path are process-global, so the coordinator runs
    // as its own CLI process (parallel tests in this binary would race
    // on them otherwise).
    use adaselection::obs::trace::validate_line;

    let dir = std::env::temp_dir().join(format!("ada_proc_flightdump_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");

    // barriers every 4 ticks; chaos at 20 kills the victim inside the
    // (20, 24] segment, so its last completed barrier is tick 20 and the
    // crash is detected collecting the tick-24 barrier
    let out = std::process::Command::new(worker_exe())
        .args([
            "cluster",
            "--workers",
            "processes",
            "--nodes",
            "3",
            "--max-ticks",
            "40",
            "--gossip-every",
            "8",
            "--merge-every",
            "4",
            "--window",
            "20",
            "--eval-every",
            "1",
            "--chaos-kill-at",
            "20",
            "--chaos-kill-node",
            "1",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");

    let flight = dir.join("trace.jsonl.flight.jsonl");
    assert!(flight.exists(), "no flight dump at {}", flight.display());
    let text = std::fs::read_to_string(&flight).unwrap();
    assert!(!text.is_empty(), "empty flight dump");

    // every ring line is a schema-valid journal event, and the victim's
    // last ready_lag span is its final completed barrier — tick 20 —
    // while the dump itself reaches the crash-detection barrier at 24
    let mut victim_last = 0u64;
    let mut survivor_last = 0u64;
    let mut max_tick = 0u64;
    for (i, line) in text.lines().enumerate() {
        let ev = validate_line(line)
            .unwrap_or_else(|e| panic!("bad flight line {i}: {e}\n{line}"));
        max_tick = max_tick.max(ev.tick);
        if ev.name.as_deref() == Some("ready_lag") {
            match ev.node {
                Some(1) => victim_last = victim_last.max(ev.tick),
                Some(_) => survivor_last = survivor_last.max(ev.tick),
                None => panic!("ready_lag span without a node: {line}"),
            }
        }
    }
    assert_eq!(
        victim_last, 20,
        "victim's last ready_lag must be its final completed barrier"
    );
    assert_eq!(survivor_last, 24, "survivors must reach the crash barrier in the dump");
    assert_eq!(max_tick, 24, "dump must stop at the crash-conversion round");

    std::fs::remove_file(&flight).ok();
    std::fs::remove_file(&trace).ok();
    for i in 0..3 {
        std::fs::remove_file(dir.join(format!("trace.jsonl.node{i}"))).ok();
    }
}

#[test]
fn straggler_alert_fires_before_shed_and_resolves() {
    // the health-rule e2e: a synthetic straggler (worker 1 sleeps 900 ms
    // at every barrier segment) must make exactly `straggler_ready_lag`
    // fire, the watermark shed must then evict that same worker, and the
    // alert must resolve once the victim's alive gauge drops. Runs as a
    // CLI subprocess: the health engine journals through process-global
    // obs state shared with other tests in this binary.
    use adaselection::obs::trace::validate_line;

    let dir = std::env::temp_dir().join(format!("ada_proc_straggler_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");

    let out = std::process::Command::new(worker_exe())
        .args([
            "cluster",
            "--workers",
            "processes",
            "--nodes",
            "3",
            "--max-ticks",
            "40",
            "--gossip-every",
            "8",
            "--merge-every",
            "4",
            "--window",
            "20",
            "--eval-every",
            "1",
            "--chaos-straggler-ms",
            "900",
            "--chaos-straggler-node",
            "1",
            "--elastic-shed-below",
            "1000000000000",
            "--elastic-min-nodes",
            "2",
            "--health",
            "warn",
            "--trace",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    // warn mode never fails the run, even though the alert fired
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");

    let text = std::fs::read_to_string(&trace).unwrap();
    let mut alerts: Vec<(String, u64)> = Vec::new(); // (state, tick), in journal order
    let mut victim_last_lag = 0u64;
    let mut fleet_last_lag = 0u64;
    for (i, line) in text.lines().enumerate() {
        let ev = validate_line(line)
            .unwrap_or_else(|e| panic!("bad journal line {i}: {e}\n{line}"));
        if let Some((rule, state)) = &ev.alert {
            // the injected straggler is the only unhealthy signal in
            // this run: no other rule may fire
            assert_eq!(rule, "straggler_ready_lag", "unexpected alert: {line}");
            assert_eq!(ev.node, Some(1), "alert blamed the wrong node: {line}");
            alerts.push((state.clone(), ev.tick));
        }
        if ev.name.as_deref() == Some("ready_lag") {
            match ev.node {
                Some(1) => victim_last_lag = victim_last_lag.max(ev.tick),
                _ => fleet_last_lag = fleet_last_lag.max(ev.tick),
            }
        }
    }

    let firing_at = alerts
        .iter()
        .find(|(s, _)| s == "firing")
        .unwrap_or_else(|| panic!("no firing straggler alert in the journal: {alerts:?}"))
        .1;
    // the shed happened mid-run: the victim's ready_lag spans stop while
    // the survivors' keep going to the final barrier
    assert!(
        victim_last_lag > 0 && victim_last_lag < 40,
        "no shed observed (victim's last barrier: {victim_last_lag})"
    );
    assert_eq!(fleet_last_lag, 40, "survivors stalled");
    // the alert preceded the shed (health evaluates before the elastic
    // step at every barrier, so at latest they share the shed barrier)
    assert!(
        firing_at <= victim_last_lag,
        "alert fired at tick {firing_at}, after the shed at {victim_last_lag}"
    );
    // and it resolved once the victim left the alive set
    let resolved_at = alerts
        .iter()
        .skip_while(|(s, _)| s != "firing")
        .find(|(s, _)| s == "resolved")
        .unwrap_or_else(|| panic!("straggler alert never resolved after the shed: {alerts:?}"))
        .1;
    assert!(
        resolved_at > victim_last_lag,
        "alert resolved at tick {resolved_at}, before the shed at {victim_last_lag}"
    );

    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(dir.join("trace.jsonl.flight.jsonl")).ok();
    for i in 0..3 {
        std::fs::remove_file(dir.join(format!("trace.jsonl.node{i}"))).ok();
    }
}

#[test]
fn registered_workers_match_spawned_baseline() {
    // the registration pin: a coordinator that spawns nothing
    // (`--spawn off`) and waits on `--listen` for externally launched
    // workers must produce the exact run the self-spawning coordinator
    // produces at equal membership. The external workers are started
    // BEFORE the coordinator binds its port — they sit in the jittered
    // connect-retry loop until it comes up — and a silent socket that
    // never sends its Hello leans on the listener for the whole
    // registration window (slow-loris: the per-connection handshake
    // budget keeps it off the accept path).
    let ticks = 120;
    let mk = || {
        let mut cfg = base_cfg(2, ticks);
        cfg.worker_mode = "processes".into();
        cfg.gossip = "delta".into();
        cfg.stream.replay = true;
        cfg
    };
    let baseline = proc::run_with_exe(&mk(), worker_exe()).unwrap();

    // pre-pick a free port so the workers can dial it before the
    // coordinator exists (the probe listener is dropped immediately)
    let addr = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };

    // the external fleet: no --node-id — the coordinator assigns ids in
    // registration order
    let mut externals: Vec<std::process::Child> = (0..2)
        .map(|_| {
            std::process::Command::new(worker_exe())
                .args(["worker", "--coordinator", &addr])
                .stdin(std::process::Stdio::null())
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::inherit())
                .spawn()
                .unwrap()
        })
        .collect();

    // slow-loris: connects as soon as the port opens, then says nothing
    // for longer than the handshake budget
    let loris_addr = addr.clone();
    let loris = std::thread::spawn(move || {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        while std::time::Instant::now() < deadline {
            if let Ok(s) = std::net::TcpStream::connect(&loris_addr) {
                std::thread::sleep(std::time::Duration::from_secs(5));
                drop(s);
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
    });

    // let the workers burn a few failed dial attempts first
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut cfg = mk();
    cfg.listen = Some(addr);
    cfg.spawn = false;
    let registered = proc::run_with_exe(&cfg, worker_exe()).unwrap();

    assert_eq!(
        registered.digest, baseline.digest,
        "registered fleet diverged from the spawned baseline"
    );
    assert_eq!(registered.samples_seen, baseline.samples_seen);
    assert_eq!(registered.samples_trained, baseline.samples_trained);
    assert_eq!(registered.samples_replayed, baseline.samples_replayed);
    assert_eq!(registered.gossip_rounds, baseline.gossip_rounds);
    assert_eq!(registered.gossip_bytes, baseline.gossip_bytes);
    assert_eq!(registered.merges, baseline.merges);
    assert_eq!(
        registered.final_rolling_loss.to_bits(),
        baseline.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(registered.node_summaries.len(), baseline.node_summaries.len());
    for (a, b) in registered.node_summaries.iter().zip(baseline.node_summaries.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.ticks_processed, b.ticks_processed, "node {}", a.id);
        assert_eq!(a.samples_seen, b.samples_seen, "node {}", a.id);
        assert_eq!(a.samples_trained, b.samples_trained, "node {}", a.id);
    }

    // the coordinator's protocol Shutdown lets both externals exit clean
    for c in externals.iter_mut() {
        let st = c.wait().unwrap();
        assert!(st.success(), "external worker exited with {st}");
    }
    loris.join().unwrap();
}

#[test]
fn arrival_watermark_sheds_straggler_with_exact_coverage() {
    // elastic scale-in pin: no scheduled churn, no chaos kill — an
    // arrival-rate watermark the stream can never meet makes the
    // coordinator voluntarily shed the worst straggler. The leave is
    // clean: the victim finished its barrier, so the ring epoch and the
    // backfill horizon coincide and survivors re-process nothing —
    // coverage stays exact. The min-nodes floor then holds even though
    // the rate stays below the watermark for the rest of the run.
    let mut cfg = base_cfg(3, 160);
    cfg.worker_mode = "processes".into();
    cfg.elastic_shed_below = 1e12; // any real rate is "too low"
    cfg.elastic_min_nodes = 2;
    let r = proc::run_with_exe(&cfg, worker_exe()).unwrap();

    assert!(r.final_rolling_loss.is_finite(), "training halted");
    assert_eq!(
        r.samples_seen,
        total_arrivals(&cfg),
        "elastic shed dropped or duplicated arrivals"
    );
    assert_eq!(r.remaps.len(), 1, "expected exactly one voluntary shed");
    let (tick, frac) = r.remaps[0];
    assert!(tick > 0 && tick < 160, "shed epoch {tick} outside the run");
    assert!(
        frac > 0.05 && frac < 0.7,
        "shed remapped an unbounded key fraction: {frac}"
    );

    assert_eq!(r.node_summaries.len(), 3);
    let shed: Vec<_> = r.node_summaries.iter().filter(|n| !n.alive_at_end).collect();
    assert_eq!(shed.len(), 1, "expected exactly one shed worker");
    assert!(
        shed[0].ticks_processed < 160,
        "shed worker 'processed' the whole run after leaving"
    );
    for n in r.node_summaries.iter().filter(|n| n.alive_at_end) {
        assert_eq!(n.ticks_processed, 160, "survivor {} stalled", n.id);
    }
    assert!(r.samples_trained > 0);
}

#[test]
fn binary_runs_process_workers_end_to_end() {
    // the CLI path: the coordinator spawns workers from its *own*
    // executable (std::env::current_exe), so drive the real binary
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out = std::process::Command::new(bin)
        .args([
            "cluster",
            "--workers",
            "processes",
            "--nodes",
            "2",
            "--max-ticks",
            "30",
            "--gossip-every",
            "8",
            "--merge-every",
            "8",
            "--window",
            "10",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("cluster result"), "{stdout}");
    assert!(stdout.contains("(processes)"), "{stdout}");

    // a worker invoked without a coordinator address fails cleanly
    let out = std::process::Command::new(bin).args(["worker"]).output().unwrap();
    assert!(!out.status.success());
}

//! Multi-process cluster workers end to end: a `--workers processes` run
//! must be bit-identical to the equivalent in-process `--transport tcp`
//! run (digests, rolling metrics, remap accounting), and a worker
//! SIGKILLed mid-run must be converted into kill-churn — bounded remap,
//! survivor backfill, full arrival coverage — instead of sinking the job.
//!
//! Worker processes are spawned from the real `adaselection` binary
//! (`CARGO_BIN_EXE_adaselection`): this test binary has no `worker`
//! subcommand.

use std::path::Path;

use adaselection::cluster::{self, proc};
use adaselection::config::ClusterConfig;
use adaselection::stream::{build_source, StreamKnobs};

fn worker_exe() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_adaselection"))
}

fn base_cfg(nodes: usize, ticks: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = nodes;
    cfg.vnodes = 128;
    cfg.gossip_every = 8;
    cfg.merge_every = 4;
    cfg.stream.dataset = "drift-class".into();
    cfg.stream.selector = "adaselection".into();
    cfg.stream.gamma = 0.5;
    cfg.stream.seed = 7;
    cfg.stream.max_ticks = ticks;
    cfg.stream.window = 60;
    cfg.stream.eval_every = 1;
    cfg.stream.workers = 1;
    cfg.stream.drift_period = 120;
    cfg
}

fn total_arrivals(cfg: &ClusterConfig) -> u64 {
    let source = build_source(
        &cfg.stream.dataset,
        StreamKnobs {
            seed: cfg.stream.seed,
            drift_period: cfg.stream.drift_period,
            burst_period: cfg.stream.burst_period,
            burst_min: cfg.stream.burst_min,
        },
    )
    .unwrap();
    (0..cfg.stream.max_ticks as u64)
        .map(|t| source.gen_chunk(t, 128).ids.len() as u64)
        .sum()
}

#[test]
fn process_workers_are_bit_identical_to_in_process_tcp() {
    // the acceptance bar: same seed, same barrier schedule, scheduled
    // kill + join churn, delta gossip with its periodic full fallback,
    // replay steering training through the gossiped stores — once through
    // in-process tcp nodes, once through 4 real worker processes
    let ticks = 140;
    let mk = || {
        let mut cfg = base_cfg(4, ticks);
        cfg.gossip = "delta".into();
        cfg.stream.replay = true;
        cfg.kill_at = 50;
        cfg.kill_node = 1;
        cfg.join_at = 90;
        cfg
    };
    let mut thread_cfg = mk();
    thread_cfg.transport = "tcp".into();
    let threads = cluster::run(&thread_cfg).unwrap();

    let procs = proc::run_with_exe(&mk(), worker_exe()).unwrap();

    assert_eq!(
        procs.digest, threads.digest,
        "process workers diverged from the in-process run"
    );
    assert_eq!(procs.samples_seen, threads.samples_seen);
    assert_eq!(procs.samples_trained, threads.samples_trained);
    assert_eq!(procs.samples_replayed, threads.samples_replayed);
    assert_eq!(procs.drift_detections, threads.drift_detections);
    assert_eq!(procs.remaps, threads.remaps, "remap accounting diverged");
    assert_eq!(procs.gossip_rounds, threads.gossip_rounds);
    assert_eq!(procs.merges, threads.merges);
    assert_eq!(
        procs.gossip_bytes, threads.gossip_bytes,
        "relayed gossip must ship the same frames the mesh ships"
    );
    assert_eq!(
        procs.final_rolling_loss.to_bits(),
        threads.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(procs.rolling.len(), threads.rolling.len());
    for (a, b) in procs.rolling.iter().zip(threads.rolling.iter()) {
        assert_eq!(a.tick, b.tick);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.acc.to_bits(), b.acc.to_bits());
    }
    // per-node accounting lines up too (4 starters + 1 joiner)
    assert_eq!(procs.node_summaries.len(), threads.node_summaries.len());
    for (a, b) in procs.node_summaries.iter().zip(threads.node_summaries.iter()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.ticks_processed, b.ticks_processed, "node {}", a.id);
        assert_eq!(a.samples_seen, b.samples_seen, "node {}", a.id);
        assert_eq!(a.samples_trained, b.samples_trained, "node {}", a.id);
        assert_eq!(a.alive_at_end, b.alive_at_end, "node {}", a.id);
    }
}

#[test]
fn sigkilled_worker_becomes_kill_churn_with_full_coverage() {
    // no scheduled churn at all: the only membership change is the
    // coordinator SIGKILLing worker 2 mid-segment; the run must convert
    // it to churn (bounded remap), backfill the lost segment share, and
    // finish with exact arrival coverage
    let mut cfg = base_cfg(4, 160);
    cfg.worker_mode = "processes".into();
    cfg.chaos_kill_at = 60;
    cfg.chaos_kill_node = 2;
    let r = proc::run_with_exe(&cfg, worker_exe()).unwrap();

    assert!(r.final_rolling_loss.is_finite(), "training halted");
    assert_eq!(
        r.samples_seen,
        total_arrivals(&cfg),
        "crash conversion dropped or duplicated arrivals"
    );
    assert_eq!(r.remaps.len(), 1, "expected exactly the crash churn event");
    let (tick, frac) = r.remaps[0];
    assert!(tick < 160, "churn epoch {tick} outside the run");
    assert!(
        frac > 0.05 && frac < 0.6,
        "crash remapped an unbounded key fraction: {frac}"
    );

    assert_eq!(r.node_summaries.len(), 4);
    let victim = r.node_summaries.iter().find(|n| n.id == 2).unwrap();
    assert!(!victim.alive_at_end, "victim reported alive");
    assert!(
        victim.ticks_processed < 160,
        "victim 'processed' the whole run after dying"
    );
    for n in r.node_summaries.iter().filter(|n| n.id != 2) {
        assert!(n.alive_at_end, "survivor {} died", n.id);
        assert_eq!(n.ticks_processed, 160, "survivor {} stalled", n.id);
    }
    assert!(r.samples_trained > 0);
}

#[test]
fn binary_runs_process_workers_end_to_end() {
    // the CLI path: the coordinator spawns workers from its *own*
    // executable (std::env::current_exe), so drive the real binary
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out = std::process::Command::new(bin)
        .args([
            "cluster",
            "--workers",
            "processes",
            "--nodes",
            "2",
            "--max-ticks",
            "30",
            "--gossip-every",
            "8",
            "--merge-every",
            "8",
            "--window",
            "10",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("cluster result"), "{stdout}");
    assert!(stdout.contains("(processes)"), "{stdout}");

    // a worker invoked without a coordinator address fails cleanly
    let out = std::process::Command::new(bin).args(["worker"]).output().unwrap();
    assert!(!out.status.success());
}

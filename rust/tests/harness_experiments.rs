//! Integration: the experiment harness regenerates figure/table files with
//! the right schema. Runs on the native backend (quick mode) — no
//! artifacts, no XLA — so the full sweep → CSV → aggregate path is
//! exercised in CI on bare runners.

use std::path::PathBuf;

use adaselection::harness::{registry, run_experiment, run_experiment_with, SweepOptions};
use adaselection::runtime::NativeBackend;

fn opts(tag: &str) -> SweepOptions {
    SweepOptions {
        out_dir: std::env::temp_dir().join(format!("ada_harness_test_{tag}")),
        quick: true,
        ..SweepOptions::default()
    }
}

fn read_csv(path: &PathBuf) -> Vec<Vec<String>> {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    text.lines()
        .map(|l| l.split(',').map(String::from).collect())
        .collect()
}

#[test]
fn fig5_emits_metric_and_time_series() {
    let mut backend = NativeBackend::new();
    let o = opts("fig5");
    run_experiment_with(&mut backend, "fig5", &o).unwrap();

    let metric = read_csv(&o.out_dir.join("fig5_simple_metric.csv"));
    assert_eq!(metric[0][0], "gamma");
    // 8 baselines + 1 quick-mode ada variant + gamma column
    assert_eq!(metric[0].len(), 10);
    assert!(metric.len() >= 3); // header + 2 quick gammas

    let runs = read_csv(&o.out_dir.join("fig5_simple_runs.csv"));
    assert_eq!(runs[0][0], "dataset");
    assert!(runs.len() > 9, "expected ≥9 runs, got {}", runs.len() - 1);

    let agg = read_csv(&o.out_dir.join("aggregate_simple.csv"));
    assert_eq!(agg[0], vec!["dataset", "selector", "avg_rank", "avg_metric", "metric"]);
    // 9 selectors + the collapsed adaselection(best=…) row + header
    assert_eq!(agg.len(), 11);
    assert!(agg.iter().any(|r| r[1].starts_with("adaselection(best=")));
}

#[test]
fn fig8_emits_weight_traces_with_candidate_columns() {
    let mut backend = NativeBackend::new();
    let o = opts("fig8");
    run_experiment_with(&mut backend, "fig8", &o).unwrap();
    let w = read_csv(&o.out_dir.join("fig8_weights_simple.csv"));
    assert_eq!(w[0], vec!["iteration", "big_loss", "small_loss", "uniform"]);
    assert!(w.len() > 1, "no weight rows");
    // weights stay positive
    for row in &w[1..] {
        for cell in &row[1..] {
            assert!(cell.parse::<f32>().unwrap() > 0.0);
        }
    }
}

#[test]
fn fig7_emits_beta_grid() {
    let mut backend = NativeBackend::new();
    let o = opts("fig7");
    run_experiment_with(&mut backend, "fig7", &o).unwrap();
    let t = read_csv(&o.out_dir.join("fig7_beta_ablation.csv"));
    assert_eq!(t[0], vec!["dataset", "beta", "test_acc"]);
    let betas: Vec<&str> = t[1..].iter().map(|r| r[1].as_str()).collect();
    for b in ["-1.0", "-0.5", "0.0", "0.5", "1.0"] {
        assert!(betas.contains(&b), "β={b} missing");
    }
}

#[test]
fn fig6_bike_regression_sweep_aggregates() {
    let mut backend = NativeBackend::new();
    let o = opts("fig6");
    run_experiment_with(&mut backend, "fig6", &o).unwrap();
    let agg = read_csv(&o.out_dir.join("aggregate_bike.csv"));
    assert_eq!(agg[0], vec!["dataset", "selector", "avg_rank", "avg_metric", "metric"]);
    // regression aggregates report loss, lower-is-better
    assert!(agg[1..].iter().all(|r| r[4] == "loss"));
}

#[test]
fn cluster_cmp_emits_scaling_summary() {
    let mut backend = NativeBackend::new();
    let o = opts("cluster_cmp");
    run_experiment_with(&mut backend, "cluster-cmp", &o).unwrap();
    let t = read_csv(&o.out_dir.join("cluster_cmp_summary.csv"));
    assert_eq!(t[0][0], "nodes");
    assert_eq!(t.len(), 3, "quick mode runs 1 and 2 nodes");
    assert_eq!(t[1][0], "1");
    assert_eq!(t[2][0], "2");
    // loss delta vs the single node is reported as a signed percentage
    assert!(t[2][2].starts_with('+') || t[2][2].starts_with('-'));
    // bandwidth is reported alongside throughput
    let gb = t[0].iter().position(|c| c == "gossip_bytes").expect("gossip_bytes column");
    assert!(t[2][gb].parse::<u64>().unwrap() > 0, "2-node job gossiped no bytes");
    assert!(o.out_dir.join("cluster_cmp_trace.csv").exists());
}

#[test]
fn registry_ids_all_resolve() {
    // only validate dispatch: unknown id errors, known ids exist in match
    let o = SweepOptions::default();
    assert!(run_experiment("nope", &o).is_err());
    assert_eq!(registry().len(), 18);
}

#[test]
fn run_experiment_builds_named_backend() {
    // dispatch through the string-named backend constructor end to end
    let o = opts("dispatch");
    run_experiment("fig5", &o).unwrap();
    assert!(o.out_dir.join("fig5_simple_metric.csv").exists());
    let mut bad = opts("dispatch_bad");
    bad.backend = "tpu9000".into();
    assert!(run_experiment("fig5", &bad).is_err());
}

//! Cluster subsystem end to end (native backend): equal-budget loss parity
//! with the single node, deterministic re-runs, and kill/join churn that
//! rebalances without halting training.

use adaselection::cluster::{self, ClusterResult};
use adaselection::config::ClusterConfig;
use adaselection::stream::{build_source, StreamKnobs};

fn base_cfg(nodes: usize, ticks: usize) -> ClusterConfig {
    let mut cfg = ClusterConfig::default();
    cfg.nodes = nodes;
    cfg.vnodes = 128;
    cfg.gossip_every = 8;
    cfg.merge_every = 4;
    cfg.stream.dataset = "drift-class".into();
    cfg.stream.selector = "adaselection".into();
    cfg.stream.gamma = 0.5;
    cfg.stream.seed = 7;
    cfg.stream.max_ticks = ticks;
    cfg.stream.window = 60;
    cfg.stream.eval_every = 1;
    cfg.stream.workers = 1;
    cfg.stream.drift_period = 120;
    cfg
}

fn total_arrivals(cfg: &ClusterConfig) -> u64 {
    let source = build_source(
        &cfg.stream.dataset,
        StreamKnobs {
            seed: cfg.stream.seed,
            drift_period: cfg.stream.drift_period,
            burst_period: cfg.stream.burst_period,
            burst_min: cfg.stream.burst_min,
        },
    )
    .unwrap();
    (0..cfg.stream.max_ticks as u64)
        .map(|t| source.gen_chunk(t, 128).ids.len() as u64)
        .sum()
}

#[test]
fn four_nodes_match_single_node_loss_at_equal_budget() {
    let ticks = 300;
    let single = cluster::run(&base_cfg(1, ticks)).unwrap();
    let four = cluster::run(&base_cfg(4, ticks)).unwrap();

    // equal total tick budget ⇒ identical traffic seen
    assert_eq!(single.samples_seen, four.samples_seen, "unequal traffic");
    assert!(single.final_rolling_loss.is_finite());
    assert!(four.final_rolling_loss.is_finite());

    // acceptance: the sharded run's rolling prequential loss stays within
    // 5% of the single-node run (plus a tiny absolute guard for the
    // near-zero-loss regime)
    let bound = single.final_rolling_loss * 1.05 + 0.02;
    assert!(
        four.final_rolling_loss <= bound,
        "4-node rolling loss {} vs 1-node {} (bound {bound})",
        four.final_rolling_loss,
        single.final_rolling_loss
    );
    // ...and is not mysteriously better by a huge margin either (that
    // would mean the clusters are not comparable runs at all)
    assert!(
        four.final_rolling_loss >= single.final_rolling_loss * 0.5,
        "4-node loss implausibly low: {} vs {}",
        four.final_rolling_loss,
        single.final_rolling_loss
    );

    // the four shards partition every chunk exactly
    let spread: u64 = four.node_summaries.iter().map(|n| n.samples_seen).sum();
    assert_eq!(spread, four.samples_seen);
    assert_eq!(four.node_summaries.len(), 4);
    for n in &four.node_summaries {
        assert!(n.samples_seen > 0, "node {} starved", n.id);
        assert!(n.alive_at_end);
    }
    assert!(four.merges > 0 && four.gossip_rounds > 0);
}

#[test]
fn cluster_runs_are_deterministic() {
    let mut cfg = base_cfg(2, 60);
    cfg.stream.workers = 2; // threaded loaders must not affect results
    let a = cluster::run(&cfg).unwrap();
    let b = cluster::run(&cfg).unwrap();
    assert_eq!(a.digest, b.digest, "selection sequences diverged");
    assert_eq!(a.samples_seen, b.samples_seen);
    assert_eq!(a.samples_trained, b.samples_trained);
    assert_eq!(
        a.final_rolling_loss.to_bits(),
        b.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(a.rolling.len(), b.rolling.len());
    for (x, y) in a.rolling.iter().zip(b.rolling.iter()) {
        assert_eq!(x.tick, y.tick);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
    }
}

fn assert_covers_traffic(r: &ClusterResult, cfg: &ClusterConfig) {
    assert_eq!(
        r.samples_seen,
        total_arrivals(cfg),
        "churn dropped or duplicated arrivals"
    );
}

#[test]
fn kill_and_join_rebalance_without_halting_training() {
    let mut cfg = base_cfg(4, 160);
    cfg.kill_at = 60;
    cfg.kill_node = 1;
    cfg.join_at = 100;
    let r = cluster::run(&cfg).unwrap();

    assert!(r.final_rolling_loss.is_finite(), "training halted");
    assert_covers_traffic(&r, &cfg);

    // churn accounting: one kill + one join, each remapping a bounded
    // fraction of the key space (≈ 1/N with vnode noise, never a shuffle)
    assert_eq!(r.remaps.len(), 2);
    for &(tick, frac) in &r.remaps {
        assert!(tick == 60 || tick == 100, "unexpected churn tick {tick}");
        assert!(
            frac > 0.05 && frac < 0.6,
            "churn at {tick} remapped an unbounded fraction: {frac}"
        );
    }

    assert_eq!(r.node_summaries.len(), 5, "expected 4 starters + 1 joiner");
    let killed = r.node_summaries.iter().find(|n| n.id == 1).unwrap();
    assert!(!killed.alive_at_end);
    assert_eq!(killed.ticks_processed, 60, "kill must stop at the barrier");
    let joined = r.node_summaries.iter().find(|n| n.id == 4).unwrap();
    assert!(joined.alive_at_end);
    assert_eq!(joined.ticks_processed, 60, "joiner runs ticks 100..160");
    assert!(joined.samples_seen > 0, "joiner never took ownership");
    // the joiner was seeded by gossip: its store holds more ids than its
    // own shard alone produced after the join
    assert!(joined.store_len > 0);

    // survivors kept processing after the kill
    for n in r.node_summaries.iter().filter(|n| n.alive_at_end && n.id != 4) {
        assert_eq!(n.ticks_processed, 160, "survivor {} stalled", n.id);
    }
}

#[test]
fn tcp_delta_gossip_is_bit_identical_to_loopback_full() {
    // the same seed through (a) the in-process loopback with full-snapshot
    // gossip and (b) real 127.0.0.1 sockets with delta gossip, including a
    // kill and a join. Replay is on so store contents actually steer
    // training; the store capacity holds every arrival here, and the
    // eviction-pressure case is pinned by the next test.
    let ticks = 140;
    let mk = |transport: &str, gossip: &str| {
        let mut cfg = base_cfg(4, ticks);
        cfg.transport = transport.into();
        cfg.gossip = gossip.into();
        cfg.stream.replay = true;
        cfg.kill_at = 50;
        cfg.kill_node = 1;
        cfg.join_at = 90;
        cfg
    };
    let full = cluster::run(&mk("loopback", "full")).unwrap();
    let delta = cluster::run(&mk("tcp", "delta")).unwrap();

    assert_eq!(full.digest, delta.digest, "training sequences diverged across modes");
    assert_eq!(full.samples_seen, delta.samples_seen);
    assert_eq!(full.samples_trained, delta.samples_trained);
    assert_eq!(full.samples_replayed, delta.samples_replayed);
    assert_eq!(full.remaps, delta.remaps, "churn remap accounting diverged");
    assert_eq!(
        full.final_rolling_loss.to_bits(),
        delta.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(full.rolling.len(), delta.rolling.len());
    for (a, b) in full.rolling.iter().zip(delta.rolling.iter()) {
        assert_eq!(a.tick, b.tick);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }

    // the point of delta gossip: strictly fewer bytes on the wire at the
    // same training result; merge traffic is mode-independent
    assert!(full.gossip_bytes > 0 && delta.gossip_bytes > 0);
    assert!(
        delta.gossip_bytes < full.gossip_bytes,
        "delta gossip must ship fewer bytes: {} vs {}",
        delta.gossip_bytes,
        full.gossip_bytes
    );
    assert_eq!(full.merge_bytes, delta.merge_bytes);
}

#[test]
fn tcp_delta_matches_loopback_full_under_eviction_pressure() {
    // the eviction case of the parity pin above: stores far smaller than
    // the traffic rotate generations constantly, so deltas computed from
    // since-last-sync marks alone would silently drop evicted-and-
    // re-inserted records. Workers flag evicted-since-sync stores at the
    // barrier and the coordinator escalates those rounds to full
    // snapshots — parity must survive with no capacity caveat.
    let ticks = 140;
    let mk = |transport: &str, gossip: &str| {
        let mut cfg = base_cfg(4, ticks);
        cfg.transport = transport.into();
        cfg.gossip = gossip.into();
        cfg.stream.replay = true;
        cfg.stream.store_capacity = 512;
        cfg.stream.store_shards = 4;
        cfg.kill_at = 50;
        cfg.kill_node = 1;
        cfg.join_at = 90;
        cfg
    };
    let full = cluster::run(&mk("loopback", "full")).unwrap();
    let delta = cluster::run(&mk("tcp", "delta")).unwrap();

    assert_eq!(full.digest, delta.digest, "delta gossip diverged under eviction");
    assert_eq!(full.samples_seen, delta.samples_seen);
    assert_eq!(full.samples_trained, delta.samples_trained);
    assert_eq!(full.samples_replayed, delta.samples_replayed);
    assert_eq!(full.remaps, delta.remaps, "churn remap accounting diverged");
    assert_eq!(
        full.final_rolling_loss.to_bits(),
        delta.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical under eviction"
    );
    assert_eq!(full.rolling.len(), delta.rolling.len());
    for (a, b) in full.rolling.iter().zip(delta.rolling.iter()) {
        assert_eq!(a.tick, b.tick);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    }
    assert_eq!(full.merge_bytes, delta.merge_bytes);
    // escalation may turn every delta round into a full snapshot, but it
    // must never ship *more* than the all-full run
    assert!(
        delta.gossip_bytes > 0 && delta.gossip_bytes <= full.gossip_bytes,
        "escalated delta shipped more than full: {} vs {}",
        delta.gossip_bytes,
        full.gossip_bytes
    );

    // the pressure was real: every store pinned at the cap while the run
    // saw far more arrivals than fit
    for n in &delta.node_summaries {
        assert!(n.store_len <= 512, "node {} store over capacity", n.id);
    }
    assert!(
        delta.node_summaries.iter().any(|n| n.samples_seen > 1024),
        "eviction pressure never materialized"
    );
}

#[test]
fn four_node_per_method_drift_is_bit_deterministic() {
    // acceptance: a 4-node cluster running a mixed kernel + forward-cheap
    // bandit pool with per-method drift detectors is bit-identical across
    // re-runs — detector state and the forward-cheap rng paths are pure
    // functions of config + seed
    let mut cfg = base_cfg(4, 120);
    cfg.stream.selector = "adaselection:big_loss+uniform+obftf+selective-backprop".into();
    cfg.stream.drift_detect = "page-hinkley".into();
    cfg.stream.drift_period = 100;
    let a = cluster::run(&cfg).unwrap();
    let b = cluster::run(&cfg).unwrap();
    assert_eq!(a.digest, b.digest, "per-method drift runs diverged");
    assert_eq!(a.samples_seen, b.samples_seen);
    assert_eq!(a.samples_trained, b.samples_trained);
    assert_eq!(
        a.final_rolling_loss.to_bits(),
        b.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical"
    );
    assert_eq!(a.rolling.len(), b.rolling.len());
    for (x, y) in a.rolling.iter().zip(b.rolling.iter()) {
        assert_eq!(x.tick, y.tick);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
    }
    assert!(a.final_rolling_loss.is_finite());
}

#[test]
fn telemetry_is_off_the_cluster_digest_path_and_metrics_scrape_live() {
    use adaselection::obs::status::{http_get, last_bound_addr};
    use adaselection::obs::trace::validate_line;
    use std::collections::BTreeMap;
    use std::time::{Duration, Instant};

    let ticks = 120;
    let plain = cluster::run(&base_cfg(4, ticks)).unwrap();

    let dir = std::env::temp_dir().join(format!("ada_cluster_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");
    let mut cfg = base_cfg(4, ticks);
    cfg.stream.trace = Some(trace.clone());
    cfg.stream.status_addr = Some("127.0.0.1:0".into());

    // run in a thread so /metrics can be scraped while the cluster is live
    let runner = std::thread::spawn(move || cluster::run(&cfg).unwrap());
    let distinct_series = |body: &str| -> usize {
        body.lines()
            .filter(|l| l.starts_with("adaselection"))
            .filter_map(|l| l.rsplit_once(' ').map(|(name, _)| name.to_string()))
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    };
    let mut best = 0usize;
    let mut metrics_body = String::new();
    let deadline = Instant::now() + Duration::from_secs(60);
    while Instant::now() < deadline {
        if runner.is_finished() {
            // the run (and its server) ended before a rich scrape landed;
            // the registry is process-wide and outlives the run, so a
            // fresh endpoint still serves the full series set
            let server = adaselection::obs::StatusServer::start("127.0.0.1:0").unwrap();
            let (code, body) = http_get(server.local_addr(), "/metrics").unwrap();
            assert_eq!(code, 200);
            if distinct_series(&body) > best {
                best = distinct_series(&body);
                metrics_body = body;
            }
            break;
        }
        if let Some(addr) = last_bound_addr() {
            if let Ok((200, body)) = http_get(addr, "/metrics") {
                let n = distinct_series(&body);
                if n > best {
                    best = n;
                    metrics_body = body;
                }
                if best >= 20
                    && metrics_body.contains("adaselection_arm_weight{")
                    && metrics_body.contains("adaselection_phase_seconds{")
                {
                    let (code, status) = http_get(addr, "/status").unwrap();
                    assert_eq!(code, 200);
                    let j = adaselection::util::json::Json::parse(&status).unwrap();
                    assert!(j.at(&["uptime_seconds"]).unwrap().as_f64().unwrap() >= 0.0);
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let traced = runner.join().unwrap();
    assert!(
        best >= 20,
        "live /metrics served only {best} distinct series:\n{metrics_body}"
    );
    assert!(metrics_body.contains("adaselection_arm_weight{"), "no per-arm weights");
    assert!(metrics_body.contains("adaselection_phase_seconds{"), "no per-phase seconds");

    // zero interference: the traced + scraped run selects identically
    assert_eq!(plain.digest, traced.digest, "telemetry changed the cluster digest");
    assert_eq!(plain.samples_seen, traced.samples_seen);
    assert_eq!(plain.samples_trained, traced.samples_trained);
    assert_eq!(
        plain.final_rolling_loss.to_bits(),
        traced.final_rolling_loss.to_bits(),
        "rolling loss not bit-identical under telemetry"
    );

    // journal round-trip: every line validates (schema v2), tick events
    // stay tick-contiguous per node, coordinator wire events are present,
    // and every barrier round journals spans with per-node ready lags
    let text = std::fs::read_to_string(&trace).unwrap();
    let mut next: BTreeMap<usize, u64> = BTreeMap::new();
    let mut wire_events = 0usize;
    let mut barrier_rounds: std::collections::BTreeSet<u64> = Default::default();
    let mut lag_nodes: std::collections::BTreeSet<usize> = Default::default();
    for line in text.lines() {
        let ev = validate_line(line)
            .unwrap_or_else(|e| panic!("bad trace line: {e}\n{line}"));
        match ev.kind.as_str() {
            "tick" => {
                let node = ev.node.expect("tick events carry a node");
                let expect = next.entry(node).or_insert(0);
                assert_eq!(ev.tick, *expect, "node {node} journal not tick-contiguous");
                *expect += 1;
            }
            "gossip" | "merge" => {
                assert!(ev.node.is_none());
                assert!(ev.round > 0, "wire event outside any barrier round");
                wire_events += 1;
            }
            "span" => match ev.name.as_deref() {
                Some("barrier") => {
                    assert!(barrier_rounds.insert(ev.round), "duplicate barrier span");
                }
                Some("ready_lag") => {
                    lag_nodes.insert(ev.node.expect("ready_lag spans carry a node"));
                }
                Some("gossip_relay") | Some("merge") => {}
                other => panic!("unexpected span name {other:?}"),
            },
            other => panic!("unexpected event kind {other}"),
        }
    }
    assert_eq!(next.len(), 4, "expected tick events from all 4 nodes");
    for (&node, &n) in &next {
        assert_eq!(n, ticks as u64, "node {node} journalled {n}/{ticks} ticks");
    }
    assert!(wire_events > 0, "no gossip/merge events journalled");
    assert!(!barrier_rounds.is_empty(), "no barrier spans journalled");
    assert_eq!(lag_nodes.len(), 4, "expected ready-lag spans for all 4 nodes");
    std::fs::remove_file(&trace).ok();
}

#[test]
fn replay_tops_up_thin_cluster_shards() {
    // 8 nodes over a burst-heavy stream: single shards regularly fall
    // below the per-node budget, so the replay scheduler must fire
    let mut cfg = base_cfg(8, 60);
    cfg.stream.replay = true;
    cfg.stream.burst_period = 16;
    cfg.stream.burst_min = 0.2;
    let r = cluster::run(&cfg).unwrap();
    assert!(r.samples_replayed > 0, "no replay despite thin shards");
    assert!(r.samples_trained > 0);
    assert!(r.final_rolling_loss.is_finite());
}

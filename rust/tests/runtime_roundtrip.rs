//! Integration: the full python-AOT → rust-PJRT bridge, against the real
//! artifacts tree (skipped gracefully when `make artifacts` hasn't run).
//! Compiled only with `--features xla`; the native backend's equivalent
//! coverage lives in `trainer_e2e.rs` and `runtime::native` unit tests.
//!
//! This is the cross-layer correctness signal: the L1 Pallas score kernel
//! (inside the HLO) must agree with the pure-rust scorer, and the L2 train
//! step must actually learn.
#![cfg(feature = "xla")]

use std::path::PathBuf;

use adaselection::data;
use adaselection::pipeline::{gather, Loader, LoaderConfig};
use adaselection::runtime::{Arg, Engine};
use adaselection::selection::adaselection::score_host;
use adaselection::util::rng::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn score_kernel_matches_rust_oracle() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    eng.check_method_order().unwrap();

    let mut rng = Pcg64::new(42);
    for &b in &[64usize, 100, 128] {
        if eng.manifest.score.get(&b).is_none() {
            continue;
        }
        let loss: Vec<f32> = (0..b).map(|_| rng.next_f32() * 3.0 + 1e-3).collect();
        let gnorm: Vec<f32> = (0..b).map(|_| rng.next_f32() * 2.0 + 1e-3).collect();
        let w = [0.3f32, 1.2, 0.8, 1.0, 0.5, 0.9, 1.3];
        for (t, cl_on) in [(1usize, true), (500, true), (7, false)] {
            let (s_kernel, alphas) = eng.score(&loss, &gnorm, &w, t, -0.5, cl_on).unwrap();
            let s_rust = score_host(&loss, &gnorm, &w, t, -0.5, cl_on);
            for (i, (a, b)) in s_kernel.iter().zip(s_rust.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * (1.0 + b.abs()),
                    "b={b} t={t} i={i}: kernel {a} vs rust {b}"
                );
            }
            // alpha rows are simplex vectors
            for row in &alphas {
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-4, "alpha row sum {sum}");
            }
        }
    }
}

#[test]
fn init_forward_train_eval_cycle_mlp() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let fam = eng.manifest.family("mlp_simple").unwrap().clone();

    let ds = data::build("simple", 3, 0.05).unwrap();
    let mut state = eng.init_state("mlp_simple", 7).unwrap();
    assert_eq!(state.n_params(), fam.n_params());

    // deterministic init
    let state2 = eng.init_state("mlp_simple", 7).unwrap();
    let p0a = state.params[0].to_vec::<f32>().unwrap();
    let p0b = state2.params[0].to_vec::<f32>().unwrap();
    assert_eq!(p0a, p0b);

    let cfg = LoaderConfig {
        batch_size: fam.batch,
        epochs: 3,
        seed: 5,
        workers: 0,
        capacity: 2,
        drop_last: true,
    };
    let mut loader = Loader::start(ds.train.clone(), &cfg);
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    while let Some(batch) = loader.next_batch() {
        let (loss, gnorm) = eng.forward(&state, &batch).unwrap();
        assert_eq!(loss.len(), fam.batch);
        assert!(loss.iter().all(|l| l.is_finite() && *l >= 0.0));
        assert!(gnorm.iter().all(|g| g.is_finite() && *g >= 0.0));
        let l = eng.train_step(&mut state, &batch, 0.05).unwrap();
        first_loss.get_or_insert(l);
        last_loss = l;
    }
    assert!(
        last_loss < 0.7 * first_loss.unwrap(),
        "train loss did not fall: {first_loss:?} -> {last_loss}"
    );

    // eval on a padded test batch with mask
    let idx: Vec<usize> = (0..60).collect();
    let test_batch = gather(&ds.test, &idx, fam.batch, 0, 0);
    let (loss_sum, correct) = eng.evaluate(&state, &test_batch).unwrap();
    assert!(loss_sum.is_finite() && loss_sum >= 0.0);
    assert_eq!(correct, 0.0); // regression: correct is always 0
}

#[test]
fn train_step_requires_compiled_size() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let ds = data::build("simple", 1, 0.05).unwrap();
    let mut state = eng.init_state("mlp_simple", 1).unwrap();
    // 17 is not in the compiled K grid {10,20,30,40,50,100}
    let idx: Vec<usize> = (0..17).collect();
    let sub = gather(&ds.train, &idx, 17, 0, 0);
    assert!(eng.train_step(&mut state, &sub, 0.01).is_err());
    // rounding helper points to the next compiled size
    let fam = eng.manifest.family("mlp_simple").unwrap();
    assert_eq!(fam.round_size(17), 20);
}

#[test]
fn wrong_arity_and_shape_are_rejected() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let name = eng.manifest.family("mlp_simple").unwrap().fwd.clone();
    assert!(eng.run(&name, &[]).is_err());
    let bad = vec![0.0f32; 3];
    let args: Vec<Arg> = (0..6).map(|_| Arg::F32(&bad)).collect();
    assert!(eng.run(&name, &args).is_err());
}

#[test]
fn lm_family_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let fam = eng.manifest.family("transformer").unwrap().clone();
    let ds = data::build("wikitext", 2, 0.005).unwrap();
    let state = eng.init_state("transformer", 3).unwrap();

    let idx: Vec<usize> = (0..fam.batch).collect();
    let batch = gather(&ds.train, &idx, fam.batch, 0, 0);
    let (loss, _gnorm) = eng.forward(&state, &batch).unwrap();
    // untrained LM loss ≈ ln(vocab) = ln 256 ≈ 5.55
    let mean: f32 = loss.iter().sum::<f32>() / loss.len() as f32;
    assert!((mean - 5.55).abs() < 1.0, "untrained LM loss {mean}");
}

#[test]
fn fused_fwd_score_matches_separate_calls() {
    let Some(dir) = artifacts() else { return };
    let mut eng = Engine::new(&dir).unwrap();
    let fam = eng.manifest.family("mlp_simple").unwrap().clone();
    if fam.fwd_score.is_none() {
        return; // artifacts tree predates the fused module
    }
    let ds = data::build("simple", 9, 0.05).unwrap();
    let state = eng.init_state("mlp_simple", 5).unwrap();
    let idx: Vec<usize> = (0..fam.batch).collect();
    let batch = gather(&ds.train, &idx, fam.batch, 0, 0);
    let w = [0.9f32, 1.1, 1.0, 0.0, 0.4, 0.8, 0.3];

    let (l1, g1, s1, a1) = eng
        .forward_score(&state, &batch, &w, 7, -0.5, true)
        .unwrap()
        .expect("fused artifact present");
    let (l2, g2) = eng.forward(&state, &batch).unwrap();
    let (s2, a2) = eng.score(&l2, &g2, &w, 7, -0.5, true).unwrap();
    for (a, b) in l1.iter().zip(l2.iter()) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()));
    }
    for (a, b) in g1.iter().zip(g2.iter()) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()));
    }
    for (a, b) in s1.iter().zip(s2.iter()) {
        assert!((a - b).abs() < 1e-5 * (1.0 + b.abs()));
    }
    for (ra, rb) in a1.iter().zip(a2.iter()) {
        for (a, b) in ra.iter().zip(rb.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

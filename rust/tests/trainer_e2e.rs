//! Integration: full training runs for every policy type.
//!
//! The default-feature tests drive the pure-Rust [`NativeBackend`] — no
//! Python, no XLA, no artifacts directory — so they run on any machine and
//! in CI. The `xla` module at the bottom keeps the original PJRT tests,
//! compiled only with `--features xla` and skipped without artifacts.

use adaselection::config::RunConfig;
use adaselection::runtime::NativeBackend;
use adaselection::train::{self, Trainer};

fn base(dataset: &str, selector: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.dataset = dataset.into();
    cfg.selector = selector.into();
    cfg.epochs = 2;
    cfg.data_scale = 0.01;
    cfg.gamma = 0.2;
    cfg.lr = 0.05;
    cfg.workers = 2;
    cfg
}

#[test]
fn native_regression_learns_and_trains_exact_ceil_gamma_b() {
    let mut backend = NativeBackend::new();
    // NOTE: small_loss is excluded — on the outlier regression task it
    // legitimately diverges at this lr (the paper's Fig-5 finding).
    for selector in ["benchmark", "uniform", "adaselection:big_loss+small_loss+uniform"] {
        let mut cfg = base("simple", selector);
        cfg.epochs = 4;
        cfg.data_scale = 0.05;
        let mut trainer = Trainer::new(&mut backend, cfg).unwrap();
        // γ=0.2, B=100 ⇒ the native subset size is exactly ⌈γB⌉ = 20
        // (no compiled-size rounding)
        assert_eq!(trainer.subset_size(), 20);
        let r = trainer.run().unwrap();
        let first = r.epochs.first().unwrap().test_loss;
        let last = r.final_test_loss();
        assert!(
            last < first,
            "{selector}: test loss must fall ({first} -> {last})"
        );
        assert!(r.iterations > 0);
        if selector == "benchmark" {
            // benchmark trains every batch in full: no forward passes
            assert_eq!(r.phases.count("forward"), 0);
        } else {
            // selection path: one forward + one subset update per iteration
            assert_eq!(r.phases.count("update"), r.iterations as u64);
            assert_eq!(r.phases.count("forward"), r.iterations as u64);
        }
        if selector.starts_with("adaselection") {
            assert!(!r.weight_trace.is_empty());
            assert_eq!(r.weight_names.len(), 3);
        } else {
            assert!(r.weight_trace.is_empty());
        }
    }
}

#[test]
fn native_subset_size_is_exact_for_every_gamma() {
    let mut backend = NativeBackend::new();
    let mut check = |gamma: f64, want: usize| {
        let mut cfg = base("simple", "big_loss");
        cfg.gamma = gamma;
        let t = Trainer::new(&mut backend, cfg).unwrap();
        assert_eq!(t.subset_size(), want, "γ={gamma}");
    };
    // B = 100 for mlp_simple: ⌈γB⌉ exactly, including non-grid sizes
    check(0.1, 10);
    check(0.17, 17);
    check(0.333, 34);
    check(1.0, 100);
}

#[test]
fn native_kernel_and_host_scorers_agree_exactly() {
    // the native "kernel" scorer is the same math as the host oracle (only
    // the α-row summation order differs), so trajectories agree to float
    // precision — a far tighter bound than the XLA kernel's 1e-2
    let mut backend = NativeBackend::new();
    let run = |backend: &mut NativeBackend, kernel: bool| {
        let mut cfg = base("simple", "adaselection:big_loss+small_loss+uniform");
        cfg.kernel_scorer = kernel;
        cfg.epochs = 3;
        train::run_with(backend, cfg).unwrap()
    };
    let a = run(&mut backend, true);
    let b = run(&mut backend, false);
    assert_eq!(a.iterations, b.iterations);
    for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
        assert!(
            (ea.test_loss - eb.test_loss).abs() < 1e-4,
            "kernel {} vs host {}",
            ea.test_loss,
            eb.test_loss
        );
    }
    for (wa, wb) in a.weight_trace.iter().zip(b.weight_trace.iter()) {
        for (x, y) in wa.iter().zip(wb.iter()) {
            assert!((x - y).abs() < 1e-4, "weights diverged: {x} vs {y}");
        }
    }
}

#[test]
fn native_classification_produces_sane_accuracy() {
    let mut cfg = base("cifar10", "big_loss");
    cfg.epochs = 3;
    cfg.data_scale = 0.01;
    cfg.lr = 0.02;
    let r = train::run(cfg).unwrap();
    let acc = r.final_test_acc();
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    assert!(acc > 0.08, "should beat random-ish after 3 epochs: {acc}");
}

#[test]
fn native_accumulate_mode_runs_and_pools_updates() {
    let mut cfg = base("simple", "big_loss");
    cfg.accumulate = true;
    cfg.epochs = 3;
    let r = train::run(cfg).unwrap();
    // γ=0.2 pools k=20 per batch, so updates fire every ⌈100/20⌉=5 batches:
    // update count ≈ iterations/5, definitely fewer than iterations
    assert!(r.phases.count("update") < r.iterations as u64);
    assert!(r.phases.count("update") > 0);
}

#[test]
fn native_lm_training_reduces_loss_below_uniform_start() {
    let mut cfg = base("wikitext", "adaselection:big_loss+small_loss+uniform");
    cfg.epochs = 2;
    cfg.data_scale = 0.003;
    cfg.lr = 0.5;
    let r = train::run(cfg).unwrap();
    // ln(256) ≈ 5.55 is the uniform ceiling
    assert!(
        r.final_test_loss() < 5.55,
        "lm loss {} did not beat uniform",
        r.final_test_loss()
    );
}

#[test]
fn native_stale_cache_skips_forward_passes() {
    let mut cfg = base("simple", "adaselection:big_loss+small_loss+uniform");
    cfg.epochs = 4;
    cfg.stale_refresh = 2;
    let r = train::run(cfg).unwrap();
    // with a 2-epoch refresh window some batches must be cache-served
    assert!(r.phases.count("cache") > 0);
    assert!(r.phases.count("forward") < r.iterations as u64);
}

#[test]
fn xla_backend_without_feature_errors_clearly() {
    if cfg!(feature = "xla") {
        return; // the xla path is exercised by the module below instead
    }
    let mut cfg = base("simple", "uniform");
    cfg.backend = "xla".into();
    let err = train::run(cfg).unwrap_err().to_string();
    assert!(err.contains("xla"), "unhelpful error: {err}");
}

/// The original PJRT integration tests, unchanged semantics: compiled only
/// with `--features xla`, skipped gracefully without an artifacts tree.
#[cfg(feature = "xla")]
mod xla {
    use super::base;
    use adaselection::runtime::Engine;
    use adaselection::train;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn regression_learns_under_every_policy_kind() {
        let Some(dir) = artifacts() else { return };
        let mut engine = Engine::new(&dir).unwrap();
        for selector in ["benchmark", "uniform", "adaselection:big_loss+small_loss+uniform"] {
            let mut cfg = base("simple", selector);
            cfg.epochs = 4;
            cfg.data_scale = 0.05;
            let r = train::run_with(&mut engine, cfg).unwrap();
            let first = r.epochs.first().unwrap().test_loss;
            let last = r.final_test_loss();
            assert!(
                last < first,
                "{selector}: test loss must fall ({first} -> {last})"
            );
        }
    }

    #[test]
    fn kernel_and_host_scorers_agree_on_selection_trajectory() {
        let Some(dir) = artifacts() else { return };
        let mut engine = Engine::new(&dir).unwrap();
        let run = |engine: &mut Engine, kernel: bool| {
            let mut cfg = base("simple", "adaselection:big_loss+small_loss+uniform");
            cfg.kernel_scorer = kernel;
            cfg.epochs = 3;
            train::run_with(engine, cfg).unwrap()
        };
        let a = run(&mut engine, true);
        let b = run(&mut engine, false);
        assert_eq!(a.iterations, b.iterations);
        for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
            assert!(
                (ea.test_loss - eb.test_loss).abs() < 1e-2 * (1.0 + eb.test_loss.abs()),
                "kernel {} vs host {}",
                ea.test_loss,
                eb.test_loss
            );
        }
        for (wa, wb) in a.weight_trace.iter().zip(b.weight_trace.iter()) {
            for (x, y) in wa.iter().zip(wb.iter()) {
                assert!((x - y).abs() < 1e-2, "weights diverged: {x} vs {y}");
            }
        }
    }

    #[test]
    fn benchmark_faster_per_sample_but_slower_per_batch_than_method() {
        // fig-3 mechanism check at tiny scale: with γ=0.1 the method path
        // (fwd(B) + train(K)) must be faster per iteration than train(B)
        let Some(dir) = artifacts() else { return };
        let mut engine = Engine::new(&dir).unwrap();
        let mk = |engine: &mut Engine, selector: &str| {
            let mut cfg = base("cifar10", selector);
            cfg.epochs = 2;
            cfg.data_scale = 0.02;
            cfg.gamma = 0.1;
            train::run_with(engine, cfg).unwrap()
        };
        // warm both paths once (compile)
        let _ = mk(&mut engine, "benchmark");
        let _ = mk(&mut engine, "big_loss");
        let bench = mk(&mut engine, "benchmark");
        let method = mk(&mut engine, "big_loss");
        assert_eq!(bench.iterations, method.iterations);
        assert!(
            method.train_time_s() < bench.train_time_s(),
            "method {:.3}s !< benchmark {:.3}s",
            method.train_time_s(),
            bench.train_time_s()
        );
    }
}

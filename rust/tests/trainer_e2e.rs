//! Integration: full training runs through the real artifacts for every
//! policy type and task family (small sizes; skipped without artifacts).

use std::path::PathBuf;

use adaselection::config::RunConfig;
use adaselection::runtime::Engine;
use adaselection::train;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    dir.join("manifest.json").exists().then_some(dir)
}

fn base(dataset: &str, selector: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.dataset = dataset.into();
    cfg.selector = selector.into();
    cfg.epochs = 2;
    cfg.data_scale = 0.01;
    cfg.gamma = 0.2;
    cfg.lr = 0.05;
    cfg.workers = 2;
    cfg
}

#[test]
fn regression_learns_under_every_policy_kind() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    // NOTE: small_loss is excluded — on the outlier regression task it
    // legitimately diverges at this lr (the paper's Fig-5 finding); its
    // execution path is covered by fig5/fig6 sweeps and the property tests.
    for selector in ["benchmark", "uniform", "adaselection:big_loss+small_loss+uniform"] {
        let mut cfg = base("simple", selector);
        cfg.epochs = 4;
        cfg.data_scale = 0.05;
        let r = train::run_with(&mut engine, cfg).unwrap();
        let first = r.epochs.first().unwrap().test_loss;
        let last = r.final_test_loss();
        assert!(
            last < first,
            "{selector}: test loss must fall ({first} -> {last})"
        );
        assert!(r.iterations > 0);
        if selector.starts_with("adaselection") {
            assert!(!r.weight_trace.is_empty());
            assert_eq!(r.weight_names.len(), 3);
        } else {
            assert!(r.weight_trace.is_empty());
        }
    }
}

#[test]
fn kernel_and_host_scorers_agree_on_selection_trajectory() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let run = |engine: &mut Engine, kernel: bool| {
        let mut cfg = base("simple", "adaselection:big_loss+small_loss+uniform");
        cfg.kernel_scorer = kernel;
        cfg.epochs = 3;
        train::run_with(engine, cfg).unwrap()
    };
    let a = run(&mut engine, true);
    let b = run(&mut engine, false);
    // identical data order + equivalent scoring ⇒ same learning trajectory
    assert_eq!(a.iterations, b.iterations);
    for (ea, eb) in a.epochs.iter().zip(b.epochs.iter()) {
        assert!(
            (ea.test_loss - eb.test_loss).abs() < 1e-2 * (1.0 + eb.test_loss.abs()),
            "kernel {} vs host {}",
            ea.test_loss,
            eb.test_loss
        );
    }
    // weight trajectories match closely
    for (wa, wb) in a.weight_trace.iter().zip(b.weight_trace.iter()) {
        for (x, y) in wa.iter().zip(wb.iter()) {
            assert!((x - y).abs() < 1e-2, "weights diverged: {x} vs {y}");
        }
    }
}

#[test]
fn classification_run_produces_sane_accuracy() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut cfg = base("cifar10", "big_loss");
    cfg.epochs = 3;
    cfg.data_scale = 0.01;
    let r = train::run_with(&mut engine, cfg).unwrap();
    let acc = r.final_test_acc();
    assert!((0.0..=1.0).contains(&acc), "acc {acc}");
    assert!(acc > 0.08, "should beat random-ish after 3 epochs: {acc}");
}

#[test]
fn accumulate_mode_runs_and_pools_updates() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut cfg = base("simple", "big_loss");
    cfg.accumulate = true;
    cfg.epochs = 3;
    let r = train::run_with(&mut engine, cfg).unwrap();
    // γ=0.2 pools k=20 per batch, so updates fire every ⌈100/20⌉=5 batches:
    // update count ≈ iterations/5, definitely fewer than iterations
    assert!(r.phases.count("update") < r.iterations as u64);
    assert!(r.phases.count("update") > 0);
}

#[test]
fn lm_training_reduces_loss_below_uniform_start() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mut cfg = base("wikitext", "adaselection:big_loss+small_loss+uniform");
    cfg.epochs = 2;
    cfg.data_scale = 0.003;
    cfg.lr = 0.1;
    let r = train::run_with(&mut engine, cfg).unwrap();
    // ln(256) ≈ 5.55 is the uniform ceiling
    assert!(
        r.final_test_loss() < 5.55,
        "lm loss {} did not beat uniform",
        r.final_test_loss()
    );
}

#[test]
fn benchmark_faster_per_sample_but_slower_per_batch_than_method() {
    // fig-3 mechanism check at tiny scale: with γ=0.2 the method path
    // (fwd(B) + train(K)) must be faster per iteration than train(B)
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::new(&dir).unwrap();
    let mk = |engine: &mut Engine, selector: &str| {
        let mut cfg = base("cifar10", selector);
        cfg.epochs = 2;
        cfg.data_scale = 0.02;
        cfg.gamma = 0.1;
        train::run_with(engine, cfg).unwrap()
    };
    // warm both paths once (compile)
    let _ = mk(&mut engine, "benchmark");
    let _ = mk(&mut engine, "big_loss");
    let bench = mk(&mut engine, "benchmark");
    let method = mk(&mut engine, "big_loss");
    assert_eq!(bench.iterations, method.iterations);
    assert!(
        method.train_time_s() < bench.train_time_s(),
        "method {:.3}s !< benchmark {:.3}s",
        method.train_time_s(),
        bench.train_time_s()
    );
}

//! Transport conformance: one reusable contract suite exercised against
//! both the in-process `Loopback` and the TCP socket transport, so every
//! `cluster::Transport` implementation keeps identical semantics —
//! ordering, idempotent registration, unregister-drops-mail, the
//! documented send/drain asymmetry on unknown nodes, and per-sender FIFO
//! under interleaved concurrent senders.

use std::sync::Arc;

use adaselection::cluster::{Loopback, Message, Tcp, Transport};
use adaselection::runtime::Tensor;
use adaselection::selection::AdaSnapshot;
use adaselection::stream::InstanceRecord;

/// A gossip message carrying a sender id and a sequence number (in the
/// single entry's id) so tests can check ordering.
fn gossip(from: usize, seq: u64) -> Message {
    Message::StoreGossip {
        from,
        entries: Arc::new(vec![(
            seq,
            InstanceRecord { loss: seq as f32, gnorm: 0.5, last_tick: seq as u32, visits: 1 },
        )]),
    }
}

fn seq_of(m: &Message) -> u64 {
    match m {
        Message::StoreGossip { entries, .. } => entries[0].0,
        _ => panic!("expected a gossip message"),
    }
}

/// A state message with distinctive float payloads (merge material must
/// survive the transport bitwise).
fn state(from: usize) -> Message {
    Message::State {
        from,
        weight: 17.25,
        tensors: vec![
            Tensor { shape: vec![2, 3], data: vec![0.1, -0.2, 0.3, 1.5e-7, -3.25, 42.0] },
            Tensor { shape: vec![0, 4], data: Vec::new() }, // genuinely empty
        ],
        policy: Some(AdaSnapshot {
            w: vec![0.125, 0.25, 0.5, 0.0625, 0.03125, 0.015625, 0.0078125],
            prev_loss: Some(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]),
            t: 99,
            ids: None,
        }),
    }
}

/// The shared `Transport` contract. Every implementation must pass this
/// suite unchanged.
fn conformance<T: Transport>(t: &T) {
    // ordering: sequential sends drain in send order, and drain empties
    t.register(1);
    t.register(2);
    for s in 0..5 {
        t.send(1, gossip(9, s)).unwrap();
    }
    let got = t.drain(1);
    assert_eq!(
        got.iter().map(seq_of).collect::<Vec<_>>(),
        vec![0, 1, 2, 3, 4],
        "messages must drain in send order"
    );
    assert!(t.drain(1).is_empty(), "drain must empty the mailbox");

    // registration is idempotent: re-registering keeps queued mail
    t.send(2, gossip(9, 7)).unwrap();
    t.register(2);
    let got = t.drain(2);
    assert_eq!(got.len(), 1, "re-register dropped queued mail");
    assert_eq!(seq_of(&got[0]), 7);

    // documented asymmetry: send to an unknown node errors, drain of an
    // unknown node returns empty
    assert!(t.send(99, gossip(0, 0)).is_err(), "send to unknown node must error");
    assert!(t.drain(99).is_empty(), "drain of unknown node must be empty");

    // unregister closes the destination and drops anything queued
    t.register(3);
    t.send(3, gossip(1, 1)).unwrap();
    t.unregister(3);
    assert!(t.send(3, gossip(1, 2)).is_err(), "send to unregistered node must error");
    assert!(t.drain(3).is_empty(), "unregister must drop queued mail");

    // a re-registered node starts fresh and works again
    t.register(3);
    t.send(3, gossip(1, 3)).unwrap();
    let got = t.drain(3);
    assert_eq!(got.len(), 1);
    assert_eq!(seq_of(&got[0]), 3);
    t.unregister(3);

    // merge material survives the transport bitwise
    t.register(4);
    let sent = state(6);
    t.send(4, sent.clone()).unwrap();
    let got = t.drain(4);
    assert_eq!(got.len(), 1);
    match (&sent, &got[0]) {
        (
            Message::State { from: f0, weight: w0, tensors: t0, policy: p0 },
            Message::State { from: f1, weight: w1, tensors: t1, policy: p1 },
        ) => {
            assert_eq!(f0, f1);
            assert_eq!(w0.to_bits(), w1.to_bits(), "weight must round-trip bitwise");
            assert_eq!(t0.len(), t1.len());
            for (a, b) in t0.iter().zip(t1.iter()) {
                assert_eq!(a.shape, b.shape);
                let ab: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.data.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb, "tensor data must round-trip bitwise");
            }
            let (p0, p1) = (p0.as_ref().unwrap(), p1.as_ref().unwrap());
            assert_eq!(p0.w, p1.w);
            assert_eq!(p0.prev_loss, p1.prev_loss);
            assert_eq!(p0.t, p1.t);
        }
        _ => panic!("state message did not survive the transport"),
    }
    t.unregister(4);

    // broadcast: every listed peer gets the message exactly once, in
    // send order; an unknown peer errors after earlier peers delivered
    t.register(6);
    t.register(7);
    t.broadcast(&[6, 7], &gossip(2, 11)).unwrap();
    t.broadcast(&[6, 7], &gossip(2, 12)).unwrap();
    for node in [6usize, 7] {
        let got = t.drain(node);
        assert_eq!(
            got.iter().map(seq_of).collect::<Vec<_>>(),
            vec![11, 12],
            "broadcast to node {node}"
        );
    }
    assert!(t.broadcast(&[6, 99], &gossip(2, 13)).is_err(), "unknown peer must error");
    assert_eq!(t.drain(6).len(), 1, "peers before the failing one still get the frame");
    t.unregister(6);
    t.unregister(7);

    // interleaved multi-sender drain: everything arrives exactly once and
    // each sender's subsequence stays FIFO (global interleaving is free)
    t.register(5);
    std::thread::scope(|scope| {
        for sender in 0..3usize {
            scope.spawn(move || {
                for s in 0..20u64 {
                    t.send(5, gossip(sender, s)).unwrap();
                }
            });
        }
    });
    let got = t.drain(5);
    assert_eq!(got.len(), 60, "messages lost or duplicated under concurrency");
    for sender in 0..3usize {
        let seqs: Vec<u64> =
            got.iter().filter(|m| m.from_node() == sender).map(seq_of).collect();
        assert_eq!(
            seqs,
            (0..20).collect::<Vec<u64>>(),
            "sender {sender}'s messages reordered"
        );
    }
    t.unregister(5);
    t.unregister(1);
    t.unregister(2);
}

#[test]
fn loopback_conforms() {
    conformance(&Loopback::new());
}

#[test]
fn tcp_conforms() {
    conformance(&Tcp::new());
}

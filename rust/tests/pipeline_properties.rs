//! Property tests over the streaming pipeline: exactly-once delivery,
//! determinism under any worker count, padding/mask correctness.

use adaselection::data::{Dataset, Task, XStore, YStore};
use adaselection::pipeline::{gather, Loader, LoaderConfig};
use adaselection::testutil::prop::prop_check;

fn toy_ds(n: usize) -> Dataset {
    Dataset {
        name: "toy".into(),
        task: Task::Regression,
        feat_shape: vec![2],
        x: XStore::F32 {
            data: (0..2 * n).map(|i| i as f32).collect(),
            stride: 2,
        },
        y: YStore::F32((0..n).map(|i| i as f32).collect()),
    }
}

#[test]
fn prop_exactly_once_per_epoch_any_config() {
    prop_check(
        "exactly-once delivery",
        0xB1,
        40,
        |rng| {
            let n = 10 + rng.next_below(300) as usize;
            let batch = 1 + rng.next_below(40) as usize;
            let workers = rng.next_below(5) as usize;
            let capacity = 1 + rng.next_below(6) as usize;
            let epochs = 1 + rng.next_below(3) as usize;
            let drop_last = rng.next_f64() < 0.5;
            (n, batch, workers, capacity, epochs, drop_last, rng.next_u64())
        },
        |&(n, batch, workers, capacity, epochs, drop_last, seed)| {
            let cfg = LoaderConfig {
                batch_size: batch,
                epochs,
                seed,
                workers,
                capacity,
                drop_last,
            };
            let mut loader = Loader::start(toy_ds(n), &cfg);
            let mut per_epoch = vec![vec![0usize; n]; epochs];
            while let Some(b) = loader.next_batch() {
                if b.len() != batch {
                    return Err(format!("batch len {} != {batch}", b.len()));
                }
                for &i in &b.indices[..b.real] {
                    per_epoch[b.epoch][i] += 1;
                }
                // padding repeats a valid index and the mask zeroes it
                let mask = b.mask();
                let real_count = mask.iter().filter(|&&m| m == 1.0).count();
                if real_count != b.real {
                    return Err("mask/real mismatch".into());
                }
            }
            for (e, counts) in per_epoch.iter().enumerate() {
                let full_batches = n / batch;
                let covered = if drop_last { full_batches * batch } else { n };
                let total: usize = counts.iter().sum();
                if total != covered {
                    return Err(format!("epoch {e}: delivered {total}, want {covered}"));
                }
                if counts.iter().any(|&c| c > 1) {
                    return Err(format!("epoch {e}: sample delivered twice"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_worker_count_does_not_change_stream() {
    prop_check(
        "worker invariance",
        0xB2,
        20,
        |rng| {
            let n = 20 + rng.next_below(200) as usize;
            let batch = 1 + rng.next_below(20) as usize;
            (n, batch, rng.next_u64())
        },
        |&(n, batch, seed)| {
            let stream = |workers: usize| {
                let cfg = LoaderConfig {
                    batch_size: batch,
                    epochs: 2,
                    seed,
                    workers,
                    capacity: 3,
                    drop_last: false,
                };
                let mut loader = Loader::start(toy_ds(n), &cfg);
                let mut out = Vec::new();
                while let Some(b) = loader.next_batch() {
                    out.push(b.indices);
                }
                out
            };
            let s0 = stream(0);
            for w in [1usize, 3] {
                if stream(w) != s0 {
                    return Err(format!("stream differs at workers={w}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gather_rows_composes_with_gather() {
    prop_check(
        "gather_rows composition",
        0xB3,
        60,
        |rng| {
            let n = 10 + rng.next_below(100) as usize;
            let bsz = 2 + rng.next_below(16) as usize;
            let indices: Vec<usize> =
                (0..bsz).map(|_| rng.next_below(n as u64) as usize).collect();
            let rows: Vec<usize> =
                (0..1 + rng.next_below(bsz as u64 - 1) as usize)
                    .map(|_| rng.next_below(bsz as u64) as usize)
                    .collect();
            (n, bsz, indices, rows)
        },
        |(n, bsz, indices, rows)| {
            let ds = toy_ds(*n);
            let b = gather(&ds, indices, *bsz, 0, 0);
            let sub = b.gather_rows(rows);
            // sub.x row r must equal the dataset row indices[rows[r]]
            let XStore::F32 { data, stride } = &ds.x else { unreachable!() };
            let sx = sub.x_f32.as_ref().unwrap();
            for (r, &row) in rows.iter().enumerate() {
                let src = indices[row];
                if sx[r * stride..(r + 1) * stride] != data[src * stride..(src + 1) * stride] {
                    return Err(format!("row {r} mismatch"));
                }
            }
            Ok(())
        },
    );
}

//! Property tests on the selection invariants (via the from-scratch
//! `testutil::prop` framework — no proptest offline).

use adaselection::selection::adaselection::score_host;
use adaselection::selection::method::{all_alphas, alpha};
use adaselection::selection::{
    AdaConfig, AdaSelection, Arm, Method, SelectionContext, Selector, SingleMethod,
};
use adaselection::testutil::prop::{loss_gnorm, prop_check};
use adaselection::util::rng::Pcg64;
use adaselection::util::topk::top_k_indices;

#[test]
fn prop_alphas_are_simplex_vectors() {
    prop_check(
        "alpha simplex",
        0xA1,
        200,
        |rng| loss_gnorm(rng, 200),
        |(loss, gnorm)| {
            for (m, a) in Method::ALL.iter().zip(all_alphas(loss, gnorm)) {
                let sum: f32 = a.iter().sum();
                if (sum - 1.0).abs() > 1e-4 {
                    return Err(format!("{m:?} sums to {sum}"));
                }
                if a.iter().any(|&x| !(0.0..=1.0).contains(&x)) {
                    return Err(format!("{m:?} out of [0,1]"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_score_linear_in_w_without_cl() {
    prop_check(
        "score linearity",
        0xA2,
        100,
        |rng| {
            let (l, g) = loss_gnorm(rng, 150);
            let w1: Vec<f32> = (0..7).map(|_| rng.next_f32()).collect();
            let w2: Vec<f32> = (0..7).map(|_| rng.next_f32()).collect();
            (l, g, w1, w2)
        },
        |(l, g, w1, w2)| {
            let mut a1 = [0f32; 7];
            let mut a2 = [0f32; 7];
            let mut a12 = [0f32; 7];
            for i in 0..7 {
                a1[i] = w1[i];
                a2[i] = w2[i];
                a12[i] = w1[i] + w2[i];
            }
            let s1 = score_host(l, g, &a1, 5, -0.5, false);
            let s2 = score_host(l, g, &a2, 5, -0.5, false);
            let s12 = score_host(l, g, &a12, 5, -0.5, false);
            for i in 0..l.len() {
                let want = s1[i] + s2[i];
                if (s12[i] - want).abs() > 1e-4 * (1.0 + want.abs()) {
                    return Err(format!("i={i}: {} vs {want}", s12[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topk_matches_sorted_prefix_and_permutation_invariance() {
    prop_check(
        "topk correctness",
        0xA3,
        200,
        |rng| {
            let v: Vec<f32> = (0..1 + rng.next_below(300) as usize)
                .map(|_| rng.next_f32())
                .collect();
            let k = rng.next_below(v.len() as u64 + 1) as usize;
            let perm = Pcg64::new(rng.next_u64()).permutation(v.len());
            (v, k, perm)
        },
        |(v, k, perm)| {
            let got = top_k_indices(v, *k);
            // matches full-sort prefix
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| {
                v[b].partial_cmp(&v[a]).unwrap().then(a.cmp(&b))
            });
            if got != idx[..*k] {
                return Err("top-k != sorted prefix".to_string());
            }
            // permutation invariance of the selected VALUE set
            let pv: Vec<f32> = perm.iter().map(|&i| v[i]).collect();
            let got_p = top_k_indices(&pv, *k);
            let mut vals: Vec<f32> = got.iter().map(|&i| v[i]).collect();
            let mut vals_p: Vec<f32> = got_p.iter().map(|&i| pv[i]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals_p.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if vals != vals_p {
                return Err("selected value set not permutation invariant".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_weights_positive_normalized_under_any_stream() {
    prop_check(
        "weight invariants",
        0xA4,
        60,
        |rng| {
            let steps: Vec<(Vec<f32>, Vec<f32>)> =
                (0..20).map(|_| loss_gnorm(rng, 64)).collect();
            let beta = -1.0 + 2.0 * rng.next_f32();
            (steps, beta)
        },
        |(steps, beta)| {
            let mut ada = AdaSelection::new(AdaConfig {
                candidates: Method::ALL.iter().copied().map(Arm::Kernel).collect(),
                beta: *beta,
                cl_on: true,
                cl_power: -0.5,
                rule: None,
                obftf_k: 10,
            });
            for (l, g) in steps {
                let k = (l.len() / 4).max(1);
                let out = ada.step_host(l, g, k);
                if out.selected.len() != k.min(l.len()) {
                    return Err("wrong selection size".into());
                }
                let w = ada.weights();
                if w.iter().any(|&x| !(x > 0.0) || !x.is_finite()) {
                    return Err(format!("bad weights {w:?}"));
                }
                let sum: f32 = w.iter().sum();
                if (sum - w.len() as f32).abs() > 1e-2 {
                    return Err(format!("weights not normalized: sum {sum}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_method_selects_k_unique_in_range() {
    prop_check(
        "single-method selection",
        0xA5,
        150,
        |rng| {
            let (l, g) = loss_gnorm(rng, 128);
            let k = 1 + rng.next_below(l.len() as u64) as usize;
            let m = Method::ALL[rng.next_below(7) as usize];
            let seed = rng.next_u64();
            (l, g, k, m, seed)
        },
        |(l, g, k, m, seed)| {
            let sel = SingleMethod::new(*m, *seed).select(&SelectionContext {
                loss: l,
                gnorm: g,
                k: *k,
                history: None,
            });
            if sel.len() != *k {
                return Err(format!("{m:?}: got {} want {k}", sel.len()));
            }
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != *k {
                return Err(format!("{m:?}: duplicate rows"));
            }
            if s.iter().any(|&i| i >= l.len()) {
                return Err(format!("{m:?}: row out of range"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alpha_order_consistency() {
    // big_loss α must order exactly like the losses; small_loss inversely
    prop_check(
        "alpha ordering",
        0xA6,
        100,
        |rng| loss_gnorm(rng, 100),
        |(loss, gnorm)| {
            let big = alpha(Method::BigLoss, loss, gnorm);
            let small = alpha(Method::SmallLoss, loss, gnorm);
            for i in 0..loss.len() {
                for j in (i + 1)..loss.len() {
                    if loss[i] > loss[j] + 1e-6 {
                        if big[i] < big[j] - 1e-7 {
                            return Err(format!("big α misordered at ({i},{j})"));
                        }
                        if small[i] > small[j] + 1e-7 {
                            return Err(format!("small α misordered at ({i},{j})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

//! Integration: CLI parsing + config layering + JSON provenance round-trips.

use adaselection::cli::Args;
use adaselection::config::RunConfig;
use adaselection::util::json::Json;

fn parse(s: &str) -> Args {
    Args::parse(s.split_whitespace().map(String::from)).unwrap()
}

#[test]
fn full_train_command_line() {
    let a = parse(
        "train --dataset svhn --selector adaselection:big_loss+uniform --gamma 0.3 \
         --beta -0.5 --cl off --epochs 7 --lr 0.02 --seed 9 --data-scale 0.05 \
         --workers 4 --accumulate --kernel-scorer off",
    );
    let mut cfg = RunConfig::default();
    for (k, v) in &a.flags {
        cfg.apply_override(k, v).unwrap();
    }
    cfg.validate().unwrap();
    assert_eq!(cfg.dataset, "svhn");
    assert_eq!(cfg.selector, "adaselection:big_loss+uniform");
    assert!((cfg.gamma - 0.3).abs() < 1e-12);
    assert!((cfg.beta + 0.5).abs() < 1e-6);
    assert!(!cfg.cl_on);
    assert_eq!(cfg.epochs, 7);
    assert_eq!(cfg.seed, 9);
    assert_eq!(cfg.workers, 4);
    assert!(cfg.accumulate);
    assert!(!cfg.kernel_scorer);
}

#[test]
fn config_file_plus_cli_override_precedence() {
    let dir = std::env::temp_dir().join("ada_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(
        &path,
        r#"{"dataset": "bike", "gamma": 0.4, "epochs": 9}"#,
    )
    .unwrap();
    let mut cfg = RunConfig::from_file(&path).unwrap();
    assert_eq!(cfg.dataset, "bike");
    assert_eq!(cfg.epochs, 9);
    // CLI override wins
    cfg.apply_override("gamma", "0.1").unwrap();
    assert!((cfg.gamma - 0.1).abs() < 1e-12);
}

#[test]
fn provenance_json_reparses_to_same_config() {
    let mut cfg = RunConfig::default();
    cfg.dataset = "wikitext".into();
    cfg.selector = "small_loss".into();
    cfg.gamma = 0.45;
    cfg.beta = -1.0;
    cfg.cl_power = -0.25;
    cfg.accumulate = true;
    let text = cfg.to_json().to_string();
    let back = RunConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.dataset, cfg.dataset);
    assert_eq!(back.selector, cfg.selector);
    assert!((back.gamma - cfg.gamma).abs() < 1e-9);
    assert!((back.beta - cfg.beta).abs() < 1e-6);
    assert!((back.cl_power - cfg.cl_power).abs() < 1e-6);
    assert_eq!(back.accumulate, cfg.accumulate);
}

#[test]
fn all_selector_specs_in_standard_set_validate() {
    for ds in adaselection::data::ALL_DATASETS {
        for sel in adaselection::harness::experiments::standard_selectors(ds) {
            let mut cfg = RunConfig::default();
            cfg.dataset = ds.into();
            cfg.selector = sel.into();
            cfg.validate().unwrap_or_else(|e| panic!("{ds}/{sel}: {e}"));
        }
    }
}

#[test]
fn binary_runs_help_and_list_experiments() {
    // smoke the actual binary (no artifacts needed for these commands)
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out = std::process::Command::new(bin).arg("help").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));

    let out = std::process::Command::new(bin)
        .arg("list-experiments")
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for id in ["fig1", "fig9", "table3", "table4"] {
        assert!(text.contains(id), "{id} missing:\n{text}");
    }

    let out = std::process::Command::new(bin)
        .args(["gen-data", "--dataset", "bike"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("bike"));

    // unknown command exits non-zero
    let out = std::process::Command::new(bin).arg("bogus").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn binary_trains_on_native_backend_without_artifacts() {
    // the zero-dependency quickstart path: no python, no XLA, no artifacts
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out = std::process::Command::new(bin)
        .args([
            "train",
            "--backend",
            "native",
            "--dataset",
            "simple",
            "--selector",
            "adaselection:big_loss+small_loss+uniform",
            "--epochs",
            "1",
            "--data-scale",
            "0.05",
            "--workers",
            "0",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("test_loss"), "{stdout}");

    // unknown backend is rejected up front
    let out = std::process::Command::new(bin)
        .args(["train", "--backend", "cuda"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn binary_streams_on_native_backend() {
    // the continuous-training subcommand end to end through the CLI
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out_dir = std::env::temp_dir().join(format!("ada_cli_stream_{}", std::process::id()));
    let out = std::process::Command::new(bin)
        .args([
            "stream",
            "--backend",
            "native",
            "--dataset",
            "drift-class",
            "--gamma",
            "0.5",
            "--max-ticks",
            "25",
            "--window",
            "10",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("rolling"), "{stdout}");
    assert!(stdout.contains("store"), "{stdout}");
    assert!(out_dir.join("stream_rolling.csv").exists());

    // unknown stream is rejected up front
    let out = std::process::Command::new(bin)
        .args(["stream", "--dataset", "cifar10"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn binary_clusters_on_native_backend() {
    // the multi-node subcommand end to end through the CLI, churn included
    let bin = env!("CARGO_BIN_EXE_adaselection");
    let out_dir = std::env::temp_dir().join(format!("ada_cli_cluster_{}", std::process::id()));
    let out = std::process::Command::new(bin)
        .args([
            "cluster",
            "--nodes",
            "2",
            "--max-ticks",
            "30",
            "--gossip-every",
            "8",
            "--merge-every",
            "8",
            "--kill-at",
            "12",
            "--kill-node",
            "1",
            "--join-at",
            "18",
            "--window",
            "10",
            "--workers",
            "0",
            "--out",
            out_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("cluster result"), "{stdout}");
    assert!(stdout.contains("remapped"), "{stdout}");
    assert!(out_dir.join("cluster_rolling.csv").exists());
    assert!(out_dir.join("cluster_nodes.csv").exists());

    // cluster + checkpoint is rejected up front
    let out = std::process::Command::new(bin)
        .args(["cluster", "--checkpoint", "/tmp/ck.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn backend_flag_round_trips_through_config() {
    let a = parse("train --backend xla --dataset simple");
    let mut cfg = RunConfig::default();
    for (k, v) in &a.flags {
        cfg.apply_override(k, v).unwrap();
    }
    cfg.validate().unwrap();
    assert_eq!(cfg.backend, "xla");
    let back = RunConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
    assert_eq!(back.backend, "xla");
}

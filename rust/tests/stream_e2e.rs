//! Streaming subsystem end to end on the native backend: bounded-memory
//! instance store at 10k+ samples, deterministic checkpoint/resume, and
//! the AdaSelection-vs-uniform rolling-loss comparison at equal budget.

use adaselection::config::StreamConfig;
use adaselection::runtime::NativeBackend;
use adaselection::stream::StreamTrainer;

fn base_cfg() -> StreamConfig {
    let mut cfg = StreamConfig::default();
    cfg.dataset = "drift-class".into();
    cfg.selector = "adaselection".into();
    cfg.gamma = 0.5;
    cfg.seed = 7;
    cfg.workers = 2;
    cfg.drift_period = 120;
    cfg
}

fn run(cfg: StreamConfig) -> adaselection::stream::StreamResult {
    let mut backend = NativeBackend::new();
    StreamTrainer::new(&mut backend, cfg).unwrap().run().unwrap()
}

#[test]
fn store_memory_bounded_over_10k_samples() {
    let mut cfg = base_cfg();
    cfg.max_ticks = 100; // 100 ticks x B=128 = 12_800 arrivals
    cfg.burst_period = 0;
    cfg.eval_every = 0; // pure ingest: no prequential passes
    cfg.store_capacity = 4096;
    cfg.store_shards = 8;
    let r = run(cfg);
    assert_eq!(r.ticks, 100);
    assert!(r.samples_seen >= 10_000, "only {} samples", r.samples_seen);
    assert!(
        r.store_len <= r.store_capacity,
        "store grew past its bound: {}/{}",
        r.store_len,
        r.store_capacity
    );
    assert_eq!(r.store_capacity, 4096);
    // the bound was actually exercised: far more ids arrived than fit
    assert!(r.store_counters.evictions > 0, "no evictions recorded");
    assert_eq!(
        r.store_counters.evictions + r.store_len as u64,
        r.samples_seen,
        "every arrival is live or counted evicted"
    );
    // γ=0.5: trained exactly ⌈B/2⌉ per tick
    assert_eq!(r.samples_trained, 100 * 64);
}

#[test]
fn arrival_bursts_vary_chunk_sizes() {
    let mut cfg = base_cfg();
    cfg.max_ticks = 32;
    cfg.burst_period = 16;
    cfg.burst_min = 0.25;
    cfg.eval_every = 0;
    let r = run(cfg);
    // mean arrivals under the sinusoid ≈ 0.62·B: strictly fewer than full
    // chunks but well above the lull floor
    assert!(r.samples_seen < 32 * 128);
    assert!(r.samples_seen > 32 * 32);
}

#[test]
fn checkpoint_resume_reproduces_selection_sequence() {
    let dir = std::env::temp_dir().join(format!("ada_stream_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let _ = std::fs::remove_file(&ck);

    let mut cfg = base_cfg();
    cfg.max_ticks = 60;
    cfg.eval_every = 4;
    cfg.store_capacity = 2048;

    // uninterrupted reference run
    let full = run(cfg.clone());
    assert_eq!(full.tick_digests.len(), 60);

    // same run killed at tick 30 (checkpoint written at the end)...
    let mut cfg1 = cfg.clone();
    cfg1.max_ticks = 30;
    cfg1.checkpoint = Some(ck.clone());
    let half = run(cfg1);
    assert_eq!(half.tick_digests.len(), 30);
    assert!(ck.exists(), "checkpoint not written");

    // ...and resumed to the original budget
    let mut cfg2 = cfg.clone();
    cfg2.checkpoint = Some(ck.clone());
    cfg2.resume = true;
    let resumed = run(cfg2);

    // the pre-kill segment matches the reference prefix
    assert_eq!(&full.tick_digests[..30], &half.tick_digests[..]);
    // the post-resume selection sequence is exactly the reference suffix
    assert_eq!(resumed.tick_digests.len(), 30);
    assert_eq!(
        &full.tick_digests[30..],
        &resumed.tick_digests[..],
        "post-resume selection sequence diverged"
    );
    assert_eq!(full.digest, resumed.digest);
    // cumulative accounting carries across the kill
    assert_eq!(full.samples_seen, resumed.samples_seen);
    assert_eq!(full.samples_trained, resumed.samples_trained);

    // resuming under a different run identity (seed) must be rejected —
    // it would silently continue over different traffic
    let mut cfg3 = cfg.clone();
    cfg3.checkpoint = Some(ck.clone());
    cfg3.resume = true;
    cfg3.seed = 8;
    let mut backend = NativeBackend::new();
    assert!(StreamTrainer::new(&mut backend, cfg3).unwrap().run().is_err());

    std::fs::remove_file(&ck).ok();
}

#[test]
fn resume_without_checkpoint_errors() {
    let mut cfg = base_cfg();
    cfg.resume = true; // no checkpoint path
    let mut backend = NativeBackend::new();
    assert!(StreamTrainer::new(&mut backend, cfg).is_err());

    let mut cfg2 = base_cfg();
    cfg2.resume = true;
    cfg2.checkpoint = Some(std::env::temp_dir().join("ada_stream_ck_missing.json"));
    let mut backend2 = NativeBackend::new();
    assert!(StreamTrainer::new(&mut backend2, cfg2).unwrap().run().is_err());
}

#[test]
fn adaselection_beats_uniform_on_the_drift_stream() {
    // equal train-step budget: same ticks, same γ, same arrivals — only the
    // row-selection rule differs. Half of drift-class traffic is a static
    // easy subpopulation; the other half chases a rotating concept. Loss-
    // aware adaptive selection spends its budget on the drifting half and
    // must track the rotation better than uniform row sampling.
    let run_sel = |selector: &str| {
        let mut cfg = base_cfg();
        cfg.selector = selector.into();
        cfg.max_ticks = 150;
        cfg.window = 40;
        cfg.eval_every = 1;
        cfg.burst_period = 0;
        run(cfg)
    };
    let ada = run_sel("adaselection");
    let uni = run_sel("uniform");
    assert_eq!(ada.samples_trained, uni.samples_trained, "unequal budgets");
    assert!(ada.final_rolling_loss.is_finite());
    assert!(uni.final_rolling_loss.is_finite());
    assert!(
        ada.final_rolling_loss < uni.final_rolling_loss,
        "adaselection rolling loss {} !< uniform {}",
        ada.final_rolling_loss,
        uni.final_rolling_loss
    );
}

#[test]
fn replay_tops_up_arrival_dips_from_the_store() {
    // deep bursts: arrivals fall to a quarter of B in the lulls, leaving
    // the ⌈γB⌉ budget underfilled — replay must spend those idle cycles on
    // stored high-loss ids, and those rows must actually be trained on
    let mut cfg = base_cfg();
    cfg.max_ticks = 80;
    cfg.burst_period = 16;
    cfg.burst_min = 0.25;
    cfg.eval_every = 0;
    cfg.replay = true;
    let with = run(cfg.clone());

    let mut cfg_off = cfg.clone();
    cfg_off.replay = false;
    let without = run(cfg_off);

    // same traffic either way
    assert_eq!(with.samples_seen, without.samples_seen);
    assert!(with.samples_replayed > 0, "no replay despite burst lulls");
    assert_eq!(without.samples_replayed, 0);
    // replayed rows land in the train step: selection counts are fixed by
    // ⌈γ·arrivals⌉, so the training total grows by exactly the replayed rows
    assert_eq!(
        with.samples_trained,
        without.samples_trained + with.samples_replayed,
        "replayed rows were not trained on"
    );
    // the top-up never exceeds the per-tick budget ⌈γB⌉ = 64
    assert!(with.samples_trained <= 80 * 64);
}

#[test]
fn drift_detector_boosts_gamma_on_the_drifting_stream() {
    // the drift-class concept rotates with period 100: the prequential
    // loss rises whenever the prototypes move, so Page–Hinkley must fire
    // at least once over two full cycles — and every boost trains more
    // rows than the fixed-γ run
    let mut cfg = base_cfg();
    cfg.max_ticks = 200;
    cfg.drift_period = 100;
    cfg.burst_period = 0;
    cfg.drift_detect = "page-hinkley".into();
    let adaptive = run(cfg.clone());

    let mut fixed_cfg = cfg.clone();
    fixed_cfg.drift_detect = "off".into();
    let fixed = run(fixed_cfg);

    assert!(adaptive.drift_detections >= 1, "Page–Hinkley never fired");
    assert_eq!(adaptive.samples_seen, fixed.samples_seen);
    assert!(
        adaptive.samples_trained > fixed.samples_trained,
        "drift boost did not raise the training volume: {} vs {}",
        adaptive.samples_trained,
        fixed.samples_trained
    );
    assert!(adaptive.final_rolling_loss.is_finite());
}

#[test]
fn adwin_detector_fires_on_the_drifting_stream() {
    // the ADWIN-backed controller must also catch the prototype rotation
    // and train more rows than the fixed-γ run (same harness as the
    // Page–Hinkley e2e above)
    let mut cfg = base_cfg();
    cfg.max_ticks = 200;
    cfg.drift_period = 100;
    cfg.burst_period = 0;
    cfg.drift_detect = "adwin".into();
    let adaptive = run(cfg.clone());

    let mut fixed_cfg = cfg.clone();
    fixed_cfg.drift_detect = "off".into();
    let fixed = run(fixed_cfg);

    assert!(adaptive.drift_detections >= 1, "ADWIN never fired");
    assert_eq!(adaptive.samples_seen, fixed.samples_seen);
    assert!(
        adaptive.samples_trained > fixed.samples_trained,
        "ADWIN boost did not raise the training volume: {} vs {}",
        adaptive.samples_trained,
        fixed.samples_trained
    );
}

#[test]
fn checkpoint_resume_with_drift_and_replay_is_deterministic() {
    let dir = std::env::temp_dir().join(format!("ada_stream_ckdr_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let _ = std::fs::remove_file(&ck);

    let mut cfg = base_cfg();
    cfg.max_ticks = 60;
    cfg.eval_every = 2;
    cfg.burst_period = 16;
    cfg.burst_min = 0.25;
    cfg.drift_detect = "page-hinkley".into();
    cfg.replay = true;
    // default (ample) store capacity here; the eviction-pressure case is
    // pinned by checkpoint_resume_under_eviction_pressure_is_tick_identical

    let full = run(cfg.clone());

    let mut cfg1 = cfg.clone();
    cfg1.max_ticks = 30;
    cfg1.checkpoint = Some(ck.clone());
    let half = run(cfg1);
    assert_eq!(&full.tick_digests[..30], &half.tick_digests[..]);

    let mut cfg2 = cfg.clone();
    cfg2.checkpoint = Some(ck.clone());
    cfg2.resume = true;
    let resumed = run(cfg2);
    assert_eq!(
        &full.tick_digests[30..],
        &resumed.tick_digests[..],
        "drift/replay state did not survive the checkpoint"
    );
    assert_eq!(full.digest, resumed.digest);
    assert_eq!(full.samples_replayed, resumed.samples_replayed);
    assert_eq!(full.drift_detections, resumed.drift_detections);

    // a run with drift-detect off must refuse this checkpoint (different
    // run identity ⇒ different selection sequence)
    let mut cfg3 = cfg.clone();
    cfg3.checkpoint = Some(ck.clone());
    cfg3.resume = true;
    cfg3.drift_detect = "off".into();
    let mut backend = NativeBackend::new();
    assert!(StreamTrainer::new(&mut backend, cfg3).unwrap().run().is_err());

    std::fs::remove_file(&ck).ok();
}

#[test]
fn checkpoint_resume_under_eviction_pressure_is_tick_identical() {
    // checkpoint v4 pin: with a store far too small for the traffic,
    // replay picks depend on exactly which records were live and which
    // generation each shard held at the kill point. The v4 snapshot
    // records per-shard generation boundaries, so the resumed run replays
    // the identical selection sequence the uninterrupted run produces —
    // tick digest for tick digest.
    let dir = std::env::temp_dir().join(format!("ada_stream_ckev_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let _ = std::fs::remove_file(&ck);

    let mut cfg = base_cfg();
    cfg.max_ticks = 60;
    cfg.eval_every = 2;
    cfg.replay = true;
    cfg.store_capacity = 512; // ~7.6k arrivals by tick 60: constant eviction
    cfg.store_shards = 4;

    let full = run(cfg.clone());
    assert!(
        full.store_counters.evictions > 0,
        "no eviction pressure — this pin is vacuous"
    );

    let mut cfg1 = cfg.clone();
    cfg1.max_ticks = 30;
    cfg1.checkpoint = Some(ck.clone());
    let half = run(cfg1);
    assert!(
        half.store_counters.evictions > 0,
        "store never rotated before the kill"
    );
    assert_eq!(&full.tick_digests[..30], &half.tick_digests[..]);

    let mut cfg2 = cfg.clone();
    cfg2.checkpoint = Some(ck.clone());
    cfg2.resume = true;
    let resumed = run(cfg2);
    assert_eq!(
        &full.tick_digests[30..],
        &resumed.tick_digests[..],
        "resume under eviction diverged — per-shard generation boundaries lost"
    );
    assert_eq!(full.digest, resumed.digest);
    assert_eq!(full.samples_seen, resumed.samples_seen);
    assert_eq!(full.samples_trained, resumed.samples_trained);
    assert_eq!(full.samples_replayed, resumed.samples_replayed);

    std::fs::remove_file(&ck).ok();
}

#[test]
fn stream_trains_from_a_file_tail_source() {
    use adaselection::stream::{build_source, write_stream_log, StreamKnobs};

    let dir = std::env::temp_dir().join(format!("ada_stream_file_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("traffic.log");
    let gen = build_source(
        "drift-class",
        StreamKnobs { seed: 11, drift_period: 64, burst_period: 8, burst_min: 0.5 },
    )
    .unwrap();
    write_stream_log(&log, gen.as_ref(), 30, 128).unwrap();

    let mut cfg = base_cfg();
    cfg.dataset = format!("file:{}", log.display());
    cfg.max_ticks = 30;
    cfg.window = 10;
    let r = run(cfg);
    assert_eq!(r.ticks, 30);
    assert!(r.final_rolling_loss.is_finite());
    // the file feed reproduces the generator's traffic volume exactly
    let expect: u64 = (0..30u64).map(|t| gen.gen_chunk(t, 128).ids.len() as u64).sum();
    assert_eq!(r.samples_seen, expect);

    std::fs::remove_file(&log).ok();
}

#[test]
fn stream_trains_from_a_socket_tail_source() {
    use adaselection::stream::{build_source, serve_once, stream_log_text, StreamKnobs};

    let gen = build_source(
        "drift-class",
        StreamKnobs { seed: 19, drift_period: 64, burst_period: 8, burst_min: 0.5 },
    )
    .unwrap();
    let text = stream_log_text(gen.as_ref(), 25, 128).unwrap();
    let (addr, producer) = serve_once(text).unwrap();

    let mut cfg = base_cfg();
    cfg.dataset = format!("tcp:{addr}");
    cfg.max_ticks = 25;
    cfg.window = 10;
    let r = run(cfg);
    producer.join().unwrap().unwrap();
    assert_eq!(r.ticks, 25);
    assert!(r.final_rolling_loss.is_finite());
    // the socket feed reproduces the generator's traffic volume exactly
    let expect: u64 = (0..25u64).map(|t| gen.gen_chunk(t, 128).ids.len() as u64).sum();
    assert_eq!(r.samples_seen, expect);
}

#[test]
fn every_policy_selects_exact_budget_deterministically() {
    // property sweep over the whole selector registry on the two-phase
    // API directly: identical seeds ⇒ identical plans and picks, and the
    // backward set is always exactly k unique in-bounds candidate-local
    // rows (the benchmark keeps everything)
    use adaselection::selection::method::valid_method_ids;
    use adaselection::selection::{build_policy_full, ScoringNeeds, SelectionContext};
    use adaselection::util::rng::Pcg64;

    let mut specs: Vec<String> = vec!["benchmark".into(), "adaselection".into()];
    specs.extend(valid_method_ids().iter().map(|s| s.to_string()));
    specs.push("adaselection:big_loss+obftf+selective-backprop".into());

    for spec in &specs {
        let mk = || build_policy_full(spec, 0xC0FFEE, 0.5, true, -0.5, 4).unwrap();
        let mut p = mk();
        let mut q = mk();
        let mut rng = Pcg64::new(0xE2E5);
        for iter in 0..40 {
            let arrivals = 1 + rng.next_below(256) as usize;
            let k = 1 + rng.next_below(arrivals as u64) as usize;
            let plan = p.plan(arrivals, k);
            assert_eq!(
                plan.candidate_rows,
                q.plan(arrivals, k).candidate_rows,
                "{spec} iter {iter}: plans diverged under equal seeds"
            );
            let rows: Vec<usize> = match &plan.candidate_rows {
                Some(rows) => {
                    assert!(
                        rows.len() >= k && rows.len() <= arrivals,
                        "{spec} iter {iter}: candidate pool {} outside [k={k}, B={arrivals}]",
                        rows.len()
                    );
                    assert!(
                        rows.windows(2).all(|w| w[0] < w[1]),
                        "{spec} iter {iter}: candidates not strictly increasing"
                    );
                    assert!(rows.iter().all(|&r| r < arrivals));
                    rows.clone()
                }
                None => (0..arrivals).collect(),
            };
            let loss: Vec<f32> =
                rows.iter().map(|&r| 0.05 + ((r * 37 + iter) % 101) as f32 * 0.03).collect();
            let gnorm: Vec<f32> = loss.iter().map(|&l| 0.5 * l + 0.01).collect();
            let sel = p.select(&SelectionContext {
                loss: &loss,
                gnorm: &gnorm,
                k,
                history: None,
            });
            assert_eq!(
                sel,
                q.select(&SelectionContext {
                    loss: &loss,
                    gnorm: &gnorm,
                    k,
                    history: None,
                }),
                "{spec} iter {iter}: selection diverged under equal seeds"
            );
            let want = if p.scoring() == ScoringNeeds::None {
                loss.len()
            } else {
                k.min(loss.len())
            };
            assert_eq!(sel.len(), want, "{spec} iter {iter}: wrong keep count");
            let mut s = sel.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), want, "{spec} iter {iter}: duplicate rows in {sel:?}");
            assert!(
                s.iter().all(|&i| i < loss.len()),
                "{spec} iter {iter}: candidate-local row out of range"
            );
        }
    }
}

#[test]
fn obftf_stream_budget_and_forward_cost() {
    // obftf_k=2 at γ=0.25, B=128: forward-score 2·32=64 candidates per
    // tick, backprop exactly ⌈γB⌉=32 — half the forward cost of a
    // full-batch-scoring policy, identical digests across re-runs
    let mut cfg = base_cfg();
    cfg.selector = "obftf".into();
    cfg.obftf_k = 2;
    cfg.gamma = 0.25;
    cfg.max_ticks = 40;
    cfg.burst_period = 0;
    cfg.eval_every = 0;
    let a = run(cfg.clone());
    let b = run(cfg.clone());
    assert_eq!(a.tick_digests, b.tick_digests, "obftf not deterministic");
    assert_eq!(a.digest, b.digest);
    assert_eq!(a.samples_trained, 40 * 32, "backward budget must be exactly ⌈γB⌉ per tick");
    assert_eq!(a.samples_forward, 40 * 64, "forward cost must be obftf_k·⌈γB⌉ per tick");
    assert!(a.samples_forward < a.samples_seen);

    // selective-backprop scores the full batch but trains the same budget
    let mut sb_cfg = cfg.clone();
    sb_cfg.selector = "selective-backprop".into();
    let sb = run(sb_cfg.clone());
    assert_eq!(sb.tick_digests, run(sb_cfg).tick_digests, "selective-backprop not deterministic");
    assert_eq!(sb.samples_trained, 40 * 32);
    assert_eq!(sb.samples_forward, 40 * 128);

    // and the benchmark never runs a selection forward pass at all
    let mut bench_cfg = cfg.clone();
    bench_cfg.selector = "benchmark".into();
    let bench = run(bench_cfg);
    assert_eq!(bench.samples_forward, 0);
    assert_eq!(bench.samples_trained, bench.samples_seen);
}

#[test]
fn forward_cheap_policies_survive_checkpoint_resume() {
    // obftf rng state and the selective-backprop threshold cache both ride
    // the v3 checkpoint: a killed run resumes tick-for-tick
    for selector in ["obftf", "selective-backprop"] {
        let dir = std::env::temp_dir().join(format!(
            "ada_stream_fc_{}_{}",
            selector.replace('-', "_"),
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let ck = dir.join("ck.json");
        let _ = std::fs::remove_file(&ck);

        let mut cfg = base_cfg();
        cfg.selector = selector.into();
        cfg.obftf_k = 2; // non-degenerate candidate plans: rng state matters
        cfg.gamma = 0.25;
        cfg.max_ticks = 40;
        cfg.eval_every = 4;

        let full = run(cfg.clone());

        let mut cfg1 = cfg.clone();
        cfg1.max_ticks = 20;
        cfg1.checkpoint = Some(ck.clone());
        let half = run(cfg1);
        assert_eq!(&full.tick_digests[..20], &half.tick_digests[..], "{selector}");

        let mut cfg2 = cfg.clone();
        cfg2.checkpoint = Some(ck.clone());
        cfg2.resume = true;
        let resumed = run(cfg2);
        assert_eq!(
            &full.tick_digests[20..],
            &resumed.tick_digests[..],
            "{selector}: post-resume selection sequence diverged"
        );
        assert_eq!(full.digest, resumed.digest, "{selector}");
        assert_eq!(full.samples_forward, resumed.samples_forward, "{selector}");

        std::fs::remove_file(&ck).ok();
    }
}

#[test]
fn per_method_drift_with_forward_cheap_pool_survives_resume() {
    // a bandit pool mixing kernel and forward-cheap arms, each arm with
    // its own drift detector: detector state (global + per-method) must
    // ride the checkpoint so a killed run resumes tick-for-tick
    let dir = std::env::temp_dir().join(format!("ada_stream_pmd_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.json");
    let _ = std::fs::remove_file(&ck);

    let mut cfg = base_cfg();
    cfg.selector = "adaselection:big_loss+uniform+obftf+selective-backprop".into();
    cfg.max_ticks = 60;
    cfg.drift_period = 100;
    cfg.burst_period = 0;
    cfg.eval_every = 2;
    cfg.drift_detect = "page-hinkley".into();

    let full = run(cfg.clone());

    let mut cfg1 = cfg.clone();
    cfg1.max_ticks = 30;
    cfg1.checkpoint = Some(ck.clone());
    let half = run(cfg1);
    assert_eq!(&full.tick_digests[..30], &half.tick_digests[..]);

    let mut cfg2 = cfg.clone();
    cfg2.checkpoint = Some(ck.clone());
    cfg2.resume = true;
    let resumed = run(cfg2);
    assert_eq!(
        &full.tick_digests[30..],
        &resumed.tick_digests[..],
        "per-method drift state did not survive the checkpoint"
    );
    assert_eq!(full.digest, resumed.digest);
    assert_eq!(full.drift_detections, resumed.drift_detections);

    std::fs::remove_file(&ck).ok();
}

#[test]
fn telemetry_is_off_the_digest_path_and_journal_round_trips() {
    use adaselection::obs::trace::validate_line;

    let dir = std::env::temp_dir().join(format!("ada_stream_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.jsonl");

    // a busy 200-tick run: drift boosts, replay top-ups, bursts and evals
    // all active so the journal carries every event shape
    let mut cfg = base_cfg();
    cfg.max_ticks = 200;
    cfg.eval_every = 4;
    cfg.burst_period = 16;
    cfg.burst_min = 0.25;
    cfg.drift_detect = "page-hinkley".into();
    cfg.replay = true;

    let plain = run(cfg.clone());

    let mut traced_cfg = cfg.clone();
    traced_cfg.trace = Some(trace.clone());
    traced_cfg.status_addr = Some("127.0.0.1:0".into());
    traced_cfg.health = "warn".into();
    let traced = adaselection::stream::run(traced_cfg).unwrap();

    // zero interference: telemetry — tracing, the status server, the
    // flight ring, the kernel profiler AND the health rule engine — only
    // reads state the tick already produced, so the selection sequence
    // is bit-identical to the dark run
    assert_eq!(plain.tick_digests, traced.tick_digests, "tracing changed a tick digest");
    assert_eq!(plain.digest, traced.digest);
    assert_eq!(plain.samples_trained, traced.samples_trained);
    assert_eq!(plain.samples_replayed, traced.samples_replayed);
    assert_eq!(plain.drift_detections, traced.drift_detections);

    // journal round-trip: every line validates (schema v1–v3) and the
    // tick sequence is contiguous from 0; with --health warn the rules
    // may interleave alert lines (none on a healthy run, but e.g. a
    // loaded CI box can trip one) without disturbing it
    let text = std::fs::read_to_string(&trace).unwrap();
    let mut expect = 0u64;
    let mut kernel_phases = false;
    for line in text.lines() {
        let ev = validate_line(line)
            .unwrap_or_else(|e| panic!("bad trace line {expect}: {e}\n{line}"));
        if ev.kind == "alert" {
            continue;
        }
        assert_eq!(ev.kind, "tick");
        assert_eq!(ev.node, Some(0));
        assert_eq!(ev.tick, expect, "journal not tick-contiguous");
        if !kernel_phases {
            // the continuous profiler's per-kernel sub-phase seconds ride
            // the tick line's phases map
            let j = adaselection::util::json::Json::parse(line).unwrap();
            let phases = j.at(&["phases"]).unwrap().as_obj().unwrap();
            kernel_phases = phases.keys().any(|k| k == "kernel:sgd_step");
        }
        expect += 1;
    }
    assert_eq!(expect, 200, "one tick journal line per processed tick");
    assert!(kernel_phases, "no kernel: phases in any tick line");
    std::fs::remove_file(&trace).ok();
}

#[test]
fn regression_and_lm_streams_train() {
    for (name, ticks) in [("drift-reg", 30usize), ("drift-lm", 12)] {
        let mut cfg = base_cfg();
        cfg.dataset = name.into();
        cfg.max_ticks = ticks;
        cfg.window = 10;
        cfg.eval_every = 2;
        let r = run(cfg);
        assert_eq!(r.ticks as usize, ticks, "{name}");
        assert!(r.samples_seen > 0, "{name}");
        assert!(r.final_rolling_loss.is_finite(), "{name}");
        if name == "drift-reg" {
            assert!(r.final_rolling_acc.is_nan(), "{name} has no accuracy");
        } else {
            assert!(r.final_rolling_acc >= 0.0, "{name}");
        }
    }
}

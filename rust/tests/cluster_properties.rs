//! Property tests for the consistent-hash ring: load balance at 128
//! virtual nodes, and the minimal-remap guarantee under single-node
//! join/leave — the bound that makes cluster churn cheap.

use std::collections::HashMap;

use adaselection::cluster::{HashRing, NodeId};
use adaselection::testutil::prop::prop_check;
use adaselection::util::rng::Pcg64;

const VNODES: usize = 128;
const KEYS: u64 = 4096;

/// A random ring: seed plus 2..=8 member nodes (non-contiguous ids).
fn gen_ring(rng: &mut Pcg64) -> (u64, Vec<NodeId>) {
    let seed = rng.next_u64();
    let n = 2 + rng.next_below(7) as usize;
    // scatter the ids so nothing depends on dense 0..n numbering
    let ids: Vec<NodeId> = (0..n).map(|i| i * 3 + rng.next_below(3) as usize * 100).collect();
    (seed, ids)
}

fn loads(ring: &HashRing, keys: u64) -> HashMap<NodeId, u64> {
    let mut m = HashMap::new();
    for k in 0..keys {
        *m.entry(ring.owner(k)).or_insert(0) += 1;
    }
    m
}

#[test]
fn balance_max_over_mean_is_bounded_at_128_vnodes() {
    prop_check(
        "ring-balance",
        0xba1a_4ce5,
        30,
        gen_ring,
        |(seed, ids)| {
            let ring = HashRing::with_nodes(*seed, VNODES, ids.iter().copied());
            let loads = loads(&ring, KEYS);
            let mean = KEYS as f64 / ids.len() as f64;
            for &id in ids {
                let l = *loads.get(&id).unwrap_or(&0) as f64;
                if l > 1.6 * mean {
                    return Err(format!(
                        "node {id} overloaded: {l} vs mean {mean:.1} ({} nodes)",
                        ids.len()
                    ));
                }
                if l < 0.45 * mean {
                    return Err(format!(
                        "node {id} starved: {l} vs mean {mean:.1} ({} nodes)",
                        ids.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn join_moves_only_keys_to_the_newcomer_and_few_of_them() {
    prop_check(
        "ring-join-minimal-remap",
        0x10b1_77aa,
        30,
        gen_ring,
        |(seed, ids)| {
            let before = HashRing::with_nodes(*seed, VNODES, ids.iter().copied());
            let newcomer: NodeId = 7777;
            let mut after = before.clone();
            after.add_node(newcomer);
            let n = ids.len() as f64;
            let mut moved = 0u64;
            for k in 0..KEYS {
                let (a, b) = (before.owner(k), after.owner(k));
                if a != b {
                    moved += 1;
                    if b != newcomer {
                        return Err(format!(
                            "key {k} shuffled between survivors: {a} -> {b}"
                        ));
                    }
                }
            }
            // ≈ K/(N+1) expected; 1.5x + constant slack covers vnode noise
            let bound = (KEYS as f64 / (n + 1.0)) * 1.5 + 64.0;
            if (moved as f64) > bound {
                return Err(format!(
                    "join remapped {moved} of {KEYS} keys (bound {bound:.0}, {} nodes)",
                    ids.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn leave_moves_only_the_departed_nodes_keys_and_few_of_them() {
    prop_check(
        "ring-leave-minimal-remap",
        0x1eaf_0042,
        30,
        gen_ring,
        |(seed, ids)| {
            let before = HashRing::with_nodes(*seed, VNODES, ids.iter().copied());
            let victim = ids[0];
            let mut after = before.clone();
            after.remove_node(victim);
            let n = ids.len() as f64;
            let mut moved = 0u64;
            for k in 0..KEYS {
                let (a, b) = (before.owner(k), after.owner(k));
                if a != b {
                    moved += 1;
                    if a != victim {
                        return Err(format!(
                            "key {k} shuffled between survivors: {a} -> {b}"
                        ));
                    }
                    if b == victim {
                        return Err(format!("key {k} still owned by removed node"));
                    }
                }
            }
            let bound = (KEYS as f64 / n) * 1.5 + 64.0;
            if (moved as f64) > bound {
                return Err(format!(
                    "leave remapped {moved} of {KEYS} keys (bound {bound:.0}, {} nodes)",
                    ids.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn remap_fraction_matches_direct_count() {
    let a = HashRing::with_nodes(3, VNODES, 0..4);
    let mut b = a.clone();
    b.add_node(4);
    let frac = HashRing::remap_fraction(&a, &b, KEYS);
    let mut moved = 0u64;
    for k in 0..KEYS {
        if a.owner(k) != b.owner(k) {
            moved += 1;
        }
    }
    assert!((frac - moved as f64 / KEYS as f64).abs() < 1e-12);
    // a fifth of the keys, give or take vnode noise
    assert!(frac > 0.08 && frac < 0.35, "remap fraction {frac}");
}

//! Offline stub of the `xla` PJRT bindings (this container ships neither the
//! crate nor `libxla_extension`). The goal is to keep the `--features xla`
//! code path *compiling* everywhere:
//!
//!   * [`Literal`] is a real host-side typed buffer (create/read/tuple all
//!     work — the `runtime::exec` packing tests exercise it), so code that
//!     only marshals data behaves identically to the real crate;
//!   * [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] return
//!     [`Error::StubRuntime`] — executing HLO needs the real PJRT runtime.
//!
//! Deployments with the real `xla` crate replace the `[patch]`-style path
//! dependency in `rust/Cargo.toml`; no source changes are needed.

use std::fmt;

/// Errors surfaced by the stub (mirrors the real crate's single error enum).
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real PJRT runtime.
    StubRuntime(&'static str),
    /// Host-side usage error (shape/dtype mismatch, missing file, ...).
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::StubRuntime(op) => write!(
                f,
                "xla stub: '{op}' requires the real PJRT runtime (build with the \
                 real `xla` crate; see rust/shims/xla)"
            ),
            Error::Usage(msg) => write!(f, "xla stub: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the workspace uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// Host native types mappable to an [`ElementType`].
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn to_bytes(self) -> [u8; 4];
    fn from_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn to_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }

    fn from_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn to_bytes(self) -> [u8; 4] {
        self.to_le_bytes()
    }

    fn from_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-side typed array (or tuple of arrays) — fully functional.
#[derive(Clone, Debug)]
pub enum Literal {
    Array {
        ty: ElementType,
        shape: Vec<usize>,
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if data.len() != elems * ty.byte_size() {
            return Err(Error::Usage(format!(
                "{} bytes for shape {shape:?} ({ty:?})",
                data.len()
            )));
        }
        Ok(Literal::Array {
            ty,
            shape: shape.to_vec(),
            data: data.to_vec(),
        })
    }

    /// A rank-0 literal holding one element.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array {
            ty: T::ELEMENT_TYPE,
            shape: Vec::new(),
            data: v.to_bytes().to_vec(),
        }
    }

    /// Number of elements (tuples: sum over members).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { ty, data, .. } => data.len() / ty.byte_size(),
            Literal::Tuple(members) => members.iter().map(Literal::element_count).sum(),
        }
    }

    /// Read the array back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::ELEMENT_TYPE {
                    return Err(Error::Usage(format!(
                        "to_vec dtype mismatch: literal is {ty:?}"
                    )));
                }
                Ok(data
                    .chunks_exact(4)
                    .map(|c| T::from_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            Literal::Tuple(_) => Err(Error::Usage("to_vec on a tuple literal".into())),
        }
    }

    /// First element of the array (scalar reads).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::Usage("get_first_element on empty literal".into()))
    }

    /// Decompose a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(members) => Ok(members),
            Literal::Array { .. } => Err(Error::Usage("to_tuple on an array literal".into())),
        }
    }
}

/// Parsed HLO module (the stub stores the text verbatim).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO-text artifact from disk (real parsing happens at
    /// compile time, which the stub cannot do).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Usage(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _module: proto.clone(),
        }
    }
}

/// The PJRT client. `cpu()` succeeds so hosts can introspect manifests;
/// compilation is where the stub stops.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::StubRuntime("compile"))
    }
}

/// A device buffer handle (never actually produced by the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::StubRuntime("to_literal_sync"))
    }
}

/// A compiled executable (never actually produced by the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::StubRuntime("execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_tuple() {
        let l = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0, 0, 128, 63, 0, 0, 0, 64], // [1.0, 2.0]
        )
        .unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert_eq!(l.element_count(), 2);
        assert!(l.to_vec::<i32>().is_err());

        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);

        let t = Literal::Tuple(vec![l.clone(), s]);
        assert_eq!(t.element_count(), 3);
        let members = t.to_tuple().unwrap();
        assert_eq!(members.len(), 2);
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn runtime_ops_error_clearly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("PJRT"));
    }
}

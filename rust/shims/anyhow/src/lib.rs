//! Offline stand-in for the `anyhow` crate (this container has no cargo
//! registry). Implements exactly the API surface the workspace uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`ensure!`], a blanket
//! `From<E: std::error::Error>` conversion, and `Context` on results.
//! The crate is a drop-in path dependency — replace it with crates.io
//! `anyhow = "1"` when building against a registry.

use std::fmt;

/// A dynamically typed error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap a new message around this error (context chaining).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(Boxed(self.to_string(), self.source))),
        }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = String> + '_ {
        let mut items = vec![self.msg.clone()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_ref().map(|b| &**b as _);
        while let Some(e) = cur {
            items.push(e.to_string());
            cur = e.source();
        }
        items.into_iter()
    }
}

/// Internal chain link so `context` preserves the original error text.
#[derive(Debug)]
struct Boxed(
    String,
    Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
);

impl fmt::Display for Boxed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Boxed {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.1.as_deref().map(|e| e as _)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur: Option<&(dyn std::error::Error + 'static)> =
                self.source.as_ref().map(|b| &**b as _);
            while let Some(e) = cur {
                write!(f, ": {e}")?;
                cur = e.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_ref().map(|b| &**b as _);
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`, exactly
// like real anyhow — that is what makes the blanket From below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| {
                Box::new(Boxed(s.to_string(), None))
                    as Box<dyn std::error::Error + Send + Sync>
            }),
        }
    }
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");

        let io: Result<()> = Err(std::io::Error::other("boom").into());
        assert!(io.unwrap_err().to_string().contains("boom"));

        let ctx = fails(false).context("outer").unwrap_err();
        assert_eq!(format!("{ctx:#}"), "outer: flag was false");
    }

    #[test]
    fn bail_short_circuits() {
        fn f() -> Result<()> {
            bail!("no {}", "good");
        }
        assert_eq!(f().unwrap_err().to_string(), "no good");
    }
}

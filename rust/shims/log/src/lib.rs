//! Offline stand-in for the `log` facade crate (this container has no cargo
//! registry). Implements the subset the workspace uses: the five level
//! macros, [`Log`], [`Record`]/[`Metadata`], [`set_logger`]/[`set_max_level`]
//! and [`max_level`]. Drop-in path dependency — replace with crates.io
//! `log = "0.4"` when building against a registry.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

/// Logging verbosity levels, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// A level filter: `Off` plus every [`Level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn as_usize(self) -> usize {
        self as usize
    }
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        self.as_usize() == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        self.as_usize().partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log invocation (level + target module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log invocation: metadata plus the formatted message arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink, registered once via [`set_logger`].
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }

    fn log(&self, _: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: AtomicPtr<&'static dyn Log> = AtomicPtr::new(std::ptr::null_mut());
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let cell: Box<&'static dyn Log> = Box::new(logger);
    let ptr = Box::into_raw(cell);
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        ptr,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // lost the race: reclaim the box we just leaked
            drop(unsafe { Box::from_raw(ptr) });
            Err(SetLoggerError(()))
        }
    }
}

fn logger() -> &'static dyn Log {
    let ptr = LOGGER.load(Ordering::SeqCst);
    if ptr.is_null() {
        &NOP
    } else {
        unsafe { *ptr }
    }
}

/// Set the maximum level that will be dispatched.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::SeqCst);
}

/// The currently configured maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Dispatch one record to the installed logger (macro plumbing).
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments) {
    if level.as_usize() > MAX_LEVEL.load(Ordering::SeqCst) {
        return;
    }
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    let l = logger();
    if l.enabled(&record.metadata) {
        l.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }

        fn log(&self, record: &Record) {
            let _ = record.args();
            HITS.fetch_add(1, Ordering::SeqCst);
        }

        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter;

    #[test]
    fn filtering_and_dispatch() {
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Info);
        info!("hello {}", 1);
        debug!("filtered out");
        assert!(HITS.load(Ordering::SeqCst) >= 1);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        // second install fails
        assert!(set_logger(&COUNTER).is_err());
    }
}

//! Streaming-subsystem benchmarks: source generation cost, instance-store
//! update throughput, end-to-end stream-trainer throughput (samples/sec)
//! at γ ∈ {0.25, 0.5, 1.0} on the drift-class stream, and a per-method
//! forward/backward cost split at γ=0.25 (benchmark, big_loss, obftf,
//! selective-backprop, adaselection). Asserts — against the emitted JSON —
//! that obftf backward-scores strictly fewer rows than the benchmark.
//!
//! Emits `BENCH_stream.json` (see `util::bench::write_json`) so the perf
//! trajectory is tracked across PRs.
//!
//! `cargo bench -- --test` runs one-iteration smoke mode (CI).

use adaselection::config::StreamConfig;
use adaselection::runtime::NativeBackend;
use adaselection::stream::{build_source, InstanceStore, StreamKnobs, StreamTrainer};
use adaselection::util::bench::{bench, print_results, write_json, BenchResult};
use adaselection::util::timer::Stopwatch;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let ms = |full: u64| if smoke { 1 } else { full };
    let mut results: Vec<BenchResult> = Vec::new();

    // source generation: one full chunk per tick
    let knobs = StreamKnobs { seed: 7, drift_period: 256, burst_period: 0, burst_min: 0.25 };
    for name in ["drift-class", "drift-reg", "drift-lm"] {
        let source = build_source(name, knobs.clone()).unwrap();
        let mut tick = 0u64;
        results.push(bench(&format!("gen_chunk {name} B=128"), ms(60), || {
            std::hint::black_box(source.gen_chunk(tick, 128));
            tick += 1;
        }));
    }

    // instance-store update path (the per-arrival bookkeeping cost)
    let store = InstanceStore::new(65_536, 16);
    let mut id = 0u64;
    results.push(bench("store.update (cap 64k, 16 shards)", ms(40), || {
        store.update(id, 1.0, 0.5, (id >> 7) as u32);
        id += 1;
    }));
    let lookup_store = InstanceStore::new(65_536, 16);
    for i in 0..4096u64 {
        lookup_store.update(i, 1.0, 0.5, 0);
    }
    let mut q = 0u64;
    results.push(bench("store.get hit (4k live)", ms(40), || {
        std::hint::black_box(lookup_store.get(q % 4096));
        q += 1;
    }));

    print_results("stream micro-benchmarks", &results);

    // end-to-end trainer throughput: samples/sec at the paper's γ sweep.
    // One short run is a single "op"; per-sample time = run time / arrivals.
    println!(
        "\n## stream trainer throughput (drift-class, native backend, B=128)"
    );
    println!("{:<40} {:>10} {:>14}", "config", "samples", "samples/s");
    let ticks = if smoke { 20 } else { 200 };
    for &gamma in &[0.25f64, 0.5, 1.0] {
        let mut cfg = StreamConfig::default();
        cfg.dataset = "drift-class".into();
        cfg.selector = "adaselection".into();
        cfg.gamma = gamma;
        cfg.max_ticks = ticks;
        cfg.eval_every = 0; // pure select+train throughput
        cfg.burst_period = 0;
        cfg.window = 50;
        let mut backend = NativeBackend::new();
        let sw = Stopwatch::new();
        let r = StreamTrainer::new(&mut backend, cfg).unwrap().run().unwrap();
        let dt = sw.elapsed_secs();
        println!(
            "{:<40} {:>10} {:>14.1}",
            format!("γ={gamma:.2} ticks={ticks}"),
            r.samples_seen,
            r.samples_per_sec
        );
        results.push(BenchResult {
            name: format!("stream e2e drift-class γ={gamma:.2} (per arrival)"),
            iters: r.samples_seen as usize,
            median_ns: dt * 1e9 / r.samples_seen.max(1) as f64,
            p95_ns: dt * 1e9 / r.samples_seen.max(1) as f64,
            mean_ns: dt * 1e9 / r.samples_seen.max(1) as f64,
        });
    }

    // per-method e2e at γ=0.25: forward-cheap methods must buy their
    // speedup by scoring forward-only candidates while the backward pass
    // runs on strictly fewer rows than the full-batch benchmark.
    println!("\n## per-method stream throughput (drift-class, γ=0.25, B=128)");
    println!(
        "{:<22} {:>12} {:>12} {:>14}",
        "method", "fwd rows", "bwd rows", "samples/s"
    );
    for method in [
        "benchmark",
        "big_loss",
        "obftf",
        "selective-backprop",
        "adaselection",
    ] {
        let mut cfg = StreamConfig::default();
        cfg.dataset = "drift-class".into();
        cfg.selector = method.into();
        cfg.gamma = 0.25;
        cfg.max_ticks = ticks;
        cfg.eval_every = 0;
        cfg.burst_period = 0;
        cfg.window = 50;
        let mut backend = NativeBackend::new();
        let sw = Stopwatch::new();
        let r = StreamTrainer::new(&mut backend, cfg).unwrap().run().unwrap();
        let dt = sw.elapsed_secs();
        println!(
            "{:<22} {:>12} {:>12} {:>14.1}",
            method, r.samples_forward, r.samples_trained, r.samples_per_sec
        );
        // iters carries the backward-row count so the emitted JSON records
        // the cost split; forward rows ride in the name.
        results.push(BenchResult {
            name: format!(
                "stream e2e method={method} γ=0.25 fwd={} (per backward row)",
                r.samples_forward
            ),
            iters: r.samples_trained as usize,
            median_ns: dt * 1e9 / (r.samples_trained.max(1) as f64),
            p95_ns: dt * 1e9 / (r.samples_trained.max(1) as f64),
            mean_ns: dt * 1e9 / (r.samples_trained.max(1) as f64),
        });
    }

    // per-kernel digests from the continuous profiler: the e2e runs above
    // timed every backend kernel, so a future bench-diff regression can
    // name the kernel that moved instead of just the end-to-end number
    let kernels = adaselection::util::bench::kernel_results();
    if !kernels.is_empty() {
        print_results("backend kernels (continuous profiler)", &kernels);
        results.extend(kernels);
    }

    write_json("stream", &results).expect("write BENCH_stream.json");

    // read the emitted file back: the perf contract is on the artifact,
    // not the in-memory values.
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join("BENCH_stream.json");
    let text = std::fs::read_to_string(&path).expect("read back BENCH_stream.json");
    let j = adaselection::util::json::Json::parse(&text).expect("parse BENCH_stream.json");
    let backward_rows = |method: &str| -> f64 {
        let tag = format!("method={method} ");
        j.at(&["results"])
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|r| {
                r.at(&["name"])
                    .ok()
                    .and_then(|n| n.as_str().ok())
                    .map(|n| n.contains(&tag))
                    .unwrap_or(false)
            })
            .unwrap_or_else(|| panic!("no {tag}entry in BENCH_stream.json"))
            .at(&["iters"])
            .unwrap()
            .as_f64()
            .unwrap()
    };
    let obftf = backward_rows("obftf");
    let benchmark = backward_rows("benchmark");
    assert!(
        obftf < benchmark,
        "obftf must backward-score strictly fewer rows than benchmark at γ=0.25 \
         (got obftf={obftf}, benchmark={benchmark})"
    );
    println!(
        "[ok] obftf backward rows {obftf} < benchmark backward rows {benchmark}"
    );
}

//! Cluster-subsystem benchmarks: hash-ring micro-costs and end-to-end
//! aggregate throughput (samples/sec) vs node count at an equal total tick
//! budget — the scale-out curve the ROADMAP's north star asks for.
//!
//! Emits `BENCH_cluster.json` (see `util::bench::write_json`) so the perf
//! trajectory is tracked across PRs.
//!
//! `cargo bench -- --test` runs one-iteration smoke mode (CI).

use adaselection::cluster::{self, HashRing};
use adaselection::config::ClusterConfig;
use adaselection::util::bench::{bench, print_results, write_json, BenchResult};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let ms = |full: u64| if smoke { 1 } else { full };
    let mut results: Vec<BenchResult> = Vec::new();

    // ring micro-costs: owner lookup and full membership rebuild
    let ring = HashRing::with_nodes(7, 128, 0..8);
    let mut key = 0u64;
    results.push(bench("ring.owner (8 nodes x 128 vnodes)", ms(30), || {
        std::hint::black_box(ring.owner(key));
        key = key.wrapping_add(1);
    }));
    let mut n = 0usize;
    results.push(bench("ring.build (4 nodes x 128 vnodes)", ms(30), || {
        std::hint::black_box(HashRing::with_nodes(n as u64, 128, 0..4));
        n += 1;
    }));

    print_results("cluster micro-benchmarks", &results);

    // end-to-end: aggregate samples/sec at 1/2/4 nodes, equal tick budget
    println!("\n## cluster throughput (drift-class, native, B=128, equal tick budget)");
    println!(
        "{:<26} {:>10} {:>14} {:>10}",
        "config", "samples", "samples/s", "speedup"
    );
    let ticks = if smoke { 20 } else { 200 };
    let mut base_sps: Option<f64> = None;
    for &nodes in &[1usize, 2, 4] {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = nodes;
        cfg.gossip_every = 8;
        cfg.merge_every = 8;
        cfg.stream.dataset = "drift-class".into();
        cfg.stream.gamma = 0.5;
        cfg.stream.max_ticks = ticks;
        cfg.stream.eval_every = 0; // pure select+train throughput
        cfg.stream.burst_period = 0;
        cfg.stream.window = 50;
        cfg.stream.workers = 1;
        let r = cluster::run(&cfg).expect("cluster bench run");
        let base = *base_sps.get_or_insert(r.samples_per_sec);
        println!(
            "{:<26} {:>10} {:>14.1} {:>9.2}x",
            format!("nodes={nodes} ticks={ticks}"),
            r.samples_seen,
            r.samples_per_sec,
            r.samples_per_sec / base.max(1e-9)
        );
        results.push(BenchResult {
            name: format!("cluster e2e drift-class nodes={nodes} (per arrival)"),
            iters: r.samples_seen as usize,
            median_ns: 1e9 / r.samples_per_sec.max(1e-9),
            p95_ns: 1e9 / r.samples_per_sec.max(1e-9),
            mean_ns: 1e9 / r.samples_per_sec.max(1e-9),
        });
    }

    // gossip bandwidth: full snapshots vs delta gossip at 4 nodes over the
    // same traffic (wire bytes via cluster::wire::frame_len, identical for
    // loopback and tcp runs)
    println!("\n## gossip bandwidth (drift-class, 4 nodes, {ticks} ticks)");
    println!(
        "{:<10} {:>14} {:>14} {:>14}",
        "gossip", "gossip bytes", "merge bytes", "gossip B/tick"
    );
    for mode in ["full", "delta"] {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = 4;
        cfg.gossip = mode.into();
        cfg.gossip_every = 8;
        cfg.merge_every = 8;
        cfg.stream.dataset = "drift-class".into();
        cfg.stream.gamma = 0.5;
        cfg.stream.max_ticks = ticks;
        cfg.stream.eval_every = 0;
        cfg.stream.burst_period = 0;
        cfg.stream.window = 50;
        cfg.stream.workers = 1;
        let r = cluster::run(&cfg).expect("cluster bandwidth run");
        let per_tick = r.gossip_bytes as f64 / ticks as f64;
        println!(
            "{:<10} {:>14} {:>14} {:>14.0}",
            mode, r.gossip_bytes, r.merge_bytes, per_tick
        );
        // *_ns fields carry bytes/tick here — the name says so; the point
        // is tracking the bandwidth trajectory across PRs in BENCH json
        results.push(BenchResult {
            name: format!("cluster gossip bytes per tick (4 nodes, {mode})"),
            iters: ticks,
            median_ns: per_tick,
            p95_ns: per_tick,
            mean_ns: per_tick,
        });
    }

    // worker runtimes: in-process threads vs real OS processes at the same
    // budget (spawn + control-plane overhead is the price of isolation;
    // workers are spawned from the adaselection binary, not this bench)
    println!("\n## worker runtimes (drift-class, 4 nodes, {ticks} ticks)");
    println!("{:<12} {:>10} {:>14} {:>10}", "workers", "samples", "samples/s", "vs threads");
    let worker_exe = std::path::Path::new(env!("CARGO_BIN_EXE_adaselection"));
    let mut thread_sps: Option<f64> = None;
    for mode in ["threads", "processes"] {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = 4;
        cfg.worker_mode = mode.into();
        cfg.gossip_every = 8;
        cfg.merge_every = 8;
        cfg.stream.dataset = "drift-class".into();
        cfg.stream.gamma = 0.5;
        cfg.stream.max_ticks = ticks;
        cfg.stream.eval_every = 0;
        cfg.stream.burst_period = 0;
        cfg.stream.window = 50;
        cfg.stream.workers = 1;
        let r = if mode == "processes" {
            cluster::proc::run_with_exe(&cfg, worker_exe).expect("process cluster bench run")
        } else {
            cluster::run(&cfg).expect("thread cluster bench run")
        };
        let base = *thread_sps.get_or_insert(r.samples_per_sec);
        println!(
            "{:<12} {:>10} {:>14.1} {:>9.2}x",
            mode,
            r.samples_seen,
            r.samples_per_sec,
            r.samples_per_sec / base.max(1e-9)
        );
        results.push(BenchResult {
            name: format!("cluster e2e drift-class 4 nodes, {mode} workers (per arrival)"),
            iters: r.samples_seen as usize,
            median_ns: 1e9 / r.samples_per_sec.max(1e-9),
            p95_ns: 1e9 / r.samples_per_sec.max(1e-9),
            mean_ns: 1e9 / r.samples_per_sec.max(1e-9),
        });
    }

    write_json("cluster", &results).expect("write BENCH_cluster.json");
}

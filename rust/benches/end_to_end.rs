//! End-to-end benchmarks: one section per paper table/figure, miniature
//! sweeps that regenerate the same rows/series shape (full-size runs via
//! `adaselection sweep --exp ...`). Also reports per-step backend costs —
//! the inputs to the paper's fwd(B) + train(⌈γB⌉) vs train(B) cost model.
//!
//! Runs on the native backend (no artifacts needed); build with
//! `--features xla` and provide artifacts to cover the PJRT path instead.
//! `cargo bench -- --test` runs a one-figure smoke (CI).

use adaselection::data;
use adaselection::harness::{run_experiment_with, SweepOptions};
use adaselection::pipeline::gather;
use adaselection::runtime::{Backend, NativeBackend};
use adaselection::util::bench::{bench, print_results, write_json, BenchResult};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut backend = NativeBackend::new();

    backend_step_costs(&mut backend, smoke);

    // Miniature reproduction of every table/figure (quick mode): the bench
    // asserts the harness can regenerate each one and prints the rows.
    let opts = SweepOptions {
        out_dir: std::env::temp_dir().join("adaselection_bench_results"),
        quick: true,
        ..SweepOptions::default()
    };
    let experiments: &[&str] = if smoke {
        &["fig5"]
    } else {
        &["fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table3"]
    };
    for exp in experiments {
        println!("\n########## {exp} (quick miniature, native backend) ##########");
        let t0 = std::time::Instant::now();
        run_experiment_with(&mut backend, exp, &opts).expect(exp);
        println!("[{exp} regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
    }
}

/// The cost model behind Fig 3: per-step times on the classification
/// surrogate family (B=128) across the train-size grid.
fn backend_step_costs(backend: &mut NativeBackend, smoke: bool) {
    let ms = |full: u64| if smoke { 1 } else { full };
    let mut results: Vec<BenchResult> = Vec::new();
    let split = data::build("cifar10", 5, 0.01).unwrap();
    let meta = backend.family_meta("resnet_c10").unwrap();
    let mut state = backend.init_state("resnet_c10", 1).unwrap();
    let idx: Vec<usize> = (0..meta.batch.min(split.train.len())).collect();
    let full = gather(&split.train, &idx, meta.batch, 0, 0);

    results.push({
        let st = backend.init_state("resnet_c10", 1).unwrap();
        let b = full.clone();
        let be = &mut *backend;
        bench("fwd(B=128) loss+gnorm (native)", ms(400), move || {
            std::hint::black_box(be.forward_scores(&st, &b).unwrap());
        })
    });
    // the paper's K grid for B=128 plus the full batch
    for k in [13usize, 26, 39, 52, 64, 128] {
        let rows: Vec<usize> = (0..k).collect();
        let sub = full.gather_rows(&rows);
        let _ = backend.train_step(&mut state, &sub, 0.01).unwrap();
        let mut st = backend.init_state("resnet_c10", 1).unwrap();
        let be = &mut *backend;
        results.push(bench(
            &format!("train_step(K={k}) (native)"),
            ms(400),
            move || {
                std::hint::black_box(be.train_step(&mut st, &sub, 0.01).unwrap());
            },
        ));
    }
    print_results(
        "fig3 cost model: per-step times (method = fwd(128)+train(K); benchmark = train(128))",
        &results,
    );
    write_json("end_to_end", &results).expect("write BENCH_end_to_end.json");
}

//! End-to-end benchmarks: one section per paper table/figure, miniature
//! sweeps that regenerate the same rows/series shape (full-size runs via
//! `adaselection sweep --exp ...`). Also reports per-step artifact costs —
//! the inputs to the paper's fwd(B) + train(⌈γB⌉) vs train(B) cost model.
//!
//! Run: cargo bench (after `make artifacts`).

use std::path::PathBuf;

use adaselection::data;
use adaselection::harness::{run_experiment_with, SweepOptions};
use adaselection::pipeline::gather;
use adaselection::runtime::Engine;
use adaselection::util::bench::{bench, print_results, BenchResult};

fn main() {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return;
    }
    let mut engine = Engine::new(&dir).expect("engine");

    artifact_step_costs(&mut engine);

    // Miniature reproduction of every table/figure (quick mode): the bench
    // asserts the harness can regenerate each one and prints the rows.
    let opts = SweepOptions {
        out_dir: std::env::temp_dir().join("adaselection_bench_results"),
        quick: true,
        ..SweepOptions::default()
    };
    for exp in [
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "table3",
    ] {
        println!("\n########## {exp} (quick miniature) ##########");
        let t0 = std::time::Instant::now();
        run_experiment_with(&mut engine, exp, &opts).expect(exp);
        println!("[{exp} regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
    }
}

/// The cost model behind Fig 3: per-artifact step times.
fn artifact_step_costs(engine: &mut Engine) {
    let mut results: Vec<BenchResult> = Vec::new();
    let split = data::build("cifar10", 5, 0.01).unwrap();
    let fam = engine.manifest.family("resnet_c10").unwrap().clone();
    let mut state = engine.init_state("resnet_c10", 1).unwrap();
    let idx: Vec<usize> = (0..fam.batch).collect();
    let full = gather(&split.train, &idx, fam.batch, 0, 0);

    // warm the executables
    let _ = engine.forward(&state, &full).unwrap();

    results.push({
        let mut st = engine.init_state("resnet_c10", 1).unwrap();
        let eng = &mut *engine;
        let b = full.clone();
        bench("resnet fwd(B=128) loss+gnorm", 800, move || {
            std::hint::black_box(eng.forward(&st, &b).unwrap());
            let _ = &mut st;
        })
    });
    for k in fam.train_sizes.clone() {
        let rows: Vec<usize> = (0..k.min(fam.batch)).collect();
        let sub = full.gather_rows(&rows);
        let _ = engine.train_step(&mut state, &sub, 0.01).unwrap();
        let eng = &mut *engine;
        let mut st = eng.init_state("resnet_c10", 1).unwrap();
        results.push(bench(
            &format!("resnet train_step(K={k})"),
            800,
            move || {
                std::hint::black_box(eng.train_step(&mut st, &sub, 0.01).unwrap());
            },
        ));
    }
    print_results(
        "fig3 cost model: per-step artifact times (method = fwd(128)+train(K); benchmark = train(128))",
        &results,
    );
}

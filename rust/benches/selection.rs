//! Selection-path micro-benchmarks: the per-iteration L3 hot path
//! (α transforms, fused scoring, top-k, weight update) plus the backend
//! scorers for comparison. Selection overhead must stay ≪ forward time
//! (DESIGN.md §9 target: < 5%).
//!
//! `cargo bench -- --test` runs one-iteration smoke mode (CI).

use adaselection::runtime::{Backend, NativeBackend};
use adaselection::selection::adaselection::score_host;
use adaselection::selection::method::all_alphas;
use adaselection::selection::{AdaConfig, AdaSelection, Arm, Method};
use adaselection::util::bench::{bench, print_results, write_json, BenchResult};
use adaselection::util::rng::Pcg64;
use adaselection::util::topk::top_k_indices;

fn inputs(b: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    (
        (0..b).map(|_| 1e-3 + 3.0 * rng.next_f32()).collect(),
        (0..b).map(|_| 1e-3 + 2.0 * rng.next_f32()).collect(),
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let ms = |full: u64| if smoke { 1 } else { full };
    let mut results: Vec<BenchResult> = Vec::new();

    for &b in &[128usize, 1024, 8192] {
        let (loss, gnorm) = inputs(b, b as u64);
        results.push(bench(&format!("all_alphas 7 methods, B={b}"), ms(60), || {
            std::hint::black_box(all_alphas(&loss, &gnorm));
        }));
        let w = [1.0f32; 7];
        results.push(bench(&format!("score_host fused, B={b}"), ms(60), || {
            std::hint::black_box(score_host(&loss, &gnorm, &w, 10, -0.5, true));
        }));
        let k = b / 5;
        results.push(bench(&format!("top_k k={k}, B={b}"), ms(60), || {
            std::hint::black_box(top_k_indices(&loss, k));
        }));
    }

    // full AdaSelection iteration (α + fuse + top-k + eq.3 update)
    let (loss, gnorm) = inputs(128, 9);
    let mut ada = AdaSelection::new(AdaConfig {
        candidates: Method::ALL.iter().copied().map(Arm::Kernel).collect(),
        ..AdaConfig::default()
    });
    results.push(bench("AdaSelection::step_host B=128 (7 cand)", ms(80), || {
        std::hint::black_box(ada.step_host(&loss, &gnorm, 26));
    }));

    // the native backend scorer (same math the trainer calls with
    // --kernel-scorer on the default backend)
    let mut native = NativeBackend::new();
    let (loss, gnorm) = inputs(128, 11);
    let w = [1.0f32; 7];
    results.push(bench("score native backend B=128", ms(60), || {
        std::hint::black_box(native.score(&loss, &gnorm, &w, 1, -0.5, true).unwrap());
    }));

    print_results("selection micro-benchmarks (host path)", &results);
    write_json("selection", &results).expect("write BENCH_selection.json");

    // XLA score-kernel path, if built with the feature + artifacts exist
    #[cfg(feature = "xla")]
    {
        use adaselection::runtime::Engine;
        let dir = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            let mut engine = Engine::new(&dir).expect("engine");
            let (loss, gnorm) = inputs(128, 11);
            let w = [1.0f32; 7];
            // compile outside the timed region
            let _ = engine.score(&loss, &gnorm, &w, 1, -0.5, true).unwrap();
            let r = bench("score kernel (XLA, pallas) B=128", ms(200), || {
                std::hint::black_box(engine.score(&loss, &gnorm, &w, 1, -0.5, true).unwrap());
            });
            print_results("selection scoring on the L1 kernel", &[r]);
        } else {
            println!("(artifacts missing — skipping XLA score kernel bench)");
        }
    }
}

//! Data-pipeline benchmarks: batch gather cost and streaming-loader
//! throughput across worker counts (prefetch + backpressure + reorder).
//! Target (DESIGN.md §9): the loader must sustain ≥ 2× the trainer's batch
//! rate so the compute backend never starves.
//!
//! `cargo bench -- --test` runs one-iteration smoke mode (CI).

use adaselection::data;
use adaselection::pipeline::{gather, Loader, LoaderConfig};
use adaselection::util::bench::{bench, print_results, write_json, BenchResult};
use adaselection::util::timer::Stopwatch;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let ms = |full: u64| if smoke { 1 } else { full };
    let scale = if smoke { 0.02 } else { 0.1 };
    let split = data::build("cifar10", 3, scale).unwrap(); // 5000 imgs at 0.1
    let ds = split.train;
    let idx: Vec<usize> = (0..128).collect();

    let mut results: Vec<BenchResult> = Vec::new();
    results.push(bench("gather 128x16x16x3 batch", ms(80), || {
        std::hint::black_box(gather(&ds, &idx, 128, 0, 0));
    }));
    let b = gather(&ds, &idx, 128, 0, 0);
    let rows: Vec<usize> = (0..26).collect();
    results.push(bench("gather_rows 26-of-128 sub-batch", ms(50), || {
        std::hint::black_box(b.gather_rows(&rows));
    }));
    print_results("batch assembly", &results);

    println!("\n## loader throughput (2 epochs x {} samples, B=128)", ds.len());
    println!("{:<34} {:>12} {:>14}", "config", "batches", "batches/s");
    for workers in [0usize, 1, 2, 4, 8] {
        let cfg = LoaderConfig {
            batch_size: 128,
            epochs: 2,
            seed: 1,
            workers,
            capacity: 8,
            drop_last: true,
        };
        let mut loader = Loader::start(ds.clone(), &cfg);
        let sw = Stopwatch::new();
        let mut n = 0usize;
        while let Some(batch) = loader.next_batch() {
            std::hint::black_box(&batch);
            n += 1;
        }
        let dt = sw.elapsed_secs();
        println!(
            "{:<34} {:>12} {:>14.1}",
            format!("workers={workers} capacity=8"),
            n,
            n as f64 / dt
        );
        let per_batch_ns = dt * 1e9 / n.max(1) as f64;
        results.push(BenchResult {
            name: format!("loader throughput workers={workers} (per batch)"),
            iters: n,
            median_ns: per_batch_ns,
            p95_ns: per_batch_ns,
            mean_ns: per_batch_ns,
        });
    }
    write_json("pipeline", &results).expect("write BENCH_pipeline.json");

    // consumer-limited regime: loader must keep the buffer full under a
    // slow trainer (simulated 2ms/step; skipped in smoke mode)
    if !smoke {
        println!("\n## prefetch under slow consumer (2 ms simulated train step)");
        for workers in [0usize, 2] {
            let cfg = LoaderConfig {
                batch_size: 128,
                epochs: 1,
                seed: 1,
                workers,
                capacity: 8,
                drop_last: true,
            };
            let mut loader = Loader::start(ds.clone(), &cfg);
            let sw = Stopwatch::new();
            let mut wait = 0.0f64;
            loop {
                let t = Stopwatch::new();
                let r = loader.next_batch();
                wait += t.elapsed_secs();
                match r {
                    Some(b) => {
                        std::hint::black_box(&b);
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    None => break,
                }
            }
            println!(
                "workers={workers}: total={:.3}s, time blocked on loader={:.3}s ({:.1}%), \
                 buffered high-watermark={}",
                sw.elapsed_secs(),
                wait,
                100.0 * wait / sw.elapsed_secs(),
                loader.buffered_high_watermark()
            );
        }
    }
}

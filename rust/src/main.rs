//! `adaselection` binary: the L3 leader entrypoint.
//!
//! See `adaselection help` (cli::USAGE) for the command surface.

use std::path::PathBuf;

use adaselection::cli::{Args, USAGE};
use adaselection::config::{ClusterConfig, RunConfig, StreamConfig};
use adaselection::harness::{registry, run_experiment, SweepOptions};
use adaselection::metrics::csv::CsvTable;
use adaselection::runtime::{default_artifacts_dir, Manifest};
use adaselection::util::logging;
use adaselection::{cluster, data, harness, stream, train};

fn main() {
    logging::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.command.as_str() {
        "train" => cmd_train(args),
        "stream" => cmd_stream(args),
        "cluster" => cmd_cluster(args),
        "worker" => cmd_worker(args),
        "sweep" => cmd_sweep(args),
        "list-experiments" => {
            println!("{:<20} {:<12} description", "id", "paper");
            for e in registry() {
                println!("{:<20} {:<12} {}", e.id, e.paper_ref, e.description);
            }
            Ok(())
        }
        "inspect-artifacts" => cmd_inspect(args),
        "gen-data" => cmd_gen_data(args),
        "bench-diff" => cmd_bench_diff(args),
        "trace-analyze" => cmd_trace_analyze(args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn build_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    for (k, v) in &args.flags {
        if k == "config" || k == "out" {
            continue;
        }
        cfg.apply_override(k, v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    println!("config: {}", cfg.to_json());
    let result = train::run(cfg)?;
    println!(
        "\nresult: selector={} dataset={} γ={:.2}",
        result.selector, result.dataset, result.gamma
    );
    for e in &result.epochs {
        println!(
            "  epoch {:>2}: train_loss={:.4} test_loss={:.4} test_acc={} time={:.2}s",
            e.epoch,
            e.train_loss,
            e.test_loss,
            if e.test_acc.is_nan() {
                "-".to_string()
            } else {
                format!("{:.4}", e.test_acc)
            },
            e.train_time_s
        );
    }
    println!("  phases: {}", result.phases.summary());
    if let Some(out) = args.flag("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        harness::report::runs_table(std::slice::from_ref(&result))
            .save(&dir.join("run.csv"))?;
        if !result.weight_trace.is_empty() {
            harness::report::weight_trace_table(&result).save(&dir.join("weights.csv"))?;
        }
        println!("wrote {out}/run.csv");
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => StreamConfig::from_file(std::path::Path::new(path))?,
        None => StreamConfig::default(),
    };
    for (k, v) in &args.flags {
        if k == "config" || k == "out" {
            continue;
        }
        cfg.apply_override(k, v)?;
    }
    cfg.validate()?;
    println!("config: {}", cfg.to_json());
    let r = stream::run(cfg)?;
    println!(
        "\nstream result: selector={} dataset={} γ={:.2} ticks={}",
        r.selector, r.dataset, r.gamma, r.ticks
    );
    println!(
        "  seen={} trained={} ({:.0} samples/s)",
        r.samples_seen, r.samples_trained, r.samples_per_sec
    );
    println!(
        "  rolling: loss={:.4} acc={}",
        r.final_rolling_loss,
        if r.final_rolling_acc.is_nan() {
            "-".to_string()
        } else {
            format!("{:.4}", r.final_rolling_acc)
        }
    );
    let c = r.store_counters;
    println!(
        "  store: {}/{} live, hits={} misses={} evictions={}",
        r.store_len, r.store_capacity, c.hits, c.misses, c.evictions
    );
    if r.samples_replayed > 0 || r.drift_detections > 0 {
        println!(
            "  replayed={} drift_detections={}",
            r.samples_replayed, r.drift_detections
        );
    }
    if let Some(w) = &r.weights {
        println!(
            "  method weights: {:?}",
            w.iter().map(|x| format!("{x:.3}")).collect::<Vec<_>>()
        );
    }
    println!("  phases: {}", r.phases.summary());
    if let Some(out) = args.flag("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        let mut t = CsvTable::new(vec!["tick", "rolling_loss", "rolling_acc"]);
        for p in &r.rolling {
            t.push(vec![
                p.tick.to_string(),
                format!("{:.6}", p.loss),
                if p.acc.is_nan() { String::new() } else { format!("{:.6}", p.acc) },
            ]);
        }
        t.save(&dir.join("stream_rolling.csv"))?;
        println!("wrote {out}/stream_rolling.csv");
    }
    Ok(())
}

/// The body of a cluster worker process: spawned by the `--workers
/// processes` coordinator, or started by hand on any machine to register
/// with a coordinator listening via `--listen`. Without `--node-id` the
/// worker registers unassigned and adopts whatever id the coordinator
/// hands it (possibly waiting as an elastic standby).
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let addr = args
        .flag("coordinator")
        .ok_or_else(|| anyhow::anyhow!("worker requires --coordinator HOST:PORT"))?;
    let node: Option<usize> = match args.flag("node-id") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    cluster::proc::run_worker(addr, node)
}

fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.flag("config") {
        Some(path) => ClusterConfig::from_file(std::path::Path::new(path))?,
        None => ClusterConfig::default(),
    };
    for (k, v) in &args.flags {
        if k == "config" || k == "out" {
            continue;
        }
        cfg.apply_override(k, v)?;
    }
    cfg.validate()?;
    println!("config: {}", cfg.to_json());
    let r = cluster::run(&cfg)?;
    println!(
        "\ncluster result: nodes={} ({}) ticks={} gossip_rounds={} merges={}",
        r.nodes_started, cfg.worker_mode, r.ticks, r.gossip_rounds, r.merges
    );
    println!(
        "  wire ({} transport, {} gossip): gossip={} KiB merge={} KiB",
        cfg.transport,
        cfg.gossip,
        r.gossip_bytes / 1024,
        r.merge_bytes / 1024
    );
    println!(
        "  seen={} trained={} replayed={} ({:.0} samples/s aggregate)",
        r.samples_seen, r.samples_trained, r.samples_replayed, r.samples_per_sec
    );
    println!(
        "  rolling: loss={:.4} acc={}",
        r.final_rolling_loss,
        if r.final_rolling_acc.is_nan() {
            "-".to_string()
        } else {
            format!("{:.4}", r.final_rolling_acc)
        }
    );
    if r.drift_detections > 0 {
        println!("  drift detections: {}", r.drift_detections);
    }
    for (tick, frac) in &r.remaps {
        println!("  churn @tick {tick}: {:.1}% of keys remapped", 100.0 * frac);
    }
    for n in &r.node_summaries {
        println!(
            "  node {}: ticks={} seen={} trained={} store={} {}",
            n.id,
            n.ticks_processed,
            n.samples_seen,
            n.samples_trained,
            n.store_len,
            if n.alive_at_end { "alive" } else { "killed" }
        );
    }
    print_phases(&r.phases);
    if let Some(out) = args.flag("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        let mut t = CsvTable::new(vec!["tick", "rolling_loss", "rolling_acc"]);
        for p in &r.rolling {
            t.push(vec![
                p.tick.to_string(),
                format!("{:.6}", p.loss),
                if p.acc.is_nan() { String::new() } else { format!("{:.6}", p.acc) },
            ]);
        }
        t.save(&dir.join("cluster_rolling.csv"))?;
        let mut nt = CsvTable::new(vec![
            "node", "ticks", "seen", "trained", "replayed", "store_live", "alive",
        ]);
        for n in &r.node_summaries {
            nt.push(vec![
                n.id.to_string(),
                n.ticks_processed.to_string(),
                n.samples_seen.to_string(),
                n.samples_trained.to_string(),
                n.samples_replayed.to_string(),
                n.store_len.to_string(),
                n.alive_at_end.to_string(),
            ]);
        }
        nt.save(&dir.join("cluster_nodes.csv"))?;
        println!("wrote {out}/cluster_rolling.csv and {out}/cluster_nodes.csv");
    }
    Ok(())
}

/// Phase timings live inside the worker processes in `--workers
/// processes` runs, so an empty timer means "not measured here", not
/// "everything was free".
fn print_phases(phases: &adaselection::util::timer::PhaseTimer) {
    if phases.grand_total_secs() > 0.0 {
        println!("  phases: {}", phases.summary());
    }
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let exp = args.flag_or("exp", "fig1");
    let mut opts = SweepOptions {
        backend: args.flag_or("backend", "native"),
        out_dir: PathBuf::from(args.flag_or("out", "results")),
        quick: args.has("quick"),
        ..SweepOptions::default()
    };
    if let Some(e) = args.flag("epochs") {
        opts.epochs = e.parse()?;
    }
    if let Some(s) = args.flag("data-scale") {
        opts.data_scale = s.parse()?;
    }
    if let Some(s) = args.flag("lr") {
        opts.lr = s.parse()?;
    }
    if let Some(s) = args.flag("seed") {
        opts.seed = s.parse()?;
    }
    if let Some(a) = args.flag("artifacts") {
        opts.artifacts_dir = PathBuf::from(a);
    }
    run_experiment(&exp, &opts)
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .flag("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let m = Manifest::load(&dir)?;
    println!("artifacts dir: {dir:?}");
    println!("method order: {:?}", m.method_order);
    println!("momentum: {}  γ grid: {:?}", m.momentum, m.gamma_grid);
    for (name, fam) in &m.families {
        let n_params: usize = fam
            .params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        println!(
            "family {name:<14} task={:?} B={} params={} tensors={} K grid={:?}",
            fam.task,
            fam.batch,
            n_params,
            fam.params.len(),
            fam.train_sizes
        );
    }
    println!("{} artifacts total", m.artifacts.len());
    Ok(())
}

/// CI perf gate: compare this run's `BENCH_*.json` files against a
/// baseline directory. A missing baseline directory passes trivially
/// (the first run of the gate has no previous artifact to fetch).
fn cmd_bench_diff(args: &Args) -> anyhow::Result<()> {
    let baseline = PathBuf::from(
        args.flag("baseline")
            .ok_or_else(|| anyhow::anyhow!("bench-diff requires --baseline DIR"))?,
    );
    let current = PathBuf::from(args.flag_or("current", "."));
    let tolerance: f64 = args.flag_or("tolerance", "0.15").parse()?;
    if !baseline.is_dir() {
        println!(
            "bench-diff: baseline {} not found; nothing to compare (pass)",
            baseline.display()
        );
        return Ok(());
    }
    let d = adaselection::util::bench::diff(&baseline, &current, tolerance)?;
    println!(
        "bench-diff: {} compared, {} unmatched, tolerance {:.0}%",
        d.compared.len(),
        d.unmatched.len(),
        tolerance * 100.0
    );
    for (bench, name, old, new) in &d.compared {
        println!(
            "  {:<44} {:>12} -> {:>12} ({:+.1}%)",
            format!("{bench}/{name}"),
            adaselection::util::bench::fmt_ns(*old),
            adaselection::util::bench::fmt_ns(*new),
            100.0 * (new - old) / old.max(1e-9)
        );
    }
    for key in &d.unmatched {
        println!("  {key}: not compared");
    }
    if !d.regressions.is_empty() {
        for (bench, name, old, new) in &d.regressions {
            eprintln!(
                "REGRESSION {bench}/{name}: median {} -> {} (>{:.0}% slower)",
                adaselection::util::bench::fmt_ns(*old),
                adaselection::util::bench::fmt_ns(*new),
                tolerance * 100.0
            );
        }
        // name the worst offender — with kernel/<name> rows in the bench
        // JSON this pins the regression to a specific backend kernel
        let worst = d
            .regressions
            .iter()
            .max_by(|a, b| (a.3 / a.2.max(1e-9)).total_cmp(&(b.3 / b.2.max(1e-9))))
            .expect("regressions is non-empty");
        anyhow::bail!(
            "bench-diff: {} benchmark(s) regressed past {:.0}%; worst is {}/{} ({} -> {}, {:+.1}%)",
            d.regressions.len(),
            tolerance * 100.0,
            worst.0,
            worst.1,
            adaselection::util::bench::fmt_ns(worst.2),
            adaselection::util::bench::fmt_ns(worst.3),
            100.0 * (worst.3 - worst.2) / worst.2.max(1e-9)
        );
    }
    println!("bench-diff: no regressions");
    Ok(())
}

/// Offline trace profiler: merge a run's journals (coordinator +
/// `PATH.node<i>`), validate every line, and emit the canonical report.
/// JSON goes to `--out FILE` or stdout; the human summary to stderr so
/// piping the JSON stays clean.
fn cmd_trace_analyze(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.positionals.is_empty(),
        "trace-analyze requires at least one journal path"
    );
    let paths: Vec<PathBuf> = args.positionals.iter().map(PathBuf::from).collect();
    let report = adaselection::obs::analyze::analyze_files(&paths)?;
    let json = report.to_string();
    match args.flag("out") {
        Some(out) => {
            std::fs::write(out, format!("{json}\n"))?;
            eprintln!("trace-analyze: wrote {out}");
        }
        None => println!("{json}"),
    }
    eprint!("{}", adaselection::obs::analyze::render_summary(&report));
    Ok(())
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let name = args.flag_or("dataset", "cifar10");
    let scale: f64 = args.flag_or("data-scale", "0.02").parse()?;
    let seed: u64 = args.flag_or("seed", "42").parse()?;
    let split = data::build(&name, seed, scale)?;
    split.train.validate()?;
    split.test.validate()?;
    println!(
        "dataset {name}: train={} test={} feat_shape={:?} task={:?}",
        split.train.len(),
        split.test.len(),
        split.train.feat_shape,
        split.train.task
    );
    Ok(())
}

//! Dataset substrate: task types, in-memory stores, and the three synthetic
//! generator families substituting for the paper's datasets (DESIGN.md §3).
//!
//! Everything is seeded and deterministic; generation happens in rust at
//! startup (no files, no network), and the pipeline layer streams batches
//! out of these stores.

pub mod images;
pub mod regression;
pub mod splits;
pub mod text;

/// What kind of learning task a dataset carries.
#[derive(Clone, Debug, PartialEq)]
pub enum Task {
    /// `classes` labels, image features.
    Classification { classes: usize },
    /// scalar targets.
    Regression,
    /// next-token prediction over `vocab` tokens, `seq` window length.
    Lm { vocab: usize, seq: usize },
}

impl Task {
    /// Whether the figure/table metric is accuracy (vs loss).
    pub fn metric_is_accuracy(&self) -> bool {
        matches!(self, Task::Classification { .. })
    }
}

/// Per-sample feature storage (contiguous, stride `feat_len`).
#[derive(Clone, Debug)]
pub enum XStore {
    F32 { data: Vec<f32>, stride: usize },
    I32 { data: Vec<i32>, stride: usize },
}

impl XStore {
    pub fn len(&self) -> usize {
        match self {
            XStore::F32 { data, stride } => data.len() / stride,
            XStore::I32 { data, stride } => data.len() / stride,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stride(&self) -> usize {
        match self {
            XStore::F32 { stride, .. } | XStore::I32 { stride, .. } => *stride,
        }
    }
}

/// Per-sample target storage.
#[derive(Clone, Debug)]
pub enum YStore {
    /// regression targets
    F32(Vec<f32>),
    /// class ids
    I32(Vec<i32>),
    /// per-token targets, stride `seq`
    Seq { data: Vec<i32>, stride: usize },
}

impl YStore {
    pub fn len(&self) -> usize {
        match self {
            YStore::F32(v) => v.len(),
            YStore::I32(v) => v.len(),
            YStore::Seq { data, stride } => data.len() / stride,
        }
    }
}

/// An in-memory dataset (one split).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    /// per-sample feature shape, e.g. `[16, 16, 3]`, `[8]`, `[32]`
    pub feat_shape: Vec<usize>,
    pub x: XStore,
    pub y: YStore,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Consistency check used by tests and at pipeline startup.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.x.len() == self.y.len(),
            "x/y length mismatch: {} vs {}",
            self.x.len(),
            self.y.len()
        );
        let expect: usize = self.feat_shape.iter().product();
        anyhow::ensure!(
            self.x.stride() == expect,
            "stride {} != feat_shape product {expect}",
            self.x.stride()
        );
        match (&self.task, &self.y) {
            (Task::Classification { classes }, YStore::I32(ys)) => {
                for &y in ys {
                    anyhow::ensure!(
                        y >= 0 && (y as usize) < *classes,
                        "label {y} out of range 0..{classes}"
                    );
                }
            }
            (Task::Regression, YStore::F32(ys)) => {
                anyhow::ensure!(
                    ys.iter().all(|v| v.is_finite()),
                    "non-finite regression target"
                );
            }
            (Task::Lm { vocab, seq }, YStore::Seq { data, stride }) => {
                anyhow::ensure!(stride == seq, "lm target stride mismatch");
                for &t in data {
                    anyhow::ensure!(
                        t >= 0 && (t as usize) < *vocab,
                        "token {t} out of range 0..{vocab}"
                    );
                }
            }
            (t, _) => anyhow::bail!("task/target storage mismatch for {t:?}"),
        }
        Ok(())
    }

    /// Dense copy of the given rows (same name/task/shape). Row indices
    /// must be in range.
    pub fn select_rows(&self, rows: &[usize]) -> Dataset {
        fn take<T: Copy>(data: &[T], stride: usize, rows: &[usize]) -> Vec<T> {
            let mut out = Vec::with_capacity(rows.len() * stride);
            for &r in rows {
                out.extend_from_slice(&data[r * stride..(r + 1) * stride]);
            }
            out
        }
        let x = match &self.x {
            XStore::F32 { data, stride } => XStore::F32 {
                data: take(data, *stride, rows),
                stride: *stride,
            },
            XStore::I32 { data, stride } => XStore::I32 {
                data: take(data, *stride, rows),
                stride: *stride,
            },
        };
        let y = match &self.y {
            YStore::F32(v) => YStore::F32(rows.iter().map(|&r| v[r]).collect()),
            YStore::I32(v) => YStore::I32(rows.iter().map(|&r| v[r]).collect()),
            YStore::Seq { data, stride } => YStore::Seq {
                data: take(data, *stride, rows),
                stride: *stride,
            },
        };
        Dataset {
            name: self.name.clone(),
            task: self.task.clone(),
            feat_shape: self.feat_shape.clone(),
            x,
            y,
        }
    }

    /// Append another dataset's rows (must share storage layout and
    /// stride; both sides come from the same source in practice).
    pub fn append(&mut self, other: &Dataset) {
        match (&mut self.x, &other.x) {
            (XStore::F32 { data: a, .. }, XStore::F32 { data: b, .. }) => a.extend_from_slice(b),
            (XStore::I32 { data: a, .. }, XStore::I32 { data: b, .. }) => a.extend_from_slice(b),
            _ => panic!("Dataset::append: feature storage mismatch"),
        }
        match (&mut self.y, &other.y) {
            (YStore::F32(a), YStore::F32(b)) => a.extend_from_slice(b),
            (YStore::I32(a), YStore::I32(b)) => a.extend_from_slice(b),
            (YStore::Seq { data: a, .. }, YStore::Seq { data: b, .. }) => a.extend_from_slice(b),
            _ => panic!("Dataset::append: target storage mismatch"),
        }
    }
}

/// A train/test pair produced by a generator.
#[derive(Clone, Debug)]
pub struct SplitDataset {
    pub train: Dataset,
    pub test: Dataset,
}

/// The registry of dataset builders keyed by the paper's dataset names.
pub fn build(name: &str, seed: u64, scale: f64) -> anyhow::Result<SplitDataset> {
    match name {
        "svhn" => Ok(images::synth_svhn(seed, scale)),
        "cifar10" => Ok(images::synth_cifar10(seed, scale)),
        "cifar100" => Ok(images::synth_cifar100(seed, scale)),
        "simple" => Ok(regression::simple_regression(seed, scale)),
        "bike" => Ok(regression::bike_synthetic(seed)),
        "wikitext" => Ok(text::markov_corpus(seed, scale)),
        other => anyhow::bail!(
            "unknown dataset '{other}' (expected svhn|cifar10|cifar100|simple|bike|wikitext)"
        ),
    }
}

/// All dataset names, in the paper's Table-2 order.
pub const ALL_DATASETS: [&str; 6] = ["cifar10", "cifar100", "svhn", "simple", "bike", "wikitext"];

/// Which model family serves each dataset (manifest key).
pub fn family_for(name: &str) -> anyhow::Result<&'static str> {
    Ok(match name {
        "svhn" | "cifar10" => "resnet_c10",
        "cifar100" => "resnet_c100",
        "simple" => "mlp_simple",
        "bike" => "mlp_bike",
        "wikitext" => "transformer",
        other => anyhow::bail!("unknown dataset '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_all_datasets() {
        for name in ALL_DATASETS {
            let ds = build(name, 7, 0.05).unwrap();
            ds.train.validate().unwrap();
            ds.test.validate().unwrap();
            assert!(ds.train.len() > 0, "{name}");
            assert!(ds.test.len() > 0, "{name}");
            family_for(name).unwrap();
        }
    }

    #[test]
    fn select_rows_and_append_round_trip() {
        let ds = build("simple", 3, 0.01).unwrap().train;
        let a = ds.select_rows(&[0, 2, 4]);
        let b = ds.select_rows(&[1, 3]);
        assert_eq!(a.len(), 3);
        a.validate().unwrap();
        let mut joined = a.clone();
        joined.append(&b);
        assert_eq!(joined.len(), 5);
        joined.validate().unwrap();
        // row 3 of the join is row 1 of the original
        let (XStore::F32 { data: dj, stride }, XStore::F32 { data: d0, .. }) =
            (&joined.x, &ds.x)
        else {
            panic!("expected f32 stores");
        };
        assert_eq!(&dj[3 * stride..4 * stride], &d0[*stride..2 * stride]);
        assert!(ds.select_rows(&[]).is_empty());
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(build("mnist", 0, 1.0).is_err());
        assert!(family_for("mnist").is_err());
    }

    #[test]
    fn generation_is_deterministic() {
        let a = build("cifar10", 3, 0.02).unwrap();
        let b = build("cifar10", 3, 0.02).unwrap();
        match (&a.train.x, &b.train.x) {
            (XStore::F32 { data: da, .. }, XStore::F32 { data: db, .. }) => {
                assert_eq!(da, db)
            }
            _ => panic!("expected f32 stores"),
        }
        let c = build("cifar10", 4, 0.02).unwrap();
        match (&a.train.x, &c.train.x) {
            (XStore::F32 { data: da, .. }, XStore::F32 { data: dc, .. }) => {
                assert_ne!(da, dc)
            }
            _ => panic!("expected f32 stores"),
        }
    }
}

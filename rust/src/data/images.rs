//! Procedural image-classification generator (CIFAR/SVHN substitutes).
//!
//! Each class gets a *template*: a mixture of seeded 2-D sinusoids per
//! channel. A sample is its class template plus per-sample Gaussian noise
//! whose scale is drawn from difficulty *tiers* (easy / medium / hard), with
//! a fraction of label-noise flips and pure-noise outliers. This reproduces
//! the loss-distribution properties the selection methods differentiate on
//! (DESIGN.md §3): heavy-tailed losses, easy/hard sub-populations, and the
//! noisy-label regime where Big Loss collapses (the paper's SVHN result).

use super::{Dataset, SplitDataset, Task, XStore, YStore};
use crate::util::rng::Pcg64;

/// Knobs for the synthetic image task.
#[derive(Clone, Debug)]
pub struct ImageSynthConfig {
    pub name: String,
    pub classes: usize,
    pub size: usize,
    pub train: usize,
    pub test: usize,
    /// fraction of training labels flipped uniformly at random
    pub label_noise: f64,
    /// fraction of training samples replaced by pure noise
    pub outlier_frac: f64,
    /// (probability, noise σ) difficulty tiers; probabilities sum to 1
    pub tiers: Vec<(f64, f64)>,
    pub seed: u64,
}

impl ImageSynthConfig {
    fn feat_len(&self) -> usize {
        self.size * self.size * 3
    }
}

/// SVHN substitute: noisy digits — high label noise + many outliers.
pub fn synth_svhn(seed: u64, scale: f64) -> SplitDataset {
    generate(&ImageSynthConfig {
        name: "svhn".into(),
        classes: 10,
        size: 16,
        train: scaled(73_257, scale),
        test: scaled(26_032, scale),
        label_noise: 0.10,
        outlier_frac: 0.05,
        tiers: vec![(0.5, 0.4), (0.3, 0.8), (0.2, 1.3)],
        seed,
    })
}

/// CIFAR10 substitute: clean labels, moderate difficulty spread.
pub fn synth_cifar10(seed: u64, scale: f64) -> SplitDataset {
    generate(&ImageSynthConfig {
        name: "cifar10".into(),
        classes: 10,
        size: 16,
        train: scaled(50_000, scale),
        test: scaled(10_000, scale),
        label_noise: 0.02,
        outlier_frac: 0.01,
        tiers: vec![(0.6, 0.35), (0.3, 0.7), (0.1, 1.1)],
        seed,
    })
}

/// CIFAR100 substitute: 100 classes (tighter template spacing ⇒ harder).
pub fn synth_cifar100(seed: u64, scale: f64) -> SplitDataset {
    generate(&ImageSynthConfig {
        name: "cifar100".into(),
        classes: 100,
        size: 16,
        train: scaled(50_000, scale),
        test: scaled(10_000, scale),
        label_noise: 0.02,
        outlier_frac: 0.01,
        tiers: vec![(0.6, 0.3), (0.3, 0.6), (0.1, 1.0)],
        seed,
    })
}

fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).round() as usize).max(64)
}

/// One class template: sum of `n_waves` random sinusoids per channel.
fn class_template(rng: &mut Pcg64, size: usize) -> Vec<f32> {
    let n_waves = 4;
    let mut tpl = vec![0.0f32; size * size * 3];
    for c in 0..3 {
        for _ in 0..n_waves {
            let fx = rng.uniform(0.5, 3.0);
            let fy = rng.uniform(0.5, 3.0);
            let phase = rng.uniform(0.0, std::f64::consts::TAU);
            let amp = rng.uniform(0.3, 1.0);
            for yy in 0..size {
                for xx in 0..size {
                    let v = amp
                        * (std::f64::consts::TAU
                            * (fx * xx as f64 / size as f64
                                + fy * yy as f64 / size as f64)
                            + phase)
                            .sin();
                    tpl[(yy * size + xx) * 3 + c] += v as f32;
                }
            }
        }
    }
    tpl
}

/// Generate a full train/test split from the config.
pub fn generate(cfg: &ImageSynthConfig) -> SplitDataset {
    let mut rng = Pcg64::new(cfg.seed ^ 0x1111_2222_3333_4444);
    let templates: Vec<Vec<f32>> = (0..cfg.classes)
        .map(|_| class_template(&mut rng, cfg.size))
        .collect();

    let gen_split = |n: usize, with_noise: bool, rng: &mut Pcg64| {
        let feat_len = cfg.feat_len();
        let mut xs = vec![0.0f32; n * feat_len];
        let mut ys = vec![0i32; n];
        for i in 0..n {
            let true_class = rng.next_below(cfg.classes as u64) as usize;
            let outlier = with_noise && rng.next_f64() < cfg.outlier_frac;
            let sigma = if outlier {
                2.0
            } else {
                let r = rng.next_f64();
                let mut acc = 0.0;
                let mut sel = cfg.tiers[cfg.tiers.len() - 1].1;
                for &(p, s) in &cfg.tiers {
                    acc += p;
                    if r < acc {
                        sel = s;
                        break;
                    }
                }
                sel
            };
            let x = &mut xs[i * feat_len..(i + 1) * feat_len];
            if outlier {
                for v in x.iter_mut() {
                    *v = rng.normal_ms(0.0, sigma) as f32;
                }
            } else {
                let tpl = &templates[true_class];
                for (v, &t) in x.iter_mut().zip(tpl.iter()) {
                    *v = t + rng.normal_ms(0.0, sigma) as f32;
                }
            }
            let label = if with_noise && rng.next_f64() < cfg.label_noise {
                rng.next_below(cfg.classes as u64) as i32
            } else {
                true_class as i32
            };
            ys[i] = label;
        }
        (xs, ys)
    };

    let (train_x, train_y) = gen_split(cfg.train, true, &mut rng);
    let (test_x, test_y) = gen_split(cfg.test, false, &mut rng);

    let make = |x: Vec<f32>, y: Vec<i32>, suffix: &str| Dataset {
        name: format!("{}-{suffix}", cfg.name),
        task: Task::Classification {
            classes: cfg.classes,
        },
        feat_shape: vec![cfg.size, cfg.size, 3],
        x: XStore::F32 {
            data: x,
            stride: cfg.feat_len(),
        },
        y: YStore::I32(y),
    };

    SplitDataset {
        train: make(train_x, train_y, "train"),
        test: make(test_x, test_y, "test"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn tiny_cfg() -> ImageSynthConfig {
        ImageSynthConfig {
            name: "t".into(),
            classes: 5,
            size: 8,
            train: 400,
            test: 100,
            label_noise: 0.1,
            outlier_frac: 0.05,
            tiers: vec![(0.6, 0.3), (0.4, 1.0)],
            seed: 5,
        }
    }

    #[test]
    fn shapes_and_validity() {
        let ds = generate(&tiny_cfg());
        ds.train.validate().unwrap();
        ds.test.validate().unwrap();
        assert_eq!(ds.train.len(), 400);
        assert_eq!(ds.test.len(), 100);
        assert_eq!(ds.train.feat_shape, vec![8, 8, 3]);
    }

    #[test]
    fn all_classes_present() {
        let ds = generate(&tiny_cfg());
        if let YStore::I32(ys) = &ds.train.y {
            let mut seen = vec![false; 5];
            for &y in ys {
                seen[y as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
        } else {
            panic!("wrong label store");
        }
    }

    #[test]
    fn templates_are_separable() {
        // same-class samples must be closer to their template than to others
        // (on average), otherwise the classification task is vacuous.
        let cfg = tiny_cfg();
        let mut rng = Pcg64::new(cfg.seed ^ 0x1111_2222_3333_4444);
        let t0 = class_template(&mut rng, cfg.size);
        let t1 = class_template(&mut rng, cfg.size);
        let d: f32 = t0
            .iter()
            .zip(&t1)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        assert!(d > 1.0, "templates nearly identical: {d}");
    }

    #[test]
    fn pixel_distribution_is_bounded() {
        let ds = generate(&tiny_cfg());
        if let XStore::F32 { data, .. } = &ds.train.x {
            assert!(data.iter().all(|v| v.is_finite()));
            let m = stats::mean(data);
            assert!(m.abs() < 0.5, "mean={m}");
        }
    }

    #[test]
    fn test_split_has_clean_labels() {
        // test split applies no label noise / outliers: repeated generation
        // with the same seed but label_noise=0 must give identical test labels
        let mut cfg = tiny_cfg();
        let a = generate(&cfg);
        cfg.label_noise = 0.5; // train-only knob
        let b = generate(&cfg);
        match (&a.test.y, &b.test.y) {
            (YStore::I32(ya), YStore::I32(yb)) => {
                // label-noise draws shift the rng stream, so just check the
                // test sets are valid and same-sized rather than identical
                assert_eq!(ya.len(), yb.len());
            }
            _ => panic!(),
        }
    }
}

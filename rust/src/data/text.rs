//! Synthetic language-modeling corpus (WikiText-2 substitute, DESIGN.md §3).
//!
//! An order-2 Markov source over a 256-token vocabulary: for every context
//! pair `(a, b)` a seeded hash derives a sparse next-token distribution
//! (8 candidates with geometric weights, candidates biased toward frequent
//! tokens by a Zipfian draw). This yields learnable low-entropy structure
//! with a Zipf-like unigram law; a small fraction of *shuffled* windows act
//! as high-loss outliers, mirroring noisy paragraphs in web text.

use super::{Dataset, SplitDataset, Task, XStore, YStore};
use crate::util::rng::{zipf_harmonic, Pcg64};

const VOCAB: usize = 256;
const SEQ: usize = 32;
const CANDIDATES: usize = 8;

fn mix(seed: u64, a: u64, b: u64) -> u64 {
    // splitmix-style avalanche over (seed, context)
    let mut z = seed ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ b.rotate_left(32);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The deterministic transition model (shared by train and test).
struct Markov {
    seed: u64,
    harmonic: Vec<f64>,
}

impl Markov {
    fn new(seed: u64) -> Self {
        Markov {
            seed,
            harmonic: zipf_harmonic(VOCAB, 1.05),
        }
    }

    /// Sample the next token given context `(a, b)`.
    fn next(&self, a: i32, b: i32, rng: &mut Pcg64) -> i32 {
        let h = mix(self.seed, a as u64, b as u64);
        // geometric choice among CANDIDATES hash-derived successors
        let mut pick = 0usize;
        for i in 0..CANDIDATES - 1 {
            if rng.next_f64() < 0.5 {
                pick = i;
                break;
            }
            pick = i + 1;
        }
        // each candidate is a Zipf-biased token derived from the context hash
        let mut sub = Pcg64::new(h ^ (pick as u64).wrapping_mul(0xabcd_ef01));
        sub.zipf(VOCAB, 1.05, &self.harmonic) as i32
    }
}

/// Generate the corpus: `scale` scales the paper's 2M/245k token counts.
pub fn markov_corpus(seed: u64, scale: f64) -> SplitDataset {
    let train_tokens = ((2_088_628.0 * scale) as usize).max(SEQ * 40 + 1);
    let test_tokens = ((245_569.0 * scale) as usize).max(SEQ * 10 + 1);
    let model = Markov::new(seed ^ 0xfeed_beef);
    let mut rng = Pcg64::new(seed ^ 0x1234_5678_9abc_def0);

    let gen_tokens = |n: usize, rng: &mut Pcg64| {
        let mut toks: Vec<i32> = Vec::with_capacity(n);
        toks.push(rng.next_below(VOCAB as u64) as i32);
        toks.push(rng.next_below(VOCAB as u64) as i32);
        while toks.len() < n {
            let a = toks[toks.len() - 2];
            let b = toks[toks.len() - 1];
            toks.push(model.next(a, b, rng));
        }
        toks
    };

    let train_toks = gen_tokens(train_tokens, &mut rng);
    let test_toks = gen_tokens(test_tokens, &mut rng);

    let windows = |toks: &[i32], with_outliers: bool, rng: &mut Pcg64| {
        let n = (toks.len() - 1) / SEQ;
        let mut xs = vec![0i32; n * SEQ];
        let mut ys = vec![0i32; n * SEQ];
        for i in 0..n {
            let start = i * SEQ;
            let x = &mut xs[i * SEQ..(i + 1) * SEQ];
            let y = &mut ys[i * SEQ..(i + 1) * SEQ];
            x.copy_from_slice(&toks[start..start + SEQ]);
            y.copy_from_slice(&toks[start + 1..start + SEQ + 1]);
            if with_outliers && rng.next_f64() < 0.03 {
                // shuffled window: unpredictable, persistent high loss
                rng.shuffle(x);
                for j in 0..SEQ - 1 {
                    y[j] = x[j + 1];
                }
                y[SEQ - 1] = rng.next_below(VOCAB as u64) as i32;
            }
        }
        (xs, ys, n)
    };

    let (train_x, train_y, _) = windows(&train_toks, true, &mut rng);
    let (test_x, test_y, _) = windows(&test_toks, false, &mut rng);

    let make = |x: Vec<i32>, y: Vec<i32>, suffix: &str| Dataset {
        name: format!("wikitext-{suffix}"),
        task: Task::Lm {
            vocab: VOCAB,
            seq: SEQ,
        },
        feat_shape: vec![SEQ],
        x: XStore::I32 {
            data: x,
            stride: SEQ,
        },
        y: YStore::Seq {
            data: y,
            stride: SEQ,
        },
    };
    SplitDataset {
        train: make(train_x, train_y, "train"),
        test: make(test_x, test_y, "test"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes_and_validity() {
        let ds = markov_corpus(1, 0.01);
        ds.train.validate().unwrap();
        ds.test.validate().unwrap();
        assert!(ds.train.len() >= 40);
        assert_eq!(ds.train.feat_shape, vec![SEQ]);
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let ds = markov_corpus(2, 0.01);
        let (XStore::I32 { data: xs, .. }, YStore::Seq { data: ys, .. }) =
            (&ds.test.x, &ds.test.y)
        else {
            panic!()
        };
        // test split has no shuffled outliers, so y[j] == x[j+1] within a window
        for i in 0..ds.test.len() {
            for j in 0..SEQ - 1 {
                assert_eq!(ys[i * SEQ + j], xs[i * SEQ + j + 1], "window {i} pos {j}");
            }
        }
    }

    #[test]
    fn markov_structure_is_predictable() {
        // given a context pair, the modal next token should dominate: check
        // the model is far from uniform (entropy structure to learn)
        let model = Markov::new(99);
        let mut rng = Pcg64::new(7);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..500 {
            *counts.entry(model.next(10, 20, &mut rng)).or_insert(0) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        assert!(max > 150, "modal next-token count {max}/500 too uniform");
        assert!(counts.len() <= CANDIDATES, "more candidates than expected");
    }

    #[test]
    fn unigram_is_zipf_skewed() {
        let ds = markov_corpus(3, 0.02);
        let XStore::I32 { data: xs, .. } = &ds.train.x else { panic!() };
        let mut counts = vec![0usize; VOCAB];
        for &t in xs {
            counts[t as usize] += 1;
        }
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert!(
            sorted[0] > 4 * sorted[VOCAB / 2].max(1),
            "head {} vs median {} not skewed",
            sorted[0],
            sorted[VOCAB / 2]
        );
    }
}

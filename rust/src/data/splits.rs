//! Deterministic index bookkeeping: epoch shuffles and stratified helpers.

use crate::util::rng::Pcg64;

/// A reshuffled-every-epoch view over `0..n`.
#[derive(Clone, Debug)]
pub struct EpochShuffler {
    n: usize,
    rng: Pcg64,
}

impl EpochShuffler {
    pub fn new(n: usize, seed: u64) -> Self {
        EpochShuffler {
            n,
            rng: Pcg64::new(seed ^ 0xe90c_51a7),
        }
    }

    /// A fresh permutation for the next epoch.
    pub fn next_epoch(&mut self) -> Vec<usize> {
        self.rng.permutation(self.n)
    }
}

/// Split `0..n` into `shards` contiguous chunks balanced within ±1.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<std::ops::Range<usize>> {
    assert!(shards > 0);
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_shuffles_are_permutations_and_differ() {
        let mut sh = EpochShuffler::new(50, 1);
        let e1 = sh.next_epoch();
        let e2 = sh.next_epoch();
        let mut s1 = e1.clone();
        s1.sort_unstable();
        assert_eq!(s1, (0..50).collect::<Vec<_>>());
        assert_ne!(e1, e2);
    }

    #[test]
    fn same_seed_same_schedule() {
        let mut a = EpochShuffler::new(20, 9);
        let mut b = EpochShuffler::new(20, 9);
        assert_eq!(a.next_epoch(), b.next_epoch());
        assert_eq!(a.next_epoch(), b.next_epoch());
    }

    #[test]
    fn shards_cover_exactly() {
        for n in [0usize, 1, 7, 100] {
            for s in [1usize, 2, 3, 7] {
                let ranges = shard_ranges(n, s);
                assert_eq!(ranges.len(), s);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                // contiguous and balanced
                let mut prev_end = 0;
                for r in &ranges {
                    assert_eq!(r.start, prev_end);
                    prev_end = r.end;
                }
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let min = lens.iter().min().unwrap();
                let max = lens.iter().max().unwrap();
                assert!(max - min <= 1);
            }
        }
    }
}

//! Regression dataset generators: the paper's `y = 2x + 1` simple task and
//! the bike-sharing substitute (DESIGN.md §3).
//!
//! Both generators plant a small fraction of high-leverage outliers — the
//! regime where Small Loss is robust and Big Loss chases corrupted targets
//! (the paper's Fig 5/6 finding).

use super::{Dataset, SplitDataset, Task, XStore, YStore};
use crate::util::rng::Pcg64;

/// Paper's simple regression: y = 2x + 1 + ε, 10 000 train + 5 000 test.
pub fn simple_regression(seed: u64, scale: f64) -> SplitDataset {
    let train_n = ((10_000.0 * scale.max(0.05)).round() as usize).max(200);
    let test_n = ((5_000.0 * scale.max(0.05)).round() as usize).max(100);
    let mut rng = Pcg64::new(seed ^ 0x5151_6262_7373_8484);

    let gen = |n: usize, with_outliers: bool, rng: &mut Pcg64| {
        let mut xs = vec![0.0f32; n];
        let mut ys = vec![0.0f32; n];
        for i in 0..n {
            let x = rng.uniform(-3.0, 3.0);
            let noise = if with_outliers && rng.next_f64() < 0.05 {
                rng.normal_ms(0.0, 8.0) // corrupted target
            } else {
                rng.normal_ms(0.0, 0.5)
            };
            xs[i] = x as f32;
            ys[i] = (2.0 * x + 1.0 + noise) as f32;
        }
        (xs, ys)
    };

    let (train_x, train_y) = gen(train_n, true, &mut rng);
    let (test_x, test_y) = gen(test_n, false, &mut rng);

    let make = |x: Vec<f32>, y: Vec<f32>, suffix: &str| Dataset {
        name: format!("simple-{suffix}"),
        task: Task::Regression,
        feat_shape: vec![1],
        x: XStore::F32 { data: x, stride: 1 },
        y: YStore::F32(y),
    };
    SplitDataset {
        train: make(train_x, train_y, "train"),
        test: make(test_x, test_y, "test"),
    }
}

/// Bike-sharing substitute: 730 daily rows with seasonal + weekly structure
/// and count-like heteroscedastic noise plus storm-day outliers.
///
/// Features (8): [sin_doy, cos_doy, workingday, temp, humidity, windspeed,
/// weathersit, holiday]; target: daily rental count scaled to ~[0, 10].
pub fn bike_synthetic(seed: u64) -> SplitDataset {
    const DAYS: usize = 730;
    const FEAT: usize = 8;
    let mut rng = Pcg64::new(seed ^ 0x9a9a_8b8b_7c7c_6d6d);

    let mut xs = vec![0.0f32; DAYS * FEAT];
    let mut ys = vec![0.0f32; DAYS];
    for day in 0..DAYS {
        let doy = (day % 365) as f64;
        let phase = std::f64::consts::TAU * doy / 365.0;
        let sin_doy = phase.sin();
        let cos_doy = phase.cos();
        let dow = day % 7;
        let workingday = if dow < 5 { 1.0 } else { 0.0 };
        let holiday = if rng.next_f64() < 0.03 { 1.0 } else { 0.0 };
        // temperature follows the season with daily jitter
        let temp = 0.5 - 0.35 * cos_doy + rng.normal_ms(0.0, 0.08);
        let humidity = (0.6 + 0.15 * sin_doy + rng.normal_ms(0.0, 0.1)).clamp(0.0, 1.0);
        let windspeed = (0.2 + rng.normal_ms(0.0, 0.08)).clamp(0.0, 1.0).abs();
        // weather: 0 clear / 1 misty / 2 storm — storms are rare
        let r = rng.next_f64();
        let weathersit = if r < 0.65 {
            0.0
        } else if r < 0.92 {
            1.0
        } else {
            2.0
        };

        // count model: season + weekday + weather effects (the structure a
        // 2-layer MLP can learn), count-like noise growing with the mean
        let base = 4.5 + 3.0 * temp - 1.2 * humidity - 0.8 * windspeed
            + 0.6 * workingday
            - 1.5 * weathersit
            - 0.7 * holiday;
        let mut y = base + rng.normal_ms(0.0, 0.15 * base.abs().max(0.5));
        if weathersit > 1.5 && rng.next_f64() < 0.5 {
            // storm-day collapse: high-leverage outlier
            y *= rng.uniform(0.05, 0.3);
        }
        let x = &mut xs[day * FEAT..(day + 1) * FEAT];
        x.copy_from_slice(&[
            sin_doy as f32,
            cos_doy as f32,
            workingday as f32,
            temp as f32,
            humidity as f32,
            windspeed as f32,
            weathersit as f32,
            holiday as f32,
        ]);
        ys[day] = y.max(0.0) as f32;
    }

    // random 80/20 split (paper reports "730 in total")
    let perm = Pcg64::new(seed ^ 0x0f0f).permutation(DAYS);
    let n_test = DAYS / 5;
    let mut train_x = Vec::with_capacity((DAYS - n_test) * FEAT);
    let mut train_y = Vec::with_capacity(DAYS - n_test);
    let mut test_x = Vec::with_capacity(n_test * FEAT);
    let mut test_y = Vec::with_capacity(n_test);
    for (rank, &i) in perm.iter().enumerate() {
        let row = &xs[i * FEAT..(i + 1) * FEAT];
        if rank < n_test {
            test_x.extend_from_slice(row);
            test_y.push(ys[i]);
        } else {
            train_x.extend_from_slice(row);
            train_y.push(ys[i]);
        }
    }

    let make = |x: Vec<f32>, y: Vec<f32>, suffix: &str| Dataset {
        name: format!("bike-{suffix}"),
        task: Task::Regression,
        feat_shape: vec![FEAT],
        x: XStore::F32 {
            data: x,
            stride: FEAT,
        },
        y: YStore::F32(y),
    };
    SplitDataset {
        train: make(train_x, train_y, "train"),
        test: make(test_x, test_y, "test"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn simple_line_is_recoverable() {
        let ds = simple_regression(1, 0.1);
        ds.train.validate().unwrap();
        // least-squares fit on the clean test split recovers slope≈2, b≈1
        let (XStore::F32 { data: xs, .. }, YStore::F32(ys)) = (&ds.test.x, &ds.test.y)
        else {
            panic!()
        };
        let n = xs.len() as f64;
        let mx: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
        let my: f64 = ys.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut sxy = 0.0;
        let mut sxx = 0.0;
        for (&x, &y) in xs.iter().zip(ys.iter()) {
            sxy += (x as f64 - mx) * (y as f64 - my);
            sxx += (x as f64 - mx) * (x as f64 - mx);
        }
        let slope = sxy / sxx;
        let intercept = my - slope * mx;
        assert!((slope - 2.0).abs() < 0.1, "slope={slope}");
        assert!((intercept - 1.0).abs() < 0.1, "intercept={intercept}");
    }

    #[test]
    fn simple_train_has_outliers_test_does_not() {
        let ds = simple_regression(2, 0.1);
        let resid = |d: &Dataset| -> Vec<f32> {
            let (XStore::F32 { data: xs, .. }, YStore::F32(ys)) = (&d.x, &d.y) else {
                panic!()
            };
            xs.iter()
                .zip(ys.iter())
                .map(|(&x, &y)| (y - (2.0 * x + 1.0)).abs())
                .collect()
        };
        let train_max = resid(&ds.train).iter().cloned().fold(0.0f32, f32::max);
        let test_max = resid(&ds.test).iter().cloned().fold(0.0f32, f32::max);
        assert!(train_max > 5.0, "train outliers missing: {train_max}");
        assert!(test_max < 5.0, "test should be clean: {test_max}");
    }

    #[test]
    fn bike_is_730_rows_with_8_features() {
        let ds = bike_synthetic(3);
        ds.train.validate().unwrap();
        ds.test.validate().unwrap();
        assert_eq!(ds.train.len() + ds.test.len(), 730);
        assert_eq!(ds.train.feat_shape, vec![8]);
    }

    #[test]
    fn bike_targets_nonnegative_and_seasonal() {
        let ds = bike_synthetic(4);
        let YStore::F32(ys) = &ds.train.y else { panic!() };
        assert!(ys.iter().all(|&y| y >= 0.0));
        assert!(stats::std(ys) > 0.3, "needs variance to be learnable");
    }

    #[test]
    fn bike_has_storm_outliers() {
        let ds = bike_synthetic(5);
        let YStore::F32(ys) = &ds.train.y else { panic!() };
        let m = stats::mean(ys);
        let frac_low = ys.iter().filter(|&&y| y < 0.3 * m).count() as f64
            / ys.len() as f64;
        assert!(
            frac_low > 0.01 && frac_low < 0.2,
            "storm outlier fraction {frac_low}"
        );
    }
}

//! The worker-process side of the multi-process cluster: a frame-driven
//! state machine around one [`ClusterNode`].
//!
//! Life cycle: connect to the coordinator, send `Hello`, receive `Assign`
//! (the full cluster config as JSON + this worker's node id and first
//! tick), then obey frames until `Shutdown`:
//!
//!   * `BarrierGo { round, until, gossip, merge, boot, churn }` — adopt
//!     the coordinator's barrier-round id (echoed into every journal
//!     line and reply frame), apply any
//!     crash-churn orders (ring epoch + backfill of the dead node's
//!     share), run the tick loop to `until`, then report `BarrierReady`
//!     (prequential records + running counters) followed by the ordered
//!     barrier payloads: a store-gossip snapshot/delta and/or the merge
//!     `State` material;
//!   * `StoreGossip` — merge a peer's entries freshest-tick-wins;
//!   * `MergePayload` — adopt the cluster-averaged model/policy state
//!     (merge barriers; also the join bootstrap).
//!
//! A side thread heartbeats twice a second so the coordinator can tell a
//! hung process from a long training segment. Any error is reported in
//! `BarrierReady::failed` (best effort) before the process exits nonzero
//! — a hard crash instead surfaces at the coordinator as a closed
//! connection and becomes churn.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::node::ClusterNode;
use crate::cluster::ring::NodeId;
use crate::cluster::trainer::{build_ring_schedule_with, make_engine, replay_budget};
use crate::cluster::transport::{
    ChurnOrder, Message, SharedTelemetry, GOSSIP_FULL, GOSSIP_NONE,
};
use crate::cluster::wire;
use crate::config::ClusterConfig;
use crate::obs::TraceJournal;
use crate::runtime::{Backend, NativeBackend};
use crate::stream::source::{build_source, StreamKnobs};
use crate::util::json::Json;

/// Heartbeat cadence of the side thread.
const HEARTBEAT_MS: u64 = 500;

/// Send one wire frame over the shared writer.
fn send_msg(writer: &Mutex<TcpStream>, msg: &Message) -> anyhow::Result<()> {
    wire::check_encodable(msg)?;
    let frame = wire::encode(msg);
    let mut w = writer.lock().unwrap();
    std::io::Write::write_all(&mut *w, &frame)?;
    std::io::Write::flush(&mut *w)?;
    Ok(())
}

/// Everything a worker derives from its `Assign`.
struct WorkerState {
    cfg: ClusterConfig,
    node: ClusterNode<NativeBackend>,
    /// unplanned kills applied so far — the schedule recompile input
    chaos: Vec<(u64, NodeId)>,
    /// per-worker trace journal (`--trace PATH` writes `PATH.node<id>`
    /// here — each process owns its own file, no cross-process locking)
    journal: Option<TraceJournal>,
}

impl WorkerState {
    /// Detach the trace sender from the node, then close the journal.
    /// Order matters: `finish()` joins the writer thread, which only
    /// exits once every sender is gone.
    fn finish_journal(&mut self) -> anyhow::Result<()> {
        self.node.detach_observer();
        if let Some(j) = self.journal.take() {
            j.finish()?;
        }
        Ok(())
    }
}

fn build_state(
    config_json: &str,
    node_id: NodeId,
    first_tick: u64,
    chaos: Vec<(u64, NodeId)>,
    telemetry: &Arc<SharedTelemetry>,
) -> anyhow::Result<WorkerState> {
    let cfg = ClusterConfig::from_json(
        &Json::parse(config_json).map_err(|e| anyhow::anyhow!("assign config: {e}"))?,
    )?;
    let s = &cfg.stream;
    anyhow::ensure!(
        s.backend == "native",
        "process workers are native-only (got backend '{}')",
        s.backend
    );
    let source = build_source(
        &s.dataset,
        StreamKnobs {
            seed: s.seed,
            drift_period: s.drift_period,
            burst_period: s.burst_period,
            burst_min: s.burst_min,
        },
    )?;
    let mut backend = NativeBackend::new();
    let meta = backend.family_meta(source.family())?;
    let b = meta.batch;
    let state = backend.init_state(&meta.name, s.seed as i32)?;
    let engine = make_engine(&cfg, node_id, b, replay_budget(&cfg, b))?;
    let (rings, _) = build_ring_schedule_with(&cfg, &chaos);
    let mut node = ClusterNode::new(
        node_id,
        backend,
        state,
        engine,
        meta.name.clone(),
        source,
        rings,
        b,
        first_tick,
        s.max_ticks,
        s.eval_every,
        s.workers,
        s.capacity,
    );
    node.attach_telemetry_out(telemetry.clone());
    let journal = match &s.trace {
        Some(path) => {
            let per_node =
                std::path::PathBuf::from(format!("{}.node{}", path.display(), node_id));
            Some(TraceJournal::open(&per_node)?)
        }
        None => None,
    };
    node.attach_observer(journal.as_ref().map(|j| j.handle()));
    Ok(WorkerState { cfg, node, chaos, journal })
}

/// Apply one crash-churn order: recompile the ownership timeline with the
/// dead node removed, rebuild the loader, and redo the dead node's share
/// of the segment that died with it.
fn apply_churn(ws: &mut WorkerState, order: &ChurnOrder) -> anyhow::Result<()> {
    let old = ws.node.rings();
    ws.chaos.push((order.epoch_tick, order.dead));
    let (rings, _) = build_ring_schedule_with(&ws.cfg, &ws.chaos);
    ws.node.adopt_schedule(rings);
    let redone =
        ws.node
            .backfill(order.dead, &old, order.epoch_tick, order.backfill_to)?;
    log::info!(
        "worker {}: churn absorbed node {} (epoch @{}, backfilled {} arrivals)",
        ws.node.id,
        order.dead,
        order.epoch_tick,
        redone
    );
    Ok(())
}

/// One barrier: run to `until`, then emit BarrierReady + ordered payloads.
/// `round` is echoed back so the coordinator's journal and this worker's
/// journal agree on the barrier-round id.
#[allow(clippy::too_many_arguments)]
fn run_barrier(
    ws: &mut WorkerState,
    writer: &Mutex<TcpStream>,
    round: u64,
    until: u64,
    gossip: u8,
    merge: bool,
    boot: bool,
) -> anyhow::Result<()> {
    ws.node.run_until(until);
    let failed = ws.node.failed.clone().unwrap_or_default();
    let ready = Message::BarrierReady {
        from: ws.node.id,
        round,
        until,
        preq: ws.node.take_preq(),
        digest: ws.node.digest,
        ticks_processed: ws.node.tick_digests.len() as u64,
        samples_seen: ws.node.engine.samples_seen,
        samples_trained: ws.node.engine.samples_trained,
        samples_replayed: ws.node.engine.samples_replayed,
        drift_detections: ws.node.engine.drift_detections(),
        store_len: ws.node.engine.store.len() as u64,
        failed: failed.clone(),
    };
    send_msg(writer, &ready)?;
    anyhow::ensure!(failed.is_empty(), "worker failed: {failed}");
    if gossip != GOSSIP_NONE {
        // the coordinator skips relaying empty deltas, but the frame
        // itself must always go up — it is what ends the wait
        send_msg(writer, &ws.node.gossip_message(gossip == GOSSIP_FULL))?;
    }
    if merge || boot {
        send_msg(writer, &ws.node.state_message()?)?;
    }
    Ok(())
}

/// Body of the `adaselection worker` subcommand. Blocks until the
/// coordinator sends `Shutdown` (or the connection drops).
pub fn run_worker(coordinator: &str, node_id: NodeId) -> anyhow::Result<()> {
    let mut reader = TcpStream::connect(coordinator).map_err(|e| {
        anyhow::anyhow!("worker {node_id}: connect to coordinator {coordinator}: {e}")
    })?;
    reader.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(reader.try_clone()?));
    send_msg(&writer, &Message::Hello { from: node_id })?;

    // heartbeats from a side thread: a long training segment must not
    // read as a dead process. Each beat piggybacks the latest telemetry
    // snapshot the training loop published to the shared mailbox, plus
    // the barrier round the main loop last adopted from a `BarrierGo`.
    let stop = Arc::new(AtomicBool::new(false));
    let telemetry = Arc::new(SharedTelemetry::default());
    let round = Arc::new(AtomicU64::new(0));
    let hb = {
        let writer = writer.clone();
        let stop = stop.clone();
        let telemetry = telemetry.clone();
        let round = round.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let beat = Message::Heartbeat {
                    from: node_id,
                    round: round.load(Ordering::Relaxed),
                    telemetry: telemetry.load(),
                };
                if send_msg(&writer, &beat).is_err() {
                    return; // coordinator gone; main loop will notice too
                }
                std::thread::sleep(std::time::Duration::from_millis(HEARTBEAT_MS));
            }
        })
    };

    let result = worker_loop(&mut reader, &writer, node_id, &telemetry, &round);
    stop.store(true, Ordering::Relaxed);
    // on error, report it on the control channel (best effort) so the
    // coordinator aborts with the cause instead of inferring a crash
    if let Err(e) = &result {
        let _ = send_msg(
            &writer,
            &Message::BarrierReady {
                from: node_id,
                round: round.load(Ordering::Relaxed),
                until: 0,
                preq: Vec::new(),
                digest: 0,
                ticks_processed: 0,
                samples_seen: 0,
                samples_trained: 0,
                samples_replayed: 0,
                drift_detections: 0,
                store_len: 0,
                failed: format!("{e:#}"),
            },
        );
    }
    let _ = hb.join();
    result
}

fn worker_loop(
    reader: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    node_id: NodeId,
    telemetry: &Arc<SharedTelemetry>,
    round_out: &Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let mut ws: Option<WorkerState> = None;
    loop {
        let msg = match wire::read_frame(reader)? {
            Some(m) => m,
            None => anyhow::bail!("worker {node_id}: coordinator closed the connection"),
        };
        match msg {
            Message::Assign { node, first_tick, config, chaos } => {
                anyhow::ensure!(
                    node == node_id,
                    "worker {node_id}: assigned someone else's id {node}"
                );
                log::info!("worker {node_id}: assigned shard from tick {first_tick}");
                ws = Some(build_state(&config, node, first_tick, chaos, telemetry)?);
            }
            Message::StoreGossip { entries, .. } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: gossip before Assign")
                })?;
                ws.node.merge_store(entries.as_slice());
            }
            Message::MergePayload { tensors, policy, .. } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: merge payload before Assign")
                })?;
                ws.node.apply_merged(&tensors, policy.as_ref())?;
            }
            Message::BarrierGo { round, until, gossip, merge, boot, churn } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: barrier before Assign")
                })?;
                // adopt the coordinator's round id before any tick runs so
                // every journal line in this segment carries it
                ws.node.set_round(round);
                round_out.store(round, Ordering::Relaxed);
                for order in &churn {
                    apply_churn(ws, order)?;
                }
                run_barrier(ws, writer, round, until, gossip, merge, boot)?;
            }
            Message::Shutdown => {
                log::info!("worker {node_id}: shutdown");
                if let Some(ws) = ws.as_mut() {
                    ws.finish_journal()?;
                }
                return Ok(());
            }
            // coordinator never heartbeats, but tolerating one is free
            Message::Heartbeat { .. } => {}
            other => anyhow::bail!(
                "worker {node_id}: unexpected control frame {other:?}"
            ),
        }
    }
}

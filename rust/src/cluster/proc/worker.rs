//! The worker-process side of the multi-process cluster: a frame-driven
//! state machine around one [`ClusterNode`].
//!
//! Life cycle: connect to the coordinator, send `Hello`, receive `Assign`
//! (the full cluster config as JSON + this worker's node id and first
//! tick), then obey frames until `Shutdown`:
//!
//!   * `BarrierGo { round, until, gossip, merge, boot, churn }` — adopt
//!     the coordinator's barrier-round id (echoed into every journal
//!     line and reply frame), apply any
//!     crash-churn orders (ring epoch + backfill of the dead node's
//!     share), run the tick loop to `until`, then report `BarrierReady`
//!     (prequential records + running counters) followed by the ordered
//!     barrier payloads: a store-gossip snapshot/delta and/or the merge
//!     `State` material;
//!   * `StoreGossip` — merge a peer's entries freshest-tick-wins;
//!   * `MergePayload` — adopt the cluster-averaged model/policy state
//!     (merge barriers; also the join bootstrap).
//!
//! A side thread heartbeats twice a second so the coordinator can tell a
//! hung process from a long training segment. Any error is reported in
//! `BarrierReady::failed` (best effort) before the process exits nonzero
//! — a hard crash instead surfaces at the coordinator as a closed
//! connection and becomes churn.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::node::ClusterNode;
use crate::cluster::ring::NodeId;
use crate::cluster::trainer::{
    build_ring_schedule_with_events, make_engine, replay_budget,
};
use crate::cluster::transport::{
    ChurnOrder, Message, SharedTelemetry, GOSSIP_AUTO, GOSSIP_FULL, GOSSIP_NONE,
    UNASSIGNED,
};
use crate::cluster::wire;
use crate::config::ClusterConfig;
use crate::obs::{flight, TraceJournal};
use crate::runtime::{Backend, NativeBackend};
use crate::stream::source::{build_source, StreamKnobs};
use crate::stream::tick::{fnv_fold, FNV_OFFSET};
use crate::util::json::Json;

/// Heartbeat cadence of the side thread.
const HEARTBEAT_MS: u64 = 500;

/// Connect retry/backoff: first retry after [`CONNECT_BASE_MS`], doubling
/// to [`CONNECT_CAP_MS`], giving up after [`CONNECT_BUDGET_MS`] total —
/// enough for "worker launched before the coordinator listens" without
/// hanging forever on a dead address.
const CONNECT_BASE_MS: u64 = 50;
const CONNECT_CAP_MS: u64 = 2_000;
const CONNECT_BUDGET_MS: u64 = 30_000;

/// Dial the coordinator with jittered exponential backoff. The jitter is
/// deterministic per (attempt, pid) — ±25% of the nominal delay — so a
/// fleet of workers started together does not reconnect in lockstep, yet
/// a given worker's retry schedule is reproducible.
fn connect_with_retry(coordinator: &str) -> anyhow::Result<TcpStream> {
    let start = std::time::Instant::now();
    let mut delay = CONNECT_BASE_MS;
    let mut attempt = 0u64;
    loop {
        match TcpStream::connect(coordinator) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let elapsed = start.elapsed().as_millis() as u64;
                if elapsed >= CONNECT_BUDGET_MS {
                    anyhow::bail!(
                        "connect to coordinator {coordinator}: {e} \
                         (gave up after {attempt} attempts over {elapsed} ms)"
                    );
                }
                let h = fnv_fold(
                    fnv_fold(FNV_OFFSET, attempt),
                    std::process::id() as u64,
                );
                let jitter = (delay / 4).max(1);
                let sleep = (delay - jitter + h % (2 * jitter + 1))
                    .min(CONNECT_BUDGET_MS.saturating_sub(elapsed));
                std::thread::sleep(std::time::Duration::from_millis(sleep));
                delay = (delay * 2).min(CONNECT_CAP_MS);
                attempt += 1;
            }
        }
    }
}

/// Send one wire frame over the shared writer.
fn send_msg(writer: &Mutex<TcpStream>, msg: &Message) -> anyhow::Result<()> {
    wire::check_encodable(msg)?;
    let frame = wire::encode(msg);
    let mut w = writer.lock().unwrap();
    std::io::Write::write_all(&mut *w, &frame)?;
    std::io::Write::flush(&mut *w)?;
    Ok(())
}

/// Everything a worker derives from its `Assign`.
struct WorkerState {
    cfg: ClusterConfig,
    node: ClusterNode<NativeBackend>,
    /// unplanned kills applied so far — the schedule recompile input
    chaos: Vec<(u64, NodeId)>,
    /// unscheduled elastic joins applied so far (same recompile input;
    /// the coordinator broadcasts the cumulative list on every barrier)
    joins: Vec<(u64, NodeId)>,
    /// per-worker trace journal (`--trace PATH` writes `PATH.node<id>`
    /// here — each process owns its own file, no cross-process locking)
    journal: Option<TraceJournal>,
}

impl WorkerState {
    /// Detach the trace sender from the node, then close the journal.
    /// Order matters: `finish()` joins the writer thread, which only
    /// exits once every sender is gone.
    fn finish_journal(&mut self) -> anyhow::Result<()> {
        self.node.detach_observer();
        if let Some(j) = self.journal.take() {
            j.finish()?;
        }
        Ok(())
    }
}

fn build_state(
    config_json: &str,
    node_id: NodeId,
    first_tick: u64,
    chaos: Vec<(u64, NodeId)>,
    joins: Vec<(u64, NodeId)>,
    telemetry: &Arc<SharedTelemetry>,
) -> anyhow::Result<WorkerState> {
    let cfg = ClusterConfig::from_json(
        &Json::parse(config_json).map_err(|e| anyhow::anyhow!("assign config: {e}"))?,
    )?;
    let s = &cfg.stream;
    anyhow::ensure!(
        s.backend == "native",
        "process workers are native-only (got backend '{}')",
        s.backend
    );
    let source = build_source(
        &s.dataset,
        StreamKnobs {
            seed: s.seed,
            drift_period: s.drift_period,
            burst_period: s.burst_period,
            burst_min: s.burst_min,
        },
    )?;
    let mut backend = NativeBackend::new();
    let meta = backend.family_meta(source.family())?;
    let b = meta.batch;
    let state = backend.init_state(&meta.name, s.seed as i32)?;
    let engine = make_engine(&cfg, node_id, b, replay_budget(&cfg, b))?;
    let (rings, _) = build_ring_schedule_with_events(&cfg, &chaos, &joins);
    let mut node = ClusterNode::new(
        node_id,
        backend,
        state,
        engine,
        meta.name.clone(),
        source,
        rings,
        b,
        first_tick,
        s.max_ticks,
        s.eval_every,
        s.workers,
        s.capacity,
    );
    node.attach_telemetry_out(telemetry.clone());
    let journal = match &s.trace {
        Some(path) => {
            let per_node =
                std::path::PathBuf::from(format!("{}.node{}", path.display(), node_id));
            // this worker's flight dump sits next to its own journal file
            flight::set_dump_path(flight::default_dump_path(Some(&per_node)));
            Some(TraceJournal::open(&per_node)?)
        }
        None => {
            // no journal: still give each worker process a distinct dump
            // path so post-mortems from a fleet in one cwd don't collide
            flight::set_dump_path(std::path::PathBuf::from(format!(
                "adaselection.node{node_id}.flight.jsonl"
            )));
            None
        }
    };
    node.attach_observer(journal.as_ref().map(|j| j.handle()));
    Ok(WorkerState { cfg, node, chaos, joins, journal })
}

/// Apply one crash-churn order: recompile the ownership timeline with the
/// dead node removed, rebuild the loader, and redo the dead node's share
/// of the segment that died with it.
fn apply_churn(ws: &mut WorkerState, order: &ChurnOrder) -> anyhow::Result<()> {
    let old = ws.node.rings();
    ws.chaos.push((order.epoch_tick, order.dead));
    let (rings, _) = build_ring_schedule_with_events(&ws.cfg, &ws.chaos, &ws.joins);
    ws.node.adopt_schedule(rings);
    let redone =
        ws.node
            .backfill(order.dead, &old, order.epoch_tick, order.backfill_to)?;
    log::info!(
        "worker {}: churn absorbed node {} (epoch @{}, backfilled {} arrivals)",
        ws.node.id,
        order.dead,
        order.epoch_tick,
        redone
    );
    Ok(())
}

/// One barrier: run to `until`, then emit BarrierReady + ordered payloads.
/// `round` is echoed back so the coordinator's journal and this worker's
/// journal agree on the barrier-round id.
///
/// `GOSSIP_AUTO` defers the delta/full choice to the coordinator: the
/// `BarrierReady` reports whether this store rotated a generation since
/// its last gossip, and the worker then blocks on exactly one `GossipGo`
/// frame carrying the cluster-wide resolution. The read is safe because
/// the control channel is FIFO and the coordinator sends nothing else to
/// this worker between the `BarrierGo` and the `GossipGo`.
#[allow(clippy::too_many_arguments)]
fn run_barrier(
    ws: &mut WorkerState,
    reader: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    round: u64,
    until: u64,
    gossip: u8,
    merge: bool,
    boot: bool,
) -> anyhow::Result<()> {
    // chaos injection: the configured straggler sleeps before its segment,
    // inflating the ready lag the coordinator measures — training state
    // and digests are untouched, only the health telemetry moves
    if ws.cfg.chaos_straggler_ms > 0 && ws.node.id == ws.cfg.chaos_straggler_node {
        std::thread::sleep(std::time::Duration::from_millis(
            ws.cfg.chaos_straggler_ms as u64,
        ));
    }
    ws.node.run_until(until);
    let failed = ws.node.failed.clone().unwrap_or_default();
    let ready = Message::BarrierReady {
        from: ws.node.id,
        round,
        until,
        preq: ws.node.take_preq(),
        digest: ws.node.digest,
        ticks_processed: ws.node.tick_digests.len() as u64,
        samples_seen: ws.node.engine.samples_seen,
        samples_trained: ws.node.engine.samples_trained,
        samples_replayed: ws.node.engine.samples_replayed,
        drift_detections: ws.node.engine.drift_detections(),
        store_len: ws.node.engine.store.len() as u64,
        store_evicted: ws.node.store_evicted_since_gossip(),
        failed: failed.clone(),
    };
    send_msg(writer, &ready)?;
    anyhow::ensure!(failed.is_empty(), "worker failed: {failed}");
    if gossip != GOSSIP_NONE {
        let full = if gossip == GOSSIP_AUTO {
            match wire::read_frame(reader)? {
                Some(Message::GossipGo { round: r, mode }) => {
                    anyhow::ensure!(
                        r == round,
                        "worker {}: GossipGo for round {r} during round {round}",
                        ws.node.id
                    );
                    mode == GOSSIP_FULL
                }
                Some(other) => anyhow::bail!(
                    "worker {}: expected GossipGo, got {other:?}",
                    ws.node.id
                ),
                None => anyhow::bail!(
                    "worker {}: coordinator closed before GossipGo",
                    ws.node.id
                ),
            }
        } else {
            gossip == GOSSIP_FULL
        };
        // the coordinator skips relaying empty deltas, but the frame
        // itself must always go up — it is what ends the wait
        send_msg(writer, &ws.node.gossip_message(full))?;
    }
    if merge || boot {
        send_msg(writer, &ws.node.state_message()?)?;
    }
    Ok(())
}

/// Body of the `adaselection worker` subcommand. Blocks until the
/// coordinator sends `Shutdown` (or the connection drops).
///
/// `node_id: None` registers *unassigned*: the Hello carries the
/// [`UNASSIGNED`] sentinel and the worker adopts whatever id its `Assign`
/// hands it — possibly after waiting in the coordinator's standby pool
/// for an elastic admit. The connection itself retries with jittered
/// exponential backoff, so a worker launched before the coordinator
/// listens still joins.
pub fn run_worker(coordinator: &str, node_id: Option<NodeId>) -> anyhow::Result<()> {
    // a panicking or SIGTERMed worker dumps its flight ring (the last
    // rounds of tick lines) before dying; SIGKILL is uncatchable, so that
    // post-mortem comes from the coordinator's crash-conversion dump
    flight::install_crash_hooks();
    let hello_id = node_id.unwrap_or(UNASSIGNED);
    let mut reader = connect_with_retry(coordinator)
        .map_err(|e| anyhow::anyhow!("worker: {e}"))?;
    reader.set_nodelay(true).ok();
    let writer = Arc::new(Mutex::new(reader.try_clone()?));
    send_msg(&writer, &Message::Hello { from: hello_id })?;

    // heartbeats from a side thread: a long training segment must not
    // read as a dead process. Each beat piggybacks the latest telemetry
    // snapshot the training loop published to the shared mailbox, plus
    // the barrier round the main loop last adopted from a `BarrierGo`.
    // The id cell starts at the Hello id and is overwritten when an
    // unassigned worker adopts the id its Assign grants.
    let stop = Arc::new(AtomicBool::new(false));
    let telemetry = Arc::new(SharedTelemetry::default());
    let round = Arc::new(AtomicU64::new(0));
    let my_id = Arc::new(AtomicUsize::new(hello_id));
    let hb = {
        let writer = writer.clone();
        let stop = stop.clone();
        let telemetry = telemetry.clone();
        let round = round.clone();
        let my_id = my_id.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let beat = Message::Heartbeat {
                    from: my_id.load(Ordering::Relaxed),
                    round: round.load(Ordering::Relaxed),
                    telemetry: telemetry.load(),
                };
                if send_msg(&writer, &beat).is_err() {
                    return; // coordinator gone; main loop will notice too
                }
                std::thread::sleep(std::time::Duration::from_millis(HEARTBEAT_MS));
            }
        })
    };

    let result = worker_loop(&mut reader, &writer, &my_id, &telemetry, &round);
    stop.store(true, Ordering::Relaxed);
    // on error, report it on the control channel (best effort) so the
    // coordinator aborts with the cause instead of inferring a crash
    if let Err(e) = &result {
        let _ = send_msg(
            &writer,
            &Message::BarrierReady {
                from: my_id.load(Ordering::Relaxed),
                round: round.load(Ordering::Relaxed),
                until: 0,
                preq: Vec::new(),
                digest: 0,
                ticks_processed: 0,
                samples_seen: 0,
                samples_trained: 0,
                samples_replayed: 0,
                drift_detections: 0,
                store_len: 0,
                store_evicted: false,
                failed: format!("{e:#}"),
            },
        );
    }
    let _ = hb.join();
    result
}

fn worker_loop(
    reader: &mut TcpStream,
    writer: &Mutex<TcpStream>,
    my_id: &Arc<AtomicUsize>,
    telemetry: &Arc<SharedTelemetry>,
    round_out: &Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let mut node_id: NodeId = my_id.load(Ordering::Relaxed);
    let mut ws: Option<WorkerState> = None;
    loop {
        let msg = match wire::read_frame(reader)? {
            Some(m) => m,
            None => anyhow::bail!("worker {node_id}: coordinator closed the connection"),
        };
        match msg {
            Message::Assign { node, first_tick, config, chaos, joins } => {
                if node_id == UNASSIGNED {
                    // unassigned registration: adopt the granted id (the
                    // heartbeat thread picks it up on its next beat)
                    node_id = node;
                    my_id.store(node, Ordering::Relaxed);
                } else {
                    anyhow::ensure!(
                        node == node_id,
                        "worker {node_id}: assigned someone else's id {node}"
                    );
                }
                log::info!("worker {node_id}: assigned shard from tick {first_tick}");
                ws = Some(build_state(
                    &config, node, first_tick, chaos, joins, telemetry,
                )?);
            }
            Message::StoreGossip { entries, .. } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: gossip before Assign")
                })?;
                ws.node.merge_store(entries.as_slice());
            }
            Message::MergePayload { tensors, policy, .. } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: merge payload before Assign")
                })?;
                ws.node.apply_merged(&tensors, policy.as_ref())?;
            }
            Message::BarrierGo { round, until, gossip, merge, boot, churn, joins } => {
                let ws = ws.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("worker {node_id}: barrier before Assign")
                })?;
                // adopt the coordinator's round id before any tick runs so
                // every journal line in this segment carries it
                ws.node.set_round(round);
                round_out.store(round, Ordering::Relaxed);
                // elastic joins: the coordinator broadcasts the cumulative
                // list; a longer list means the ring grew since our last
                // barrier, so recompile ownership before any tick runs
                if joins.len() > ws.joins.len() {
                    ws.joins = joins;
                    let (rings, _) = build_ring_schedule_with_events(
                        &ws.cfg, &ws.chaos, &ws.joins,
                    );
                    ws.node.adopt_schedule(rings);
                }
                for order in &churn {
                    apply_churn(ws, order)?;
                }
                run_barrier(ws, reader, writer, round, until, gossip, merge, boot)?;
            }
            Message::Shutdown => {
                log::info!("worker {node_id}: shutdown");
                if let Some(ws) = ws.as_mut() {
                    ws.finish_journal()?;
                }
                return Ok(());
            }
            // coordinator never heartbeats, but tolerating one is free
            Message::Heartbeat { .. } => {}
            other => anyhow::bail!(
                "worker {node_id}: unexpected control frame {other:?}"
            ),
        }
    }
}

//! Multi-process cluster workers: real OS processes instead of scoped
//! threads (ROADMAP: "a worker binary + a coordinator that spawns
//! processes instead of threads (barrier protocol over the same wire)").
//!
//!   * [`worker`] — the `adaselection worker` subcommand body: connect to
//!     the coordinator, receive a [`crate::config::ClusterConfig`] +
//!     ring-shard assignment over the control plane, then run the very
//!     same [`crate::cluster::ClusterNode`]/`TickEngine` loop the thread
//!     coordinator drives, between wire-level barriers;
//!   * [`coordinator`] — [`coordinator::Coordinator`]: spawns N children
//!     of the current executable with `std::process::Command`, drives the
//!     identical sync-barrier/gossip/merge schedule the thread
//!     coordinator runs (the barrier sequence comes from the shared
//!     `sync_points`), detects a dead child (closed connection or missed
//!     heartbeats) and converts it into the kill-churn path — ring epoch,
//!     bounded remap, survivor backfill — so training continues, and
//!     aggregates cluster-wide rolling metrics with the same fold the
//!     in-process run uses.
//!
//! The control plane is the `Control` family of [`crate::cluster::wire`]
//! messages (`Hello`/`Assign`/`BarrierGo`/`BarrierReady`/`MergePayload`/
//! `Shutdown`/`Heartbeat`), versioned alongside the gossip/merge payloads
//! in the same checksummed frames. Because every payload round-trips
//! bitwise and the coordinator replays the exact thread-mode barrier
//! schedule, a `--workers processes` run produces **bit-identical**
//! digests, rolling metrics and remap accounting to the equivalent
//! in-process run (`tests/cluster_proc_e2e.rs` pins this).
//!
//! CLI surface: `adaselection cluster --workers processes --nodes 4 ...`
//! (the coordinator side) and the internally-spawned
//! `adaselection worker --coordinator 127.0.0.1:PORT --node-id N`.

pub mod coordinator;
pub mod worker;

pub use coordinator::{run, run_with_exe, Coordinator};
pub use worker::run_worker;

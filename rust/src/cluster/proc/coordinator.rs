//! The process coordinator: drive the thread coordinator's exact barrier
//! schedule over the `cluster::wire` control plane, against a fleet of
//! worker processes that *register* via the `Hello` handshake — spawned
//! children of the current executable by default, or (with `--listen`,
//! optionally `--spawn off`) `adaselection worker --coordinator HOST:PORT`
//! processes started by hand on any machine. Registrations beyond the
//! configured node count park in a standby pool, the reservoir for
//! elastic scale-out: arrival-rate watermarks admit a standby when the
//! stream runs hot and shed the worst straggler (by per-round ready-lag)
//! when it runs cold, reusing the bounded-remap ring machinery and the
//! crash-conversion `ChurnOrder` path as the involuntary half.
//!
//! Topology is hub-and-spoke: every worker holds one TCP connection to
//! the coordinator; store gossip is relayed through the hub in node-id
//! order (so workers merge peers' entries in the same order the
//! in-process transports deliver them), and merges are computed once at
//! the hub with the shared [`MergeMaterial`] weighted-average code and
//! shipped back as `MergePayload` — the same id-sorted input set every
//! thread node averages for itself, hence the same bits.
//!
//! Failure handling: each worker's reader thread turns a closed
//! connection into a death notice, and heartbeats bound how long a hung
//! process can stall a barrier. A dead worker is converted into the
//! kill-churn path — a ring epoch at the last barrier it completed, a
//! measured bounded remap, and `ChurnOrder`s telling the survivors to
//! re-process the dead shard's share of the lost segment — so training
//! continues with exact arrival coverage. `--chaos-kill-at T` makes the
//! coordinator SIGKILL one child mid-segment on purpose, which is how the
//! crash-recovery e2e exercises this path deterministically enough to
//! assert on.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::node::NodePreq;
use crate::cluster::ring::{HashRing, NodeId};
use crate::cluster::trainer::{
    build_ring_schedule_with, fold_preq_records, publish_ready_lag_gauges, sync_points,
    ClusterResult, MergeMaterial, NodeSummary, REMAP_SAMPLE,
};
use crate::cluster::transport::{
    ChurnOrder, Message, TelemetrySnapshot, GOSSIP_AUTO, GOSSIP_DELTA, GOSSIP_FULL,
    GOSSIP_NONE, UNASSIGNED,
};
use crate::cluster::wire;
use crate::config::ClusterConfig;
use crate::metrics::rolling::{RollingPoint, RollingWindow};
use crate::obs::trace::{span_line, wire_event_line};
use crate::obs::{self, flight, HealthEngine, HealthInputs, HealthMode, TraceJournal};
use crate::runtime::{Backend, NativeBackend, TaskKind};
use crate::stream::source::{build_source, StreamKnobs};
use crate::stream::tick::{fnv_fold, FNV_OFFSET};
use crate::util::timer::{PhaseTimer, Stopwatch};

/// How long a worker may stay silent (no frames, no heartbeats) before
/// the coordinator declares it dead and SIGKILLs it. Workers heartbeat
/// every 500 ms from a side thread, so only a truly wedged process trips
/// this.
const STALE_AFTER: Duration = Duration::from_secs(30);

/// Budget for required workers (spawned children or awaited external
/// registrations) to show up in the registration channel.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-connection budget for the first (`Hello`) frame. Short on purpose:
/// a connected-but-silent socket ties up only its own handshake thread
/// for this long, never the accept loop (the slow-loris guard).
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(2);

/// A worker connection's liveness pulse. The reader thread stamps it on
/// every inbound frame (recording the last heartbeat-reported barrier
/// round as it goes by), and waiters block on the condvar instead of
/// sleep-polling — the chaos injector waits here for the victim to
/// confirm it has started the segment.
struct Pulse {
    state: Mutex<Instant>,
    beat: Condvar,
    round: AtomicU64,
}

impl Pulse {
    fn new() -> Pulse {
        Pulse {
            state: Mutex::new(Instant::now()),
            beat: Condvar::new(),
            round: AtomicU64::new(0),
        }
    }

    fn stamp(&self, round: Option<u64>) {
        if let Some(r) = round {
            self.round.store(r, Ordering::Relaxed);
        }
        *self.state.lock().unwrap() = Instant::now();
        self.beat.notify_all();
    }

    fn staleness(&self) -> Duration {
        self.state.lock().unwrap().elapsed()
    }

    /// Block until the next stamp or `timeout`, whichever comes first.
    fn wait_beat(&self, timeout: Duration) {
        let guard = self.state.lock().unwrap();
        let _ = self.beat.wait_timeout(guard, timeout).unwrap();
    }

    fn last_round(&self) -> u64 {
        self.round.load(Ordering::Relaxed)
    }
}

/// A completed handshake, handed from a per-connection handshake thread
/// to whoever is filling worker slots. `hello_id` is the id the worker
/// announced — [`UNASSIGNED`] for a bare
/// `adaselection worker --coordinator HOST:PORT` registration.
struct Registration {
    hello_id: NodeId,
    stream: TcpStream,
}

/// One worker process, as the coordinator sees it — spawned child or
/// externally registered peer (then `child` is `None` and shutdown is
/// purely protocol-level).
struct Worker {
    id: NodeId,
    child: Option<Child>,
    /// write half of the control connection
    stream: TcpStream,
    rx: mpsc::Receiver<Option<Message>>,
    pulse: Arc<Pulse>,
    /// participating in the barrier protocol
    alive: bool,
    /// connection lost / process dead, conversion may still be pending
    crashed: bool,
    /// crash already converted into churn (or graceful shutdown)
    converted: bool,
    /// last barrier tick this worker completed (`BarrierReady` received)
    reported_until: u64,
    // -- last reported summary (doubles as the post-mortem record) --
    digest: u64,
    ticks_processed: u64,
    samples_seen: u64,
    samples_trained: u64,
    samples_replayed: u64,
    drift_detections: u64,
    store_len: usize,
    /// seconds from barrier GO to this worker's `BarrierReady`, as of the
    /// last collected barrier — the straggler signal the elastic shed
    /// ranks by
    last_ready_lag: f64,
    // -- per-barrier stashes --
    barrier_preq: Vec<NodePreq>,
    /// `BarrierReady::store_evicted` from the last collect — the input
    /// for resolving a `GOSSIP_AUTO` round
    store_evicted: bool,
    barrier_gossip: Option<Message>,
    barrier_state: Option<Message>,
}

/// Build a [`Worker`] around a handshaken control connection (reader
/// thread included). `alive: false` parks it as an elastic standby.
fn make_worker(
    id: NodeId,
    child: Option<Child>,
    stream: TcpStream,
    alive: bool,
) -> anyhow::Result<Worker> {
    let read_half = stream.try_clone()?;
    let (tx, rx) = mpsc::channel();
    let pulse = Arc::new(Pulse::new());
    {
        let pulse = pulse.clone();
        std::thread::spawn(move || reader_thread(read_half, tx, pulse));
    }
    Ok(Worker {
        id,
        child,
        stream,
        rx,
        pulse,
        alive,
        crashed: false,
        converted: false,
        reported_until: 0,
        digest: FNV_OFFSET,
        ticks_processed: 0,
        samples_seen: 0,
        samples_trained: 0,
        samples_replayed: 0,
        drift_detections: 0,
        store_len: 0,
        last_ready_lag: 0.0,
        barrier_preq: Vec::new(),
        store_evicted: false,
        barrier_gossip: None,
        barrier_state: None,
    })
}

/// Display a `Hello` id ([`UNASSIGNED`] reads as "unassigned").
fn fmt_hello(id: NodeId) -> String {
    if id == UNASSIGNED {
        "unassigned".to_string()
    } else {
        id.to_string()
    }
}

impl Worker {
    fn send(&mut self, msg: &Message) -> bool {
        if self.crashed {
            return false;
        }
        if let Err(e) = wire::check_encodable(msg) {
            // a coordinator-side bug, not a dead worker: report it loudly
            // and do NOT mark the healthy worker crashed — converting it
            // into kill-churn would mask the real problem as node death
            log::error!(
                "coordinator: refusing unencodable frame for worker {}: {e}",
                self.id
            );
            return false;
        }
        self.send_frame(&wire::encode(msg))
    }

    fn send_frame(&mut self, frame: &[u8]) -> bool {
        if self.crashed {
            return false;
        }
        let ok = self
            .stream
            .write_all(frame)
            .and_then(|_| self.stream.flush())
            .is_ok();
        if !ok {
            self.crashed = true;
        }
        ok
    }

    /// Next non-heartbeat frame, or `None` when the worker is dead
    /// (closed connection or stale heartbeat — the latter also SIGKILLs).
    /// Heartbeats are consumed here: the pulse was already stamped by
    /// the reader thread, and the piggybacked telemetry snapshot is
    /// published as per-node registry gauges for the status endpoint.
    fn recv(&mut self) -> Option<Message> {
        loop {
            match self.rx.recv_timeout(Duration::from_millis(200)) {
                Ok(Some(Message::Heartbeat { from, telemetry, .. })) => {
                    publish_worker_heartbeat(from, &telemetry);
                    continue;
                }
                Ok(Some(m)) => return Some(m),
                Ok(None) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.crashed = true;
                    return None;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let staleness = self.pulse.staleness();
                    if staleness > STALE_AFTER {
                        log::warn!(
                            "worker {}: silent for {:.1}s (stale threshold {}s) — \
                             declaring dead",
                            self.id,
                            staleness.as_secs_f64(),
                            STALE_AFTER.as_secs()
                        );
                        if let Some(c) = self.child.as_mut() {
                            let _ = c.kill();
                        }
                        self.crashed = true;
                        return None;
                    }
                }
            }
        }
    }

    fn reap(&mut self) {
        if let Some(mut c) = self.child.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Publish one worker's heartbeat telemetry as per-node gauges. The
/// heartbeat-age trick: the gauge stores coordinator uptime *at receipt*,
/// so a scraper (or `/status`) computes age as `uptime_now - value`
/// without any wall-clock in the registry.
fn publish_worker_heartbeat(id: NodeId, t: &TelemetrySnapshot) {
    if id == UNASSIGNED {
        return; // a standby's beats carry no node identity yet
    }
    let reg = obs::registry();
    let node = id.to_string();
    let gauge = |name: &str, v: f64| {
        reg.gauge(&obs::series(name, &[("node", node.as_str())])).set(v);
    };
    gauge("adaselection_node_heartbeat_uptime_seconds", obs::uptime_seconds());
    gauge("adaselection_node_ticks_total", t.ticks as f64);
    gauge("adaselection_node_samples_seen", t.samples_seen as f64);
    gauge("adaselection_node_samples_trained", t.samples_trained as f64);
    gauge("adaselection_node_samples_replayed", t.samples_replayed as f64);
    gauge("adaselection_node_drift_detections", t.drift_detections as f64);
    gauge("adaselection_node_store_live", t.store_len as f64);
}

fn reader_thread(mut stream: TcpStream, tx: mpsc::Sender<Option<Message>>, pulse: Arc<Pulse>) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(m)) => {
                let round = match &m {
                    Message::Heartbeat { round, .. } => Some(*round),
                    _ => None,
                };
                pulse.stamp(round);
                if tx.send(Some(m)).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => {
                let _ = tx.send(None);
                return;
            }
        }
    }
}

/// The accept loop, on its own thread so the listener is *always* being
/// served: spawned children, late external registrations and elastic
/// standbys all come in here, whatever the coordinator is doing. Each
/// accepted connection gets its own handshake thread with a short first-
/// frame budget, so a slow or silent socket cannot stall the accept loop
/// or a startup handshake (the slow-loris fix). Completed handshakes
/// land in the registration channel.
fn registrar(listener: TcpListener, tx: mpsc::Sender<Registration>, stop: Arc<AtomicBool>) {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if stop.load(Ordering::Relaxed) {
                    return; // the shutdown wake-up connection
                }
                let tx = tx.clone();
                std::thread::spawn(move || handshake(stream, peer, tx));
            }
            Err(e) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                // transient accept errors (EMFILE et al.): keep serving
                log::warn!("coordinator: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// One connection's handshake: read the `Hello` frame under
/// [`HANDSHAKE_TIMEOUT`], then hand the stream over. A stray local
/// connection (port scanner, curious operator) must not abort a training
/// run: anything that is not a clean `Hello` is dropped here.
fn handshake(mut stream: TcpStream, peer: std::net::SocketAddr, tx: mpsc::Sender<Registration>) {
    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).is_err() {
        return;
    }
    match wire::read_frame(&mut stream) {
        Ok(Some(Message::Hello { from })) => {
            if stream.set_read_timeout(None).is_err() {
                return;
            }
            let _ = tx.send(Registration { hello_id: from, stream });
        }
        other => {
            log::warn!(
                "coordinator: dropping non-worker connection from {peer} \
                 (first frame: {other:?})"
            );
        }
    }
}

/// The multi-process cluster coordinator (see module docs).
pub struct Coordinator {
    cfg: ClusterConfig,
    cfg_json: String,
    exe: PathBuf,
    /// handshaken registrations from the registrar thread
    reg_rx: mpsc::Receiver<Registration>,
    /// raised (plus one wake-up dial) to stop the registrar
    reg_stop: Arc<AtomicBool>,
    /// dialable control address — what spawned children and the README
    /// quickstart pass as `--coordinator` (loopback-substituted when the
    /// listen address is a wildcard bind)
    addr: String,
    workers: Vec<Worker>,
    /// registered-but-unassigned workers, in arrival order — the elastic
    /// admit pool
    standbys: Vec<Worker>,
    /// next id handed to an elastically admitted worker (starts above
    /// every preassigned id, scheduled joiner included)
    next_node_id: NodeId,
    /// elastic admissions so far, broadcast cumulatively in every
    /// `Assign`/`BarrierGo` so all nodes compile the same ring timeline
    joins_events: Vec<(u64, NodeId)>,
    /// `(barrier tick, fleet samples_seen)` of the last arrival-rate
    /// measurement
    last_rate_check: Option<(u64, u64)>,
    // churn state
    chaos_events: Vec<(u64, NodeId)>,
    pending_churn: Vec<ChurnOrder>,
    current_ring: HashRing,
    remaps: Vec<(u64, f64)>,
    chaos_fired: bool,
    // accounting
    gossip_rounds: u64,
    merges: u64,
    gossip_bytes: u64,
    merge_bytes: u64,
    /// Monotonically increasing barrier-round id, stamped into every
    /// `BarrierGo`/`MergePayload` frame so workers echo it into their
    /// journal lines and offline analysis can merge by `(round, node)`.
    round: u64,
    /// Run clock for span timestamps — every span's `start` is seconds on
    /// this clock, so coordinator spans in one journal share a timeline.
    span_clock: Stopwatch,
    /// coordinator-side trace journal (`--trace PATH` writes gossip/merge
    /// events here; each worker process journals its ticks to
    /// `PATH.node<id>`)
    journal: Option<TraceJournal>,
    /// fleet health rules, evaluated once per barrier round against the
    /// registry snapshot the barrier just refreshed
    health: HealthEngine,
}

impl Coordinator {
    /// Bind the control listener and prepare a run. `exe` is the binary
    /// spawned as `exe worker --coordinator ADDR --node-id N` — the
    /// current executable from the CLI, an explicit path from tests and
    /// benches (whose own executable has no `worker` subcommand).
    pub fn new(cfg: &ClusterConfig, exe: PathBuf) -> anyhow::Result<Coordinator> {
        let mut cfg = cfg.clone();
        cfg.worker_mode = "processes".into();
        cfg.validate()?;
        let bind_addr = cfg
            .listen
            .clone()
            .unwrap_or_else(|| "127.0.0.1:0".to_string());
        let listener = TcpListener::bind(&bind_addr).map_err(|e| {
            anyhow::anyhow!("coordinator: bind control listener {bind_addr}: {e}")
        })?;
        let local = listener.local_addr()?;
        // children (and the shutdown wake-up) dial this address; a
        // wildcard bind (0.0.0.0 / ::) is not dialable, so substitute
        // loopback while remote workers use the machine's real address
        let addr = if local.ip().is_unspecified() {
            format!("127.0.0.1:{}", local.port())
        } else {
            local.to_string()
        };
        if cfg.listen.is_some() {
            log::info!("coordinator: accepting worker registrations on {local}");
        }
        let (reg_tx, reg_rx) = mpsc::channel();
        let reg_stop = Arc::new(AtomicBool::new(false));
        {
            let stop = reg_stop.clone();
            std::thread::spawn(move || registrar(listener, reg_tx, stop));
        }
        let cfg_json = cfg.to_json().to_string();
        let current_ring =
            HashRing::with_nodes(cfg.stream.seed, cfg.vnodes, 0..cfg.nodes);
        let journal = match &cfg.stream.trace {
            Some(path) => Some(TraceJournal::open(path)?),
            None => None,
        };
        // the flight ring records barrier/relay/alert lines whether or not
        // a journal is open; a panic, SIGTERM, or converted worker crash
        // dumps the last rounds to disk
        flight::set_dump_path(flight::default_dump_path(cfg.stream.trace.as_deref()));
        flight::install_crash_hooks();
        let mut health = HealthEngine::new(HealthMode::parse(&cfg.stream.health)?);
        health.attach_trace(journal.as_ref().map(|j| j.handle()));
        let next_node_id = cfg.nodes + usize::from(cfg.join_at > 0);
        Ok(Coordinator {
            cfg,
            cfg_json,
            exe,
            reg_rx,
            reg_stop,
            addr,
            workers: Vec::new(),
            standbys: Vec::new(),
            next_node_id,
            joins_events: Vec::new(),
            last_rate_check: None,
            chaos_events: Vec::new(),
            pending_churn: Vec::new(),
            current_ring,
            remaps: Vec::new(),
            chaos_fired: false,
            gossip_rounds: 0,
            merges: 0,
            gossip_bytes: 0,
            merge_bytes: 0,
            round: 0,
            span_clock: Stopwatch::new(),
            journal,
            health,
        })
    }

    /// Journal one coordinator-side wire event (gossip relay / merge).
    /// Lines flow through `emit_journal` so the flight ring records them
    /// even without `--trace`.
    fn trace_event(&self, kind: &str, tick: u64, bytes: u64) {
        let t = self.journal.as_ref().map(|j| j.handle());
        obs::emit_journal(t.as_ref(), wire_event_line(kind, self.round, tick, bytes));
    }

    /// Journal one coordinator-side span under the current round. `start`
    /// is seconds on `span_clock`.
    fn trace_span(&self, name: &str, tick: u64, node: Option<usize>, start: f64, duration: f64) {
        let t = self.journal.as_ref().map(|j| j.handle());
        obs::emit_journal(t.as_ref(), span_line(name, self.round, tick, node, start, duration));
    }

    fn spawn_child(&self, node: NodeId) -> anyhow::Result<Child> {
        Command::new(&self.exe)
            .arg("worker")
            .arg("--coordinator")
            .arg(&self.addr)
            .arg("--node-id")
            .arg(node.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| {
                anyhow::anyhow!("coordinator: spawn worker {node} ({:?}): {e}", self.exe)
            })
    }

    /// Fill the worker ids in `need` from (in order) already-parked
    /// standbys, then fresh registrations — blocking on the registration
    /// channel under a deadline, never sleep-polling. `children` maps the
    /// ids we spawned ourselves (their `Hello` must announce the id); an
    /// id in `need` with no child entry may be claimed by any unassigned
    /// registration (`--spawn off` startup and scheduled joins without
    /// spawning). Registrations that fill no slot park as standbys.
    fn fill_slots(
        &mut self,
        mut children: BTreeMap<NodeId, Child>,
        mut need: Vec<NodeId>,
    ) -> anyhow::Result<()> {
        need.sort_unstable();
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        // standbys first: explicit ids, then unassigned in arrival order
        // (two passes so an explicit --node-id is honored even when an
        // unassigned standby registered earlier)
        for pass in 0..2 {
            let mut k = 0;
            while k < self.standbys.len() && !need.is_empty() {
                let hid = self.standbys[k].id;
                let claim = if pass == 0 {
                    need.iter()
                        .position(|&n| n == hid && !children.contains_key(&n))
                } else if hid == UNASSIGNED {
                    need.iter().position(|&n| !children.contains_key(&n))
                } else {
                    None
                };
                match claim {
                    Some(p) => {
                        let id = need.remove(p);
                        let mut w = self.standbys.remove(k);
                        w.id = id;
                        w.alive = true;
                        self.workers.push(w);
                    }
                    None => k += 1,
                }
            }
        }
        while !need.is_empty() {
            match self.reg_rx.recv_timeout(Duration::from_millis(250)) {
                Ok(reg) => self.place_registration(reg, &mut children, &mut need),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // a child that died before Hello would hang us forever
                    for (id, c) in children.iter_mut() {
                        if let Ok(Some(status)) = c.try_wait() {
                            anyhow::bail!(
                                "coordinator: worker {id} exited during handshake ({status})"
                            );
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "coordinator: workers never registered: {need:?}"
                    );
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("coordinator: registrar thread died")
                }
            }
        }
        // keep id order stable regardless of connect order
        self.workers.sort_by_key(|w| w.id);
        Ok(())
    }

    /// Route one registration: claim a needed slot (matching child id, or
    /// any unspawned slot for an unassigned `Hello`) or park as standby.
    fn place_registration(
        &mut self,
        reg: Registration,
        children: &mut BTreeMap<NodeId, Child>,
        need: &mut Vec<NodeId>,
    ) {
        let Registration { hello_id, stream } = reg;
        let slot = match need.iter().position(|&n| n == hello_id) {
            Some(p) => Some(p),
            None if hello_id == UNASSIGNED => {
                need.iter().position(|&n| !children.contains_key(&n))
            }
            None => None,
        };
        let (id, alive, child) = match slot {
            Some(p) => {
                let id = need.remove(p);
                (id, true, children.remove(&id))
            }
            None => (hello_id, false, None),
        };
        match make_worker(id, child, stream, alive) {
            Ok(w) if alive => self.workers.push(w),
            Ok(w) => {
                log::info!(
                    "coordinator: parked registration (hello id {}) as standby #{}",
                    fmt_hello(hello_id),
                    self.standbys.len() + 1
                );
                self.standbys.push(w);
            }
            Err(e) => log::warn!("coordinator: dropping registration: {e}"),
        }
    }

    /// Sweep registrations that arrived mid-run into the standby pool
    /// (called at every barrier, so `/status` and the elastic admit see
    /// them promptly).
    fn drain_registrations(&mut self) {
        while let Ok(reg) = self.reg_rx.try_recv() {
            self.place_registration(reg, &mut BTreeMap::new(), &mut Vec::new());
        }
    }

    fn alive_ids(&self) -> Vec<NodeId> {
        self.workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .map(|w| w.id)
            .collect()
    }

    /// Convert every un-converted crash into churn: ring epoch at the last
    /// barrier the dead worker completed, bounded-remap measurement, and a
    /// `ChurnOrder` telling survivors to re-process the dead shard's share
    /// of `[epoch, survivors_at)`.
    fn convert_crashes(&mut self, survivors_at: u64) -> anyhow::Result<()> {
        for i in 0..self.workers.len() {
            if !(self.workers[i].crashed && !self.workers[i].converted) {
                continue;
            }
            let (id, epoch) = (self.workers[i].id, self.workers[i].reported_until);
            let before = self.current_ring.clone();
            self.current_ring.remove_node(id);
            anyhow::ensure!(
                !self.current_ring.is_empty(),
                "coordinator: every worker is dead"
            );
            let frac =
                HashRing::remap_fraction(&before, &self.current_ring, REMAP_SAMPLE);
            self.remaps.push((epoch, frac));
            self.chaos_events.push((epoch, id));
            self.pending_churn.push(ChurnOrder {
                dead: id,
                epoch_tick: epoch,
                backfill_to: survivors_at,
            });
            let w = &mut self.workers[i];
            w.alive = false;
            w.converted = true;
            w.reap();
            log::warn!(
                "coordinator: worker {id} died; converted to churn (epoch @{epoch}, \
                 backfill to {survivors_at}, {:.1}% of keys remapped)",
                100.0 * frac
            );
            // post-mortem: dump the flight ring (the last rounds of
            // barrier/relay/alert lines) next to the journal
            flight::dump_now("worker crash");
        }
        Ok(())
    }

    /// Collect one worker's `BarrierReady` (counters + eviction flag).
    /// Returns an error only for protocol violations / reported failures
    /// — a death just marks the worker crashed.
    fn collect_ready(&mut self, i: usize, sync: u64) -> anyhow::Result<()> {
        let w = &mut self.workers[i];
        w.barrier_preq.clear();
        w.store_evicted = false;
        w.barrier_gossip = None;
        w.barrier_state = None;
        if w.crashed {
            return Ok(());
        }
        match w.recv() {
            Some(Message::BarrierReady {
                preq,
                digest,
                ticks_processed,
                samples_seen,
                samples_trained,
                samples_replayed,
                drift_detections,
                store_len,
                store_evicted,
                failed,
                ..
            }) => {
                anyhow::ensure!(
                    failed.is_empty(),
                    "cluster worker failed: {failed}"
                );
                w.reported_until = sync;
                w.barrier_preq = preq;
                w.digest = digest;
                w.ticks_processed = ticks_processed;
                w.samples_seen = samples_seen;
                w.samples_trained = samples_trained;
                w.samples_replayed = samples_replayed;
                w.drift_detections = drift_detections;
                w.store_len = store_len as usize;
                w.store_evicted = store_evicted;
            }
            Some(other) => anyhow::bail!(
                "coordinator: worker {} sent {other:?} instead of BarrierReady",
                w.id
            ),
            None => return Ok(()),
        }
        Ok(())
    }

    /// Collect one worker's ordered barrier payloads (gossip, then merge
    /// `State`), per its `BarrierGo` flags.
    fn collect_payloads(
        &mut self,
        i: usize,
        gossip: bool,
        state_expected: bool,
    ) -> anyhow::Result<()> {
        let w = &mut self.workers[i];
        if w.crashed {
            return Ok(());
        }
        if gossip {
            match w.recv() {
                Some(m @ Message::StoreGossip { .. }) => w.barrier_gossip = Some(m),
                Some(other) => anyhow::bail!(
                    "coordinator: worker {} sent {other:?} instead of StoreGossip",
                    w.id
                ),
                None => return Ok(()),
            }
        }
        if state_expected {
            match w.recv() {
                Some(m @ Message::State { .. }) => w.barrier_state = Some(m),
                Some(other) => anyhow::bail!(
                    "coordinator: worker {} sent {other:?} instead of State",
                    w.id
                ),
                None => return Ok(()),
            }
        }
        Ok(())
    }

    /// Collect one barrier round across `flags` (worker index, gossip
    /// order, state expected): every `BarrierReady` first, then — on a
    /// `GOSSIP_AUTO` round — resolve the cluster-wide delta/full choice
    /// from the reported eviction flags and release the workers with a
    /// `GossipGo`, then the ordered payloads. Returns the resolved gossip
    /// mode (what `relay_gossip` should assume). `GossipGo` frames are
    /// control plane, not counted into `gossip_bytes`.
    fn collect_round(
        &mut self,
        flags: &[(usize, u8, bool)],
        sync: u64,
        barrier_start: f64,
    ) -> anyhow::Result<u8> {
        for &(i, _, _) in flags {
            self.collect_ready(i, sync)?;
            if self.workers[i].crashed {
                // no BarrierReady arrived: the elapsed time measures death
                // detection, not readiness — don't report it as a lag (so
                // a flight dump's last ready_lag for a crashed worker is
                // its final completed barrier)
                continue;
            }
            let lag = self.span_clock.elapsed_secs() - barrier_start;
            self.workers[i].last_ready_lag = lag;
            let id = self.workers[i].id;
            self.trace_span("ready_lag", sync, Some(id), barrier_start, lag);
        }
        let mut resolved = flags
            .iter()
            .map(|&(_, g, _)| g)
            .find(|&g| g != GOSSIP_NONE)
            .unwrap_or(GOSSIP_NONE);
        if resolved == GOSSIP_AUTO {
            // a delta cannot resurrect entries a receiver evicted, so one
            // eviction anywhere escalates the whole round to full — the
            // same rule the thread coordinator applies locally
            let evicted = flags.iter().any(|&(i, g, _)| {
                g == GOSSIP_AUTO
                    && !self.workers[i].crashed
                    && self.workers[i].store_evicted
            });
            resolved = if evicted { GOSSIP_FULL } else { GOSSIP_DELTA };
            let go = Message::GossipGo { round: self.round, mode: resolved };
            for &(i, g, _) in flags {
                if g == GOSSIP_AUTO {
                    self.workers[i].send(&go);
                }
            }
        }
        for &(i, g, st) in flags {
            self.collect_payloads(i, g != GOSSIP_NONE, st)?;
        }
        Ok(resolved)
    }

    /// Relay the collected gossip messages hub-and-spoke, in sender-id
    /// order, skipping empty deltas exactly like the thread coordinator.
    /// Returns wire bytes shipped to peers (the same `frame_len × peers`
    /// the in-process run reports, so the two modes account identically).
    fn relay_gossip(&mut self, mode: u8) -> u64 {
        let ids = self.alive_ids();
        if ids.len() < 2 {
            return 0;
        }
        let mut bytes = 0u64;
        for i in 0..self.workers.len() {
            if !(self.workers[i].alive && !self.workers[i].crashed) {
                continue;
            }
            let Some(msg) = self.workers[i].barrier_gossip.take() else {
                continue;
            };
            if mode == GOSSIP_DELTA {
                if let Message::StoreGossip { entries, .. } = &msg {
                    if entries.is_empty() {
                        continue; // a quiet shard's delta carries nothing
                    }
                }
            }
            let from = self.workers[i].id;
            let frame = wire::encode(&msg);
            let flen = wire::frame_len(&msg) as u64;
            for j in 0..self.workers.len() {
                if self.workers[j].id == from
                    || !(self.workers[j].alive && !self.workers[j].crashed)
                {
                    continue;
                }
                if self.workers[j].send_frame(&frame) {
                    bytes += flen;
                }
            }
        }
        bytes
    }

    /// Take the barrier `State` stashes from every live worker, in id
    /// order — the single owner of the contributor-set rule shared by
    /// barrier merges and the join bootstrap. Returns the merge material,
    /// the uplink frame bytes, and the contributor count.
    fn take_states(&mut self) -> (MergeMaterial, u64, usize) {
        let mut mat = MergeMaterial::default();
        let mut bytes = 0u64;
        let mut contributed = 0usize;
        for w in &mut self.workers {
            if !(w.alive && !w.crashed) {
                continue;
            }
            if let Some(msg) = w.barrier_state.take() {
                bytes += wire::frame_len(&msg) as u64;
                mat.push(msg);
                contributed += 1;
            }
        }
        (mat, bytes, contributed)
    }

    /// One merge round over the collected `State` material: weighted
    /// average at the hub, `MergePayload` back to every live worker.
    /// Mirrors the thread coordinator's no-op when fewer than two nodes
    /// are alive. Returns wire bytes (uplink states + downlink payloads).
    fn do_merge(&mut self) -> anyhow::Result<u64> {
        if self.alive_ids().len() < 2 {
            return Ok(0);
        }
        let (mat, mut bytes, contributed) = self.take_states();
        anyhow::ensure!(contributed >= 1, "merge with no contributing workers");
        let (avg, snap) = mat.merged()?;
        let payload =
            Message::MergePayload { round: self.round, tensors: avg, policy: snap };
        wire::check_encodable(&payload)?;
        let frame = wire::encode(&payload);
        let flen = wire::frame_len(&payload) as u64;
        for i in 0..self.workers.len() {
            if self.workers[i].alive
                && !self.workers[i].crashed
                && self.workers[i].send_frame(&frame)
            {
                bytes += flen;
            }
        }
        Ok(bytes)
    }

    /// One *uniform* barrier round: the same `BarrierGo` flags to every
    /// live worker, collect the replies, fold the prequential stashes.
    /// Shared by the join mini-round and the crash-recovery round (the
    /// main segment round stays in `drive` — its flags differ per worker
    /// around a scheduled kill/join).
    #[allow(clippy::too_many_arguments)]
    fn uniform_round(
        &mut self,
        until: u64,
        gossip: u8,
        merge: bool,
        boot: bool,
        churn: Vec<ChurnOrder>,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        self.round += 1;
        let barrier_start = self.span_clock.elapsed_secs();
        let joins = self.joins_events.clone();
        let mut flags: Vec<(usize, u8, bool)> = Vec::new();
        for i in 0..self.workers.len() {
            if !(self.workers[i].alive && !self.workers[i].crashed) {
                continue;
            }
            let go = Message::BarrierGo {
                round: self.round,
                until,
                gossip,
                merge,
                boot,
                churn: churn.clone(),
                joins: joins.clone(),
            };
            if self.workers[i].send(&go) {
                flags.push((i, gossip, merge || boot));
            }
        }
        self.collect_round(&flags, until, barrier_start)?;
        let dur = self.span_clock.elapsed_secs() - barrier_start;
        self.trace_span("barrier", until, None, barrier_start, dur);
        self.fold_barrier(classification, roll_loss, roll_acc, rolling);
        self.health_check(until, roll_loss);
        Ok(())
    }

    /// Fold this barrier's prequential stashes, in worker-id order — the
    /// same summation order `cluster::run` uses, for bit-identical
    /// rolling traces.
    fn fold_barrier(
        &mut self,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) {
        let per_node: Vec<Vec<NodePreq>> = self
            .workers
            .iter_mut()
            .map(|w| std::mem::take(&mut w.barrier_preq))
            .collect();
        fold_preq_records(&per_node, classification, roll_loss, roll_acc, rolling);
        // fleet-wide gauges for the status endpoint (per-node detail comes
        // in over the heartbeats)
        let reg = obs::registry();
        let loss = roll_loss.mean();
        if loss.is_finite() {
            reg.gauge("adaselection_rolling_loss").set(loss);
        }
        let acc = roll_acc.mean();
        if classification && acc.is_finite() {
            reg.gauge("adaselection_rolling_acc").set(acc);
        }
        let live: usize = self
            .workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .map(|w| w.store_len)
            .sum();
        reg.gauge("adaselection_store_live").set(live as f64);
        // live membership for /status: fleet counts plus a per-node
        // alive flag (dead workers keep reporting 0 so the view shows
        // the shed/crash instead of silently dropping the row)
        let alive = self
            .workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .count();
        reg.gauge("adaselection_cluster_nodes").set(alive as f64);
        reg.gauge("adaselection_cluster_standbys")
            .set(self.standbys.len() as f64);
        for w in &self.workers {
            let node = w.id.to_string();
            reg.gauge(&obs::series("adaselection_node_alive", &[("node", node.as_str())]))
                .set(f64::from(u8::from(w.alive && !w.crashed)));
            if w.alive && !w.crashed {
                // arrival counters straight from this barrier's
                // BarrierReady — fresher than the heartbeat copies, so
                // the arrival-stall health rule sees progress even when
                // a fast segment outpaces the 500 ms heartbeat cadence
                reg.gauge(&obs::series(
                    "adaselection_node_samples_seen",
                    &[("node", node.as_str())],
                ))
                .set(w.samples_seen as f64);
            }
        }
        // per-node ready lag from this barrier's collect — the series the
        // straggler health rule medians over (and the shed ranks by)
        let lags: Vec<(NodeId, f64)> = self
            .workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .map(|w| (w.id, w.last_ready_lag))
            .collect();
        publish_ready_lag_gauges(&lags);
    }

    /// Evaluate the fleet health rules against the registry snapshot the
    /// barrier just refreshed. Telemetry-only: never touches training
    /// state, so enabling it cannot move the digest.
    fn health_check(&mut self, sync: u64, roll_loss: &RollingWindow) {
        if self.health.mode().is_off() {
            return;
        }
        let m = roll_loss.mean();
        self.health
            .evaluate(self.round, sync, &HealthInputs::from_registry(m.is_finite().then_some(m)));
    }

    /// Run the whole job. Consumes the coordinator.
    pub fn run(mut self) -> anyhow::Result<ClusterResult> {
        let r = self.drive();
        // whatever happened, never leave children (or parked externally
        // registered standbys) behind
        for w in self.workers.iter_mut().chain(self.standbys.iter_mut()) {
            let _ = w.send(&Message::Shutdown);
        }
        for w in self.workers.iter_mut().chain(self.standbys.iter_mut()) {
            w.reap();
        }
        // stop the registrar: raise the flag, then dial the listener once
        // so the blocking accept wakes up and sees it
        self.reg_stop.store(true, Ordering::Relaxed);
        if let Ok(sa) = self.addr.parse::<std::net::SocketAddr>() {
            let _ = TcpStream::connect_timeout(&sa, Duration::from_millis(250));
        }
        // the health engine holds the only persistent trace sender —
        // detach it (event handles are transient) so the writer thread
        // drains and exits as soon as the journal's own sender drops
        // inside finish(). Strict-mode failure is surfaced only after the
        // journal is flushed, so the firing alerts reach disk first.
        let health_verdict = self.health.finish();
        self.health.attach_trace(None);
        if let Some(j) = self.journal.take() {
            let finished = j.finish();
            if r.is_ok() {
                finished?;
            }
        }
        let result = r?;
        health_verdict?;
        Ok(result)
    }

    fn drive(&mut self) -> anyhow::Result<ClusterResult> {
        let cfg = self.cfg.clone();
        let s = &cfg.stream;
        let max = s.max_ticks as u64;
        let delta = cfg.gossip == "delta";

        // traffic/task metadata (for rolling-accuracy semantics), plus the
        // precompiled remap accounting for the *scheduled* churn
        let source = build_source(
            &s.dataset,
            StreamKnobs {
                seed: s.seed,
                drift_period: s.drift_period,
                burst_period: s.burst_period,
                burst_min: s.burst_min,
            },
        )?;
        let probe = NativeBackend::new();
        let meta = probe.family_meta(source.family())?;
        let classification = meta.task != TaskKind::Regression;
        let (_, scheduled_remaps) = build_ring_schedule_with(&cfg, &[]);
        self.remaps = scheduled_remaps;

        log::info!(
            "cluster start (processes): nodes={} vnodes={} stream={} γ={} B={} ticks={} gossip={}({}) merge={} kill@{} join@{} chaos@{}",
            cfg.nodes,
            cfg.vnodes,
            s.dataset,
            s.gamma,
            meta.batch,
            s.max_ticks,
            cfg.gossip_every,
            cfg.gossip,
            cfg.merge_every,
            cfg.kill_at,
            cfg.join_at,
            cfg.chaos_kill_at
        );

        // spawn (unless --spawn off) + registration + assign
        let mut children = BTreeMap::new();
        if cfg.spawn {
            for id in 0..cfg.nodes {
                children.insert(id, self.spawn_child(id)?);
            }
        } else {
            log::info!(
                "coordinator: waiting for {} external worker registration(s) on {}",
                cfg.nodes,
                self.addr
            );
        }
        self.fill_slots(children, (0..cfg.nodes).collect())?;
        let cfg_json = self.cfg_json.clone();
        for w in &mut self.workers {
            let assign = Message::Assign {
                node: w.id,
                first_tick: 0,
                config: cfg_json.clone(),
                chaos: Vec::new(),
                joins: Vec::new(),
            };
            anyhow::ensure!(
                w.send(&assign),
                "coordinator: worker {} dropped before Assign",
                w.id
            );
        }

        let mut roll_loss = RollingWindow::new(s.window);
        let mut roll_acc = RollingWindow::new(s.window);
        let mut rolling: Vec<RollingPoint> = Vec::new();
        let clock = Stopwatch::new();
        let mut prev = 0u64;

        for &sync in &sync_points(&cfg) {
            let is_kill = cfg.kill_at > 0 && cfg.kill_at as u64 == sync;
            let is_join = cfg.join_at > 0 && cfg.join_at as u64 == sync;
            let cadence_gossip = sync < max
                && cfg.gossip_every > 0
                && sync % cfg.gossip_every as u64 == 0
                && !is_join;
            let cadence_merge =
                sync < max && cfg.merge_every > 0 && sync % cfg.merge_every as u64 == 0;
            // delta-cadence rounds go out as GOSSIP_AUTO: whether the
            // round may actually ship deltas depends on eviction flags
            // the workers only report at the barrier, so the choice is
            // resolved post-collect by a GossipGo (cadence-full rounds
            // are full no matter what, so they are ordered directly)
            let gossip_mode = if cadence_gossip {
                if delta && self.gossip_rounds % cfg.full_gossip_every as u64 != 0 {
                    GOSSIP_AUTO
                } else {
                    GOSSIP_FULL
                }
            } else {
                GOSSIP_NONE
            };

            // crashes noticed after the previous barrier's conversion pass
            // (e.g. during relays) become churn *before* this segment runs
            self.convert_crashes(prev)?;
            let churn = std::mem::take(&mut self.pending_churn);

            // ---- segment barrier: GO, (maybe) chaos, collect ----
            self.round += 1;
            let barrier_start = self.span_clock.elapsed_secs();
            let joins = self.joins_events.clone();
            let mut flags: Vec<(usize, u8, bool)> = Vec::new(); // (idx, gossip, state?)
            for i in 0..self.workers.len() {
                if !(self.workers[i].alive && !self.workers[i].crashed) {
                    continue;
                }
                let victim = is_kill && self.workers[i].id == cfg.kill_node;
                let g = if victim { GOSSIP_NONE } else { gossip_mode };
                let m = cadence_merge && !victim && !is_join;
                let b = is_join && !victim;
                let go = Message::BarrierGo {
                    round: self.round,
                    until: sync,
                    gossip: g,
                    merge: m,
                    boot: b,
                    churn: churn.clone(),
                    joins: joins.clone(),
                };
                if self.workers[i].send(&go) {
                    flags.push((i, g, m || b));
                }
            }
            let mut chaos_this_barrier = false;
            if cfg.chaos_kill_at > 0
                && !self.chaos_fired
                && prev <= cfg.chaos_kill_at as u64
                && (cfg.chaos_kill_at as u64) < sync
            {
                self.chaos_fired = true;
                chaos_this_barrier = true;
                // wait (condvar beats, not a sleep-poll) until the
                // victim's heartbeat confirms it has adopted this round —
                // i.e. the segment is under way — so the SIGKILL lands
                // mid-flight; a cap keeps a wedged victim from stalling us
                let round = self.round;
                if let Some(w) = self
                    .workers
                    .iter_mut()
                    .find(|w| w.id == cfg.chaos_kill_node && w.alive)
                {
                    let cap = Instant::now() + Duration::from_secs(2);
                    while w.pulse.last_round() < round && Instant::now() < cap {
                        w.pulse.wait_beat(Duration::from_millis(100));
                    }
                    log::warn!("coordinator: chaos-killing worker {}", w.id);
                    if let Some(c) = w.child.as_mut() {
                        let _ = c.kill();
                    }
                }
            }
            let resolved = self.collect_round(&flags, sync, barrier_start)?;
            let dur = self.span_clock.elapsed_secs() - barrier_start;
            self.trace_span("barrier", sync, None, barrier_start, dur);
            self.fold_barrier(classification, &mut roll_loss, &mut roll_acc, &mut rolling);
            self.health_check(sync, &roll_loss);

            // ---- churn: crashes first (mirrors kill-before-gossip), then
            // the scheduled kill, then the scheduled join ----
            self.convert_crashes(sync)?;
            if is_kill {
                if let Some(w) = self
                    .workers
                    .iter_mut()
                    .find(|w| w.id == cfg.kill_node && w.alive && !w.crashed)
                {
                    let _ = w.send(&Message::Shutdown);
                    w.alive = false;
                    w.converted = true;
                    if let Some(mut c) = w.child.take() {
                        let _ = c.wait();
                    }
                    log::info!("cluster: killed worker {} at tick {sync}", cfg.kill_node);
                }
                self.current_ring.remove_node(cfg.kill_node);
            }

            if cadence_gossip {
                let gossip_start = self.span_clock.elapsed_secs();
                let bytes = self.relay_gossip(resolved);
                self.gossip_bytes += bytes;
                self.gossip_rounds += 1;
                self.trace_event("gossip", sync, bytes);
                let dur = self.span_clock.elapsed_secs() - gossip_start;
                self.trace_span("gossip_relay", sync, None, gossip_start, dur);
            }

            if is_join {
                self.join_round(
                    sync,
                    cadence_merge,
                    classification,
                    &mut roll_loss,
                    &mut roll_acc,
                    &mut rolling,
                )?;
            } else if cadence_merge {
                let merge_start = self.span_clock.elapsed_secs();
                let bytes = self.do_merge()?;
                self.merge_bytes += bytes;
                self.merges += 1;
                self.trace_event("merge", sync, bytes);
                let dur = self.span_clock.elapsed_secs() - merge_start;
                self.trace_span("merge", sync, None, merge_start, dur);
            }

            // ---- elastic membership: watermark admit / shed ----
            self.drain_registrations();
            if sync < max && !is_kill && !is_join && !chaos_this_barrier {
                self.elastic_step(
                    sync,
                    classification,
                    &mut roll_loss,
                    &mut roll_acc,
                    &mut rolling,
                )?;
            }
            prev = sync;
        }

        // a worker that died during the *final* segment (or final relays)
        // leaves churn no later BarrierGo can deliver — run one recovery
        // round so survivors still backfill the dead shard's share and
        // report their corrected counters, keeping arrival coverage exact
        self.convert_crashes(max)?;
        let churn = std::mem::take(&mut self.pending_churn);
        if !churn.is_empty() {
            self.uniform_round(
                max,
                GOSSIP_NONE,
                false,
                false,
                churn,
                classification,
                &mut roll_loss,
                &mut roll_acc,
                &mut rolling,
            )?;
            self.convert_crashes(max)?;
            if !self.pending_churn.is_empty() {
                // a second death during recovery: nobody left to backfill
                // for it — surface the coverage gap instead of hiding it
                log::warn!(
                    "coordinator: {} churn event(s) could not be backfilled before \
                     shutdown; arrival coverage may be short",
                    self.pending_churn.len()
                );
            }
        }

        // graceful shutdown; the final barrier already reported every
        // worker's end-of-run counters
        for w in &mut self.workers {
            if w.alive && !w.crashed {
                let _ = w.send(&Message::Shutdown);
            }
        }
        for w in &mut self.workers {
            if w.alive {
                if let Some(mut c) = w.child.take() {
                    let _ = c.wait();
                }
            }
        }

        let elapsed = clock.elapsed_secs();
        let mut digest = FNV_OFFSET;
        let mut samples_seen = 0u64;
        let mut samples_trained = 0u64;
        let mut samples_replayed = 0u64;
        let mut drift_detections = 0u64;
        let mut store_live_total = 0usize;
        let mut summaries = Vec::new();
        for w in &self.workers {
            digest = fnv_fold(digest, w.digest);
            samples_seen += w.samples_seen;
            samples_trained += w.samples_trained;
            samples_replayed += w.samples_replayed;
            drift_detections += w.drift_detections;
            if w.alive {
                store_live_total += w.store_len;
            }
            summaries.push(NodeSummary {
                id: w.id,
                ticks_processed: w.ticks_processed,
                samples_seen: w.samples_seen,
                samples_trained: w.samples_trained,
                samples_replayed: w.samples_replayed,
                store_len: w.store_len,
                alive_at_end: w.alive,
            });
        }
        let mut remaps = std::mem::take(&mut self.remaps);
        remaps.sort_by(|a, b| a.0.cmp(&b.0));

        Ok(ClusterResult {
            nodes_started: cfg.nodes,
            ticks: max,
            samples_seen,
            samples_trained,
            samples_replayed,
            drift_detections,
            final_rolling_loss: roll_loss.mean() as f32,
            final_rolling_acc: if classification {
                roll_acc.mean() as f32
            } else {
                f32::NAN
            },
            rolling,
            digest,
            samples_per_sec: samples_seen as f64 / elapsed.max(1e-9),
            gossip_rounds: self.gossip_rounds,
            merges: self.merges,
            gossip_bytes: self.gossip_bytes,
            merge_bytes: self.merge_bytes,
            store_live_total,
            remaps,
            node_summaries: summaries,
            phases: PhaseTimer::default(),
        })
    }

    /// The scheduled-join barrier: boot a fresh worker process from the
    /// survivors' merged state, then run the same join round the thread
    /// coordinator runs — an immediate full-gossip seeding (plus the
    /// cadence merge when it lands on the join tick), joiner included.
    #[allow(clippy::too_many_arguments)]
    fn join_round(
        &mut self,
        sync: u64,
        cadence_merge: bool,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        let join_id = self.cfg.nodes;
        // boot material: every survivor sent its State with the `boot`
        // flag at the segment barrier (bytes uncounted — the in-process
        // join bootstrap never crosses a transport either)
        let (mat, _, contributed) = self.take_states();
        anyhow::ensure!(contributed >= 1, "join bootstrap: no surviving contributors");
        let (tensors, snap) = mat.merged().map_err(|e| anyhow::anyhow!("join bootstrap: {e}"))?;

        self.current_ring.add_node(join_id);
        let mut children = BTreeMap::new();
        if self.cfg.spawn {
            children.insert(join_id, self.spawn_child(join_id)?);
        } else {
            log::info!(
                "coordinator: waiting for an external joiner registration on {}",
                self.addr
            );
        }
        self.fill_slots(children, vec![join_id])?;
        let ji = self
            .workers
            .iter()
            .position(|w| w.id == join_id)
            .expect("joiner registered");
        let assign = Message::Assign {
            node: join_id,
            first_tick: sync,
            config: self.cfg_json.clone(),
            chaos: self.chaos_events.clone(),
            joins: self.joins_events.clone(),
        };
        let boot = Message::MergePayload { round: self.round, tensors, policy: snap };
        anyhow::ensure!(
            self.workers[ji].send(&assign) && self.workers[ji].send(&boot),
            "coordinator: joiner dropped during bootstrap"
        );
        log::info!("cluster: worker {join_id} joined at tick {sync}");

        // join mini-round: no ticks to run (everyone is already at `sync`),
        // but every live worker — joiner included — re-synchronizes via a
        // full gossip round and, on a merge cadence, a cluster merge
        self.uniform_round(
            sync,
            GOSSIP_FULL,
            cadence_merge,
            false,
            Vec::new(),
            classification,
            roll_loss,
            roll_acc,
            rolling,
        )?;
        self.convert_crashes(sync)?;
        let gossip_start = self.span_clock.elapsed_secs();
        let bytes = self.relay_gossip(GOSSIP_FULL);
        self.gossip_bytes += bytes;
        self.gossip_rounds += 1;
        self.trace_event("gossip", sync, bytes);
        let dur = self.span_clock.elapsed_secs() - gossip_start;
        self.trace_span("gossip_relay", sync, None, gossip_start, dur);
        if cadence_merge {
            let merge_start = self.span_clock.elapsed_secs();
            let bytes = self.do_merge()?;
            self.merge_bytes += bytes;
            self.merges += 1;
            self.trace_event("merge", sync, bytes);
            let dur = self.span_clock.elapsed_secs() - merge_start;
            self.trace_span("merge", sync, None, merge_start, dur);
        }
        Ok(())
    }

    /// One elastic-membership decision, taken after a regular segment
    /// barrier: measure the fleet arrival rate (samples/tick) since the
    /// last check, admit a registered standby above the high watermark,
    /// shed the worst straggler below the low one. At most one membership
    /// change per barrier, never below `elastic_min_nodes` or above
    /// `elastic_max_nodes`, and never while crash churn is pending (one
    /// membership event settles before the next is considered).
    #[allow(clippy::too_many_arguments)]
    fn elastic_step(
        &mut self,
        sync: u64,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        let admit_above = self.cfg.elastic_admit_above;
        let shed_below = self.cfg.elastic_shed_below;
        if admit_above == 0.0 && shed_below == 0.0 {
            return Ok(());
        }
        if !self.pending_churn.is_empty() {
            return Ok(());
        }
        // counters of dead workers stay frozen at their last report, so
        // summing over everyone keeps the series monotone across sheds
        let seen: u64 = self.workers.iter().map(|w| w.samples_seen).sum();
        let Some((t0, s0)) = self.last_rate_check.replace((sync, seen)) else {
            return Ok(()); // first barrier: baseline only
        };
        if sync <= t0 {
            return Ok(());
        }
        let rate = seen.saturating_sub(s0) as f64 / (sync - t0) as f64;
        obs::registry()
            .gauge("adaselection_cluster_arrival_rate")
            .set(rate);
        let alive = self.alive_ids().len();
        if admit_above > 0.0
            && rate > admit_above
            && !self.standbys.is_empty()
            && (self.cfg.elastic_max_nodes == 0 || alive < self.cfg.elastic_max_nodes)
        {
            log::info!(
                "cluster: arrival rate {rate:.1}/tick above watermark {admit_above} \
                 — admitting a standby"
            );
            return self.admit_standby(sync, classification, roll_loss, roll_acc, rolling);
        }
        if shed_below > 0.0 && rate < shed_below && alive > self.cfg.elastic_min_nodes {
            log::info!(
                "cluster: arrival rate {rate:.1}/tick below watermark {shed_below} \
                 — shedding the worst straggler"
            );
            self.shed_straggler(sync)?;
        }
        Ok(())
    }

    /// Voluntary scale-in: shed the alive worker with the worst ready-lag
    /// this barrier. The victim completed the barrier at `sync`, so the
    /// leave is clean — ring epoch and backfill horizon coincide and
    /// survivors re-process nothing (the involuntary crash path reuses
    /// the same `ChurnOrder` machinery with a real backfill span).
    fn shed_straggler(&mut self, sync: u64) -> anyhow::Result<()> {
        let Some(vi) = (0..self.workers.len())
            .filter(|&i| self.workers[i].alive && !self.workers[i].crashed)
            .max_by(|&a, &b| {
                self.workers[a]
                    .last_ready_lag
                    .total_cmp(&self.workers[b].last_ready_lag)
            })
        else {
            return Ok(());
        };
        let id = self.workers[vi].id;
        let lag = self.workers[vi].last_ready_lag;
        {
            let w = &mut self.workers[vi];
            let _ = w.send(&Message::Shutdown);
            w.alive = false;
            w.converted = true;
            if let Some(mut c) = w.child.take() {
                let _ = c.wait();
            }
        }
        let before = self.current_ring.clone();
        self.current_ring.remove_node(id);
        anyhow::ensure!(
            !self.current_ring.is_empty(),
            "coordinator: elastic shed emptied the ring"
        );
        let frac = HashRing::remap_fraction(&before, &self.current_ring, REMAP_SAMPLE);
        self.remaps.push((sync, frac));
        self.chaos_events.push((sync, id));
        self.pending_churn.push(ChurnOrder {
            dead: id,
            epoch_tick: sync,
            backfill_to: sync,
        });
        log::info!(
            "cluster: elastic shed of worker {id} at tick {sync} \
             (ready-lag {lag:.3}s, {:.1}% of keys remapped)",
            100.0 * frac
        );
        Ok(())
    }

    /// Voluntary scale-out: promote the oldest standby under a fresh node
    /// id. Mirrors the scheduled join — a no-tick boot round collects the
    /// survivors' merged state, the joiner gets `Assign` + boot payload,
    /// and a full-gossip mini-round seeds its store — except the ring
    /// change is broadcast through the cumulative `joins` list instead of
    /// being precompiled into every schedule.
    fn admit_standby(
        &mut self,
        sync: u64,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        // boot material: a no-tick round where every survivor ships State
        // (sent before the joins list grows, so nobody recompiles early)
        self.uniform_round(
            sync,
            GOSSIP_NONE,
            false,
            true,
            Vec::new(),
            classification,
            roll_loss,
            roll_acc,
            rolling,
        )?;
        self.convert_crashes(sync)?;
        let (mat, _, contributed) = self.take_states();
        anyhow::ensure!(contributed >= 1, "elastic admit: no surviving contributors");
        let (tensors, snap) = mat
            .merged()
            .map_err(|e| anyhow::anyhow!("elastic admit bootstrap: {e}"))?;

        let id = self.next_node_id;
        self.next_node_id += 1;
        let mut w = self.standbys.remove(0);
        w.id = id;
        w.alive = true;
        self.workers.push(w);
        let before = self.current_ring.clone();
        self.current_ring.add_node(id);
        let frac = HashRing::remap_fraction(&before, &self.current_ring, REMAP_SAMPLE);
        self.remaps.push((sync, frac));
        self.joins_events.push((sync, id));
        let wi = self.workers.len() - 1;
        let assign = Message::Assign {
            node: id,
            first_tick: sync,
            config: self.cfg_json.clone(),
            chaos: self.chaos_events.clone(),
            joins: self.joins_events.clone(),
        };
        let boot = Message::MergePayload {
            round: self.round,
            tensors,
            policy: snap,
        };
        anyhow::ensure!(
            self.workers[wi].send(&assign) && self.workers[wi].send(&boot),
            "coordinator: admitted standby dropped during bootstrap"
        );
        self.workers.sort_by_key(|w| w.id);
        log::info!(
            "cluster: elastic admit of a standby as worker {id} at tick {sync} \
             ({} standby(s) left, {:.1}% of keys remapped)",
            self.standbys.len(),
            100.0 * frac
        );

        // seed the joiner: a full-gossip mini-round, everyone included —
        // the survivors learn the grown ring from this round's BarrierGo
        self.uniform_round(
            sync,
            GOSSIP_FULL,
            false,
            false,
            Vec::new(),
            classification,
            roll_loss,
            roll_acc,
            rolling,
        )?;
        self.convert_crashes(sync)?;
        let gossip_start = self.span_clock.elapsed_secs();
        let bytes = self.relay_gossip(GOSSIP_FULL);
        self.gossip_bytes += bytes;
        self.gossip_rounds += 1;
        self.trace_event("gossip", sync, bytes);
        let dur = self.span_clock.elapsed_secs() - gossip_start;
        self.trace_span("gossip_relay", sync, None, gossip_start, dur);
        Ok(())
    }
}

/// Run a multi-process cluster job, spawning workers from the current
/// executable (the CLI path — `adaselection cluster --workers processes`).
pub fn run(cfg: &ClusterConfig) -> anyhow::Result<ClusterResult> {
    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("coordinator: resolve current executable: {e}"))?;
    run_with_exe(cfg, &exe)
}

/// Run with an explicit worker binary — tests and benches pass
/// `env!("CARGO_BIN_EXE_adaselection")` because *their* executable has no
/// `worker` subcommand.
pub fn run_with_exe(cfg: &ClusterConfig, exe: &Path) -> anyhow::Result<ClusterResult> {
    Coordinator::new(cfg, exe.to_path_buf())?.run()
}

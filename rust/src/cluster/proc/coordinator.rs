//! The process coordinator: spawn N worker processes of the current
//! executable and drive the thread coordinator's exact barrier schedule
//! over the `cluster::wire` control plane.
//!
//! Topology is hub-and-spoke: every worker holds one TCP connection to
//! the coordinator; store gossip is relayed through the hub in node-id
//! order (so workers merge peers' entries in the same order the
//! in-process transports deliver them), and merges are computed once at
//! the hub with the shared [`MergeMaterial`] weighted-average code and
//! shipped back as `MergePayload` — the same id-sorted input set every
//! thread node averages for itself, hence the same bits.
//!
//! Failure handling: each worker's reader thread turns a closed
//! connection into a death notice, and heartbeats bound how long a hung
//! process can stall a barrier. A dead worker is converted into the
//! kill-churn path — a ring epoch at the last barrier it completed, a
//! measured bounded remap, and `ChurnOrder`s telling the survivors to
//! re-process the dead shard's share of the lost segment — so training
//! continues with exact arrival coverage. `--chaos-kill-at T` makes the
//! coordinator SIGKILL one child mid-segment on purpose, which is how the
//! crash-recovery e2e exercises this path deterministically enough to
//! assert on.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cluster::node::NodePreq;
use crate::cluster::ring::{HashRing, NodeId};
use crate::cluster::trainer::{
    build_ring_schedule_with, fold_preq_records, sync_points, ClusterResult, MergeMaterial,
    NodeSummary, REMAP_SAMPLE,
};
use crate::cluster::transport::{
    ChurnOrder, Message, TelemetrySnapshot, GOSSIP_DELTA, GOSSIP_FULL, GOSSIP_NONE,
};
use crate::cluster::wire;
use crate::config::ClusterConfig;
use crate::metrics::rolling::{RollingPoint, RollingWindow};
use crate::obs::{self, TraceJournal};
use crate::runtime::{Backend, NativeBackend, TaskKind};
use crate::stream::source::{build_source, StreamKnobs};
use crate::stream::tick::{fnv_fold, FNV_OFFSET};
use crate::util::timer::{PhaseTimer, Stopwatch};

/// How long a worker may stay silent (no frames, no heartbeats) before
/// the coordinator declares it dead and SIGKILLs it. Workers heartbeat
/// every 500 ms from a side thread, so only a truly wedged process trips
/// this.
const STALE_AFTER: Duration = Duration::from_secs(30);

/// Handshake budget for a spawned child to connect and say `Hello`.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// One spawned worker process, as the coordinator sees it.
struct Worker {
    id: NodeId,
    child: Option<Child>,
    /// write half of the control connection
    stream: TcpStream,
    rx: mpsc::Receiver<Option<Message>>,
    last_heard: Arc<Mutex<Instant>>,
    /// participating in the barrier protocol
    alive: bool,
    /// connection lost / process dead, conversion may still be pending
    crashed: bool,
    /// crash already converted into churn (or graceful shutdown)
    converted: bool,
    /// last barrier tick this worker completed (`BarrierReady` received)
    reported_until: u64,
    // -- last reported summary (doubles as the post-mortem record) --
    digest: u64,
    ticks_processed: u64,
    samples_seen: u64,
    samples_trained: u64,
    samples_replayed: u64,
    drift_detections: u64,
    store_len: usize,
    // -- per-barrier stashes --
    barrier_preq: Vec<NodePreq>,
    barrier_gossip: Option<Message>,
    barrier_state: Option<Message>,
}

impl Worker {
    fn send(&mut self, msg: &Message) -> bool {
        if self.crashed {
            return false;
        }
        if let Err(e) = wire::check_encodable(msg) {
            // a coordinator-side bug, not a dead worker: report it loudly
            // and do NOT mark the healthy worker crashed — converting it
            // into kill-churn would mask the real problem as node death
            log::error!(
                "coordinator: refusing unencodable frame for worker {}: {e}",
                self.id
            );
            return false;
        }
        self.send_frame(&wire::encode(msg))
    }

    fn send_frame(&mut self, frame: &[u8]) -> bool {
        if self.crashed {
            return false;
        }
        let ok = self
            .stream
            .write_all(frame)
            .and_then(|_| self.stream.flush())
            .is_ok();
        if !ok {
            self.crashed = true;
        }
        ok
    }

    /// Next non-heartbeat frame, or `None` when the worker is dead
    /// (closed connection or stale heartbeat — the latter also SIGKILLs).
    /// Heartbeats are consumed here: `last_heard` was already stamped by
    /// the reader thread, and the piggybacked telemetry snapshot is
    /// published as per-node registry gauges for the status endpoint.
    fn recv(&mut self) -> Option<Message> {
        loop {
            match self.rx.recv_timeout(Duration::from_millis(200)) {
                Ok(Some(Message::Heartbeat { from, telemetry, .. })) => {
                    publish_worker_heartbeat(from, &telemetry);
                    continue;
                }
                Ok(Some(m)) => return Some(m),
                Ok(None) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                    self.crashed = true;
                    return None;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    let staleness = self.last_heard.lock().unwrap().elapsed();
                    if staleness > STALE_AFTER {
                        log::warn!(
                            "worker {}: silent for {:.1}s (stale threshold {}s) — \
                             declaring dead",
                            self.id,
                            staleness.as_secs_f64(),
                            STALE_AFTER.as_secs()
                        );
                        if let Some(c) = self.child.as_mut() {
                            let _ = c.kill();
                        }
                        self.crashed = true;
                        return None;
                    }
                }
            }
        }
    }

    fn reap(&mut self) {
        if let Some(mut c) = self.child.take() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Publish one worker's heartbeat telemetry as per-node gauges. The
/// heartbeat-age trick: the gauge stores coordinator uptime *at receipt*,
/// so a scraper (or `/status`) computes age as `uptime_now - value`
/// without any wall-clock in the registry.
fn publish_worker_heartbeat(id: NodeId, t: &TelemetrySnapshot) {
    let reg = obs::registry();
    let node = id.to_string();
    let gauge = |name: &str, v: f64| {
        reg.gauge(&obs::series(name, &[("node", node.as_str())])).set(v);
    };
    gauge("adaselection_node_heartbeat_uptime_seconds", obs::uptime_seconds());
    gauge("adaselection_node_ticks_total", t.ticks as f64);
    gauge("adaselection_node_samples_seen", t.samples_seen as f64);
    gauge("adaselection_node_samples_trained", t.samples_trained as f64);
    gauge("adaselection_node_samples_replayed", t.samples_replayed as f64);
    gauge("adaselection_node_drift_detections", t.drift_detections as f64);
    gauge("adaselection_node_store_live", t.store_len as f64);
}

fn reader_thread(
    mut stream: TcpStream,
    tx: mpsc::Sender<Option<Message>>,
    last_heard: Arc<Mutex<Instant>>,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(m)) => {
                *last_heard.lock().unwrap() = Instant::now();
                if tx.send(Some(m)).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => {
                let _ = tx.send(None);
                return;
            }
        }
    }
}

/// The multi-process cluster coordinator (see module docs).
pub struct Coordinator {
    cfg: ClusterConfig,
    cfg_json: String,
    exe: PathBuf,
    listener: TcpListener,
    addr: String,
    workers: Vec<Worker>,
    // churn state
    chaos_events: Vec<(u64, NodeId)>,
    pending_churn: Vec<ChurnOrder>,
    current_ring: HashRing,
    remaps: Vec<(u64, f64)>,
    chaos_fired: bool,
    // accounting
    gossip_rounds: u64,
    merges: u64,
    gossip_bytes: u64,
    merge_bytes: u64,
    /// Monotonically increasing barrier-round id, stamped into every
    /// `BarrierGo`/`MergePayload` frame so workers echo it into their
    /// journal lines and offline analysis can merge by `(round, node)`.
    round: u64,
    /// Run clock for span timestamps — every span's `start` is seconds on
    /// this clock, so coordinator spans in one journal share a timeline.
    span_clock: Stopwatch,
    /// coordinator-side trace journal (`--trace PATH` writes gossip/merge
    /// events here; each worker process journals its ticks to
    /// `PATH.node<id>`)
    journal: Option<TraceJournal>,
}

impl Coordinator {
    /// Bind the control listener and prepare a run. `exe` is the binary
    /// spawned as `exe worker --coordinator ADDR --node-id N` — the
    /// current executable from the CLI, an explicit path from tests and
    /// benches (whose own executable has no `worker` subcommand).
    pub fn new(cfg: &ClusterConfig, exe: PathBuf) -> anyhow::Result<Coordinator> {
        let mut cfg = cfg.clone();
        cfg.worker_mode = "processes".into();
        cfg.validate()?;
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| anyhow::anyhow!("coordinator: bind control listener: {e}"))?;
        let addr = listener.local_addr()?.to_string();
        let cfg_json = cfg.to_json().to_string();
        let current_ring =
            HashRing::with_nodes(cfg.stream.seed, cfg.vnodes, 0..cfg.nodes);
        let journal = match &cfg.stream.trace {
            Some(path) => Some(TraceJournal::open(path)?),
            None => None,
        };
        Ok(Coordinator {
            cfg,
            cfg_json,
            exe,
            listener,
            addr,
            workers: Vec::new(),
            chaos_events: Vec::new(),
            pending_churn: Vec::new(),
            current_ring,
            remaps: Vec::new(),
            chaos_fired: false,
            gossip_rounds: 0,
            merges: 0,
            gossip_bytes: 0,
            merge_bytes: 0,
            round: 0,
            span_clock: Stopwatch::new(),
            journal,
        })
    }

    /// Journal one coordinator-side wire event (gossip relay / merge).
    fn trace_event(&self, kind: &str, tick: u64, bytes: u64) {
        if let Some(j) = &self.journal {
            j.handle().emit_wire_event(kind, self.round, tick, bytes);
        }
    }

    /// Journal one coordinator-side span under the current round. `start`
    /// is seconds on `span_clock`.
    fn trace_span(&self, name: &str, tick: u64, node: Option<usize>, start: f64, duration: f64) {
        if let Some(j) = &self.journal {
            j.handle().emit_span(name, self.round, tick, node, start, duration);
        }
    }

    fn spawn_child(&self, node: NodeId) -> anyhow::Result<Child> {
        Command::new(&self.exe)
            .arg("worker")
            .arg("--coordinator")
            .arg(&self.addr)
            .arg("--node-id")
            .arg(node.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .map_err(|e| {
                anyhow::anyhow!("coordinator: spawn worker {node} ({:?}): {e}", self.exe)
            })
    }

    /// Accept `children` (already spawned, keyed by node id) until every
    /// one has said `Hello`, then register reader threads.
    fn accept_workers(
        &mut self,
        mut children: BTreeMap<NodeId, Child>,
    ) -> anyhow::Result<()> {
        let deadline = Instant::now() + CONNECT_TIMEOUT;
        self.listener.set_nonblocking(true)?;
        while !children.is_empty() {
            match self.listener.accept() {
                Ok((mut stream, peer)) => {
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
                    // a stray local connection (port scanner, curious
                    // operator) must not abort a training run: anything
                    // that is not a clean Hello from a spawned child is
                    // dropped, and we keep accepting until the deadline
                    let id = match wire::read_frame(&mut stream) {
                        Ok(Some(Message::Hello { from })) => from,
                        other => {
                            log::warn!(
                                "coordinator: dropping non-worker connection from {peer} \
                                 (first frame: {other:?})"
                            );
                            continue;
                        }
                    };
                    let Some(child) = children.remove(&id) else {
                        log::warn!(
                            "coordinator: dropping connection claiming unexpected worker id {id}"
                        );
                        continue;
                    };
                    stream.set_read_timeout(None)?;
                    let read_half = stream.try_clone()?;
                    let (tx, rx) = mpsc::channel();
                    let last_heard = Arc::new(Mutex::new(Instant::now()));
                    {
                        let last_heard = last_heard.clone();
                        std::thread::spawn(move || reader_thread(read_half, tx, last_heard));
                    }
                    self.workers.push(Worker {
                        id,
                        child: Some(child),
                        stream,
                        rx,
                        last_heard,
                        alive: true,
                        crashed: false,
                        converted: false,
                        reported_until: 0,
                        digest: FNV_OFFSET,
                        ticks_processed: 0,
                        samples_seen: 0,
                        samples_trained: 0,
                        samples_replayed: 0,
                        drift_detections: 0,
                        store_len: 0,
                        barrier_preq: Vec::new(),
                        barrier_gossip: None,
                        barrier_state: None,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // a child that died before Hello would hang us forever
                    for (id, c) in children.iter_mut() {
                        if let Ok(Some(status)) = c.try_wait() {
                            anyhow::bail!(
                                "coordinator: worker {id} exited during handshake ({status})"
                            );
                        }
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "coordinator: workers never connected: {:?}",
                        children.keys().collect::<Vec<_>>()
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        self.listener.set_nonblocking(false)?;
        // keep id order stable regardless of connect order
        self.workers.sort_by_key(|w| w.id);
        Ok(())
    }

    fn alive_ids(&self) -> Vec<NodeId> {
        self.workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .map(|w| w.id)
            .collect()
    }

    /// Convert every un-converted crash into churn: ring epoch at the last
    /// barrier the dead worker completed, bounded-remap measurement, and a
    /// `ChurnOrder` telling survivors to re-process the dead shard's share
    /// of `[epoch, survivors_at)`.
    fn convert_crashes(&mut self, survivors_at: u64) -> anyhow::Result<()> {
        for i in 0..self.workers.len() {
            if !(self.workers[i].crashed && !self.workers[i].converted) {
                continue;
            }
            let (id, epoch) = (self.workers[i].id, self.workers[i].reported_until);
            let before = self.current_ring.clone();
            self.current_ring.remove_node(id);
            anyhow::ensure!(
                !self.current_ring.is_empty(),
                "coordinator: every worker is dead"
            );
            let frac =
                HashRing::remap_fraction(&before, &self.current_ring, REMAP_SAMPLE);
            self.remaps.push((epoch, frac));
            self.chaos_events.push((epoch, id));
            self.pending_churn.push(ChurnOrder {
                dead: id,
                epoch_tick: epoch,
                backfill_to: survivors_at,
            });
            let w = &mut self.workers[i];
            w.alive = false;
            w.converted = true;
            w.reap();
            log::warn!(
                "coordinator: worker {id} died; converted to churn (epoch @{epoch}, \
                 backfill to {survivors_at}, {:.1}% of keys remapped)",
                100.0 * frac
            );
        }
        Ok(())
    }

    /// Collect the barrier from one worker: `BarrierReady`, then the
    /// payloads its `BarrierGo` flags ordered. Returns an error only for
    /// protocol violations / reported failures — a death just marks the
    /// worker crashed.
    fn collect_one(
        &mut self,
        i: usize,
        sync: u64,
        gossip: u8,
        state_expected: bool,
    ) -> anyhow::Result<()> {
        let w = &mut self.workers[i];
        w.barrier_preq.clear();
        w.barrier_gossip = None;
        w.barrier_state = None;
        if w.crashed {
            return Ok(());
        }
        match w.recv() {
            Some(Message::BarrierReady {
                preq,
                digest,
                ticks_processed,
                samples_seen,
                samples_trained,
                samples_replayed,
                drift_detections,
                store_len,
                failed,
                ..
            }) => {
                anyhow::ensure!(
                    failed.is_empty(),
                    "cluster worker failed: {failed}"
                );
                w.reported_until = sync;
                w.barrier_preq = preq;
                w.digest = digest;
                w.ticks_processed = ticks_processed;
                w.samples_seen = samples_seen;
                w.samples_trained = samples_trained;
                w.samples_replayed = samples_replayed;
                w.drift_detections = drift_detections;
                w.store_len = store_len as usize;
            }
            Some(other) => anyhow::bail!(
                "coordinator: worker {} sent {other:?} instead of BarrierReady",
                w.id
            ),
            None => return Ok(()),
        }
        if gossip != GOSSIP_NONE {
            match w.recv() {
                Some(m @ Message::StoreGossip { .. }) => w.barrier_gossip = Some(m),
                Some(other) => anyhow::bail!(
                    "coordinator: worker {} sent {other:?} instead of StoreGossip",
                    w.id
                ),
                None => return Ok(()),
            }
        }
        if state_expected {
            match w.recv() {
                Some(m @ Message::State { .. }) => w.barrier_state = Some(m),
                Some(other) => anyhow::bail!(
                    "coordinator: worker {} sent {other:?} instead of State",
                    w.id
                ),
                None => return Ok(()),
            }
        }
        Ok(())
    }

    /// Relay the collected gossip messages hub-and-spoke, in sender-id
    /// order, skipping empty deltas exactly like the thread coordinator.
    /// Returns wire bytes shipped to peers (the same `frame_len × peers`
    /// the in-process run reports, so the two modes account identically).
    fn relay_gossip(&mut self, mode: u8) -> u64 {
        let ids = self.alive_ids();
        if ids.len() < 2 {
            return 0;
        }
        let mut bytes = 0u64;
        for i in 0..self.workers.len() {
            if !(self.workers[i].alive && !self.workers[i].crashed) {
                continue;
            }
            let Some(msg) = self.workers[i].barrier_gossip.take() else {
                continue;
            };
            if mode == GOSSIP_DELTA {
                if let Message::StoreGossip { entries, .. } = &msg {
                    if entries.is_empty() {
                        continue; // a quiet shard's delta carries nothing
                    }
                }
            }
            let from = self.workers[i].id;
            let frame = wire::encode(&msg);
            let flen = wire::frame_len(&msg) as u64;
            for j in 0..self.workers.len() {
                if self.workers[j].id == from
                    || !(self.workers[j].alive && !self.workers[j].crashed)
                {
                    continue;
                }
                if self.workers[j].send_frame(&frame) {
                    bytes += flen;
                }
            }
        }
        bytes
    }

    /// Take the barrier `State` stashes from every live worker, in id
    /// order — the single owner of the contributor-set rule shared by
    /// barrier merges and the join bootstrap. Returns the merge material,
    /// the uplink frame bytes, and the contributor count.
    fn take_states(&mut self) -> (MergeMaterial, u64, usize) {
        let mut mat = MergeMaterial::default();
        let mut bytes = 0u64;
        let mut contributed = 0usize;
        for w in &mut self.workers {
            if !(w.alive && !w.crashed) {
                continue;
            }
            if let Some(msg) = w.barrier_state.take() {
                bytes += wire::frame_len(&msg) as u64;
                mat.push(msg);
                contributed += 1;
            }
        }
        (mat, bytes, contributed)
    }

    /// One merge round over the collected `State` material: weighted
    /// average at the hub, `MergePayload` back to every live worker.
    /// Mirrors the thread coordinator's no-op when fewer than two nodes
    /// are alive. Returns wire bytes (uplink states + downlink payloads).
    fn do_merge(&mut self) -> anyhow::Result<u64> {
        if self.alive_ids().len() < 2 {
            return Ok(0);
        }
        let (mat, mut bytes, contributed) = self.take_states();
        anyhow::ensure!(contributed >= 1, "merge with no contributing workers");
        let (avg, snap) = mat.merged()?;
        let payload =
            Message::MergePayload { round: self.round, tensors: avg, policy: snap };
        wire::check_encodable(&payload)?;
        let frame = wire::encode(&payload);
        let flen = wire::frame_len(&payload) as u64;
        for i in 0..self.workers.len() {
            if self.workers[i].alive
                && !self.workers[i].crashed
                && self.workers[i].send_frame(&frame)
            {
                bytes += flen;
            }
        }
        Ok(bytes)
    }

    /// One *uniform* barrier round: the same `BarrierGo` flags to every
    /// live worker, collect the replies, fold the prequential stashes.
    /// Shared by the join mini-round and the crash-recovery round (the
    /// main segment round stays in `drive` — its flags differ per worker
    /// around a scheduled kill/join).
    #[allow(clippy::too_many_arguments)]
    fn uniform_round(
        &mut self,
        until: u64,
        gossip: u8,
        merge: bool,
        churn: Vec<ChurnOrder>,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        self.round += 1;
        let barrier_start = self.span_clock.elapsed_secs();
        let mut flags: Vec<(usize, u8, bool)> = Vec::new();
        for i in 0..self.workers.len() {
            if !(self.workers[i].alive && !self.workers[i].crashed) {
                continue;
            }
            let go = Message::BarrierGo {
                round: self.round,
                until,
                gossip,
                merge,
                boot: false,
                churn: churn.clone(),
            };
            if self.workers[i].send(&go) {
                flags.push((i, gossip, merge));
            }
        }
        for &(i, g, st) in &flags {
            self.collect_one(i, until, g, st)?;
            let lag = self.span_clock.elapsed_secs() - barrier_start;
            let id = self.workers[i].id;
            self.trace_span("ready_lag", until, Some(id), barrier_start, lag);
        }
        let dur = self.span_clock.elapsed_secs() - barrier_start;
        self.trace_span("barrier", until, None, barrier_start, dur);
        self.fold_barrier(classification, roll_loss, roll_acc, rolling);
        Ok(())
    }

    /// Fold this barrier's prequential stashes, in worker-id order — the
    /// same summation order `cluster::run` uses, for bit-identical
    /// rolling traces.
    fn fold_barrier(
        &mut self,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) {
        let per_node: Vec<Vec<NodePreq>> = self
            .workers
            .iter_mut()
            .map(|w| std::mem::take(&mut w.barrier_preq))
            .collect();
        fold_preq_records(&per_node, classification, roll_loss, roll_acc, rolling);
        // fleet-wide gauges for the status endpoint (per-node detail comes
        // in over the heartbeats)
        let reg = obs::registry();
        let loss = roll_loss.mean();
        if loss.is_finite() {
            reg.gauge("adaselection_rolling_loss").set(loss);
        }
        let acc = roll_acc.mean();
        if classification && acc.is_finite() {
            reg.gauge("adaselection_rolling_acc").set(acc);
        }
        let live: usize = self
            .workers
            .iter()
            .filter(|w| w.alive && !w.crashed)
            .map(|w| w.store_len)
            .sum();
        reg.gauge("adaselection_store_live").set(live as f64);
    }

    /// Run the whole job. Consumes the coordinator.
    pub fn run(mut self) -> anyhow::Result<ClusterResult> {
        let r = self.drive();
        // whatever happened, never leave children behind
        for w in &mut self.workers {
            let _ = w.send(&Message::Shutdown);
        }
        for w in &mut self.workers {
            w.reap();
        }
        // all trace senders are transient (per-event handles), so the
        // writer thread drains and exits as soon as the journal's own
        // sender drops inside finish()
        if let Some(j) = self.journal.take() {
            let finished = j.finish();
            if r.is_ok() {
                finished?;
            }
        }
        r
    }

    fn drive(&mut self) -> anyhow::Result<ClusterResult> {
        let cfg = self.cfg.clone();
        let s = &cfg.stream;
        let max = s.max_ticks as u64;
        let delta = cfg.gossip == "delta";

        // traffic/task metadata (for rolling-accuracy semantics), plus the
        // precompiled remap accounting for the *scheduled* churn
        let source = build_source(
            &s.dataset,
            StreamKnobs {
                seed: s.seed,
                drift_period: s.drift_period,
                burst_period: s.burst_period,
                burst_min: s.burst_min,
            },
        )?;
        let probe = NativeBackend::new();
        let meta = probe.family_meta(source.family())?;
        let classification = meta.task != TaskKind::Regression;
        let (_, scheduled_remaps) = build_ring_schedule_with(&cfg, &[]);
        self.remaps = scheduled_remaps;

        log::info!(
            "cluster start (processes): nodes={} vnodes={} stream={} γ={} B={} ticks={} gossip={}({}) merge={} kill@{} join@{} chaos@{}",
            cfg.nodes,
            cfg.vnodes,
            s.dataset,
            s.gamma,
            meta.batch,
            s.max_ticks,
            cfg.gossip_every,
            cfg.gossip,
            cfg.merge_every,
            cfg.kill_at,
            cfg.join_at,
            cfg.chaos_kill_at
        );

        // spawn + handshake + assign
        let mut children = BTreeMap::new();
        for id in 0..cfg.nodes {
            children.insert(id, self.spawn_child(id)?);
        }
        self.accept_workers(children)?;
        let cfg_json = self.cfg_json.clone();
        for w in &mut self.workers {
            let assign = Message::Assign {
                node: w.id,
                first_tick: 0,
                config: cfg_json.clone(),
                chaos: Vec::new(),
            };
            anyhow::ensure!(
                w.send(&assign),
                "coordinator: worker {} dropped before Assign",
                w.id
            );
        }

        let mut roll_loss = RollingWindow::new(s.window);
        let mut roll_acc = RollingWindow::new(s.window);
        let mut rolling: Vec<RollingPoint> = Vec::new();
        let clock = Stopwatch::new();
        let mut prev = 0u64;

        for &sync in &sync_points(&cfg) {
            let is_kill = cfg.kill_at > 0 && cfg.kill_at as u64 == sync;
            let is_join = cfg.join_at > 0 && cfg.join_at as u64 == sync;
            let cadence_gossip = sync < max
                && cfg.gossip_every > 0
                && sync % cfg.gossip_every as u64 == 0
                && !is_join;
            let cadence_merge =
                sync < max && cfg.merge_every > 0 && sync % cfg.merge_every as u64 == 0;
            let gossip_mode = if cadence_gossip {
                if delta && self.gossip_rounds % cfg.full_gossip_every as u64 != 0 {
                    GOSSIP_DELTA
                } else {
                    GOSSIP_FULL
                }
            } else {
                GOSSIP_NONE
            };

            // crashes noticed after the previous barrier's conversion pass
            // (e.g. during relays) become churn *before* this segment runs
            self.convert_crashes(prev)?;
            let churn = std::mem::take(&mut self.pending_churn);

            // ---- segment barrier: GO, (maybe) chaos, collect ----
            self.round += 1;
            let barrier_start = self.span_clock.elapsed_secs();
            let mut flags: Vec<(usize, u8, bool)> = Vec::new(); // (idx, gossip, state?)
            for i in 0..self.workers.len() {
                if !(self.workers[i].alive && !self.workers[i].crashed) {
                    continue;
                }
                let victim = is_kill && self.workers[i].id == cfg.kill_node;
                let g = if victim { GOSSIP_NONE } else { gossip_mode };
                let m = cadence_merge && !victim && !is_join;
                let b = is_join && !victim;
                let go = Message::BarrierGo {
                    round: self.round,
                    until: sync,
                    gossip: g,
                    merge: m,
                    boot: b,
                    churn: churn.clone(),
                };
                if self.workers[i].send(&go) {
                    flags.push((i, g, m || b));
                }
            }
            if cfg.chaos_kill_at > 0
                && !self.chaos_fired
                && prev <= cfg.chaos_kill_at as u64
                && (cfg.chaos_kill_at as u64) < sync
            {
                self.chaos_fired = true;
                // let the segment get going, then SIGKILL mid-flight
                std::thread::sleep(Duration::from_millis(25));
                if let Some(w) = self
                    .workers
                    .iter_mut()
                    .find(|w| w.id == cfg.chaos_kill_node && w.alive)
                {
                    log::warn!("coordinator: chaos-killing worker {}", w.id);
                    if let Some(c) = w.child.as_mut() {
                        let _ = c.kill();
                    }
                }
            }
            for &(i, g, st) in &flags {
                self.collect_one(i, sync, g, st)?;
                let lag = self.span_clock.elapsed_secs() - barrier_start;
                let id = self.workers[i].id;
                self.trace_span("ready_lag", sync, Some(id), barrier_start, lag);
            }
            let dur = self.span_clock.elapsed_secs() - barrier_start;
            self.trace_span("barrier", sync, None, barrier_start, dur);
            self.fold_barrier(classification, &mut roll_loss, &mut roll_acc, &mut rolling);

            // ---- churn: crashes first (mirrors kill-before-gossip), then
            // the scheduled kill, then the scheduled join ----
            self.convert_crashes(sync)?;
            if is_kill {
                if let Some(w) = self
                    .workers
                    .iter_mut()
                    .find(|w| w.id == cfg.kill_node && w.alive && !w.crashed)
                {
                    let _ = w.send(&Message::Shutdown);
                    w.alive = false;
                    w.converted = true;
                    if let Some(mut c) = w.child.take() {
                        let _ = c.wait();
                    }
                    log::info!("cluster: killed worker {} at tick {sync}", cfg.kill_node);
                }
                self.current_ring.remove_node(cfg.kill_node);
            }

            if cadence_gossip {
                let gossip_start = self.span_clock.elapsed_secs();
                let bytes = self.relay_gossip(gossip_mode);
                self.gossip_bytes += bytes;
                self.gossip_rounds += 1;
                self.trace_event("gossip", sync, bytes);
                let dur = self.span_clock.elapsed_secs() - gossip_start;
                self.trace_span("gossip_relay", sync, None, gossip_start, dur);
            }

            if is_join {
                self.join_round(
                    sync,
                    cadence_merge,
                    classification,
                    &mut roll_loss,
                    &mut roll_acc,
                    &mut rolling,
                )?;
            } else if cadence_merge {
                let merge_start = self.span_clock.elapsed_secs();
                let bytes = self.do_merge()?;
                self.merge_bytes += bytes;
                self.merges += 1;
                self.trace_event("merge", sync, bytes);
                let dur = self.span_clock.elapsed_secs() - merge_start;
                self.trace_span("merge", sync, None, merge_start, dur);
            }
            prev = sync;
        }

        // a worker that died during the *final* segment (or final relays)
        // leaves churn no later BarrierGo can deliver — run one recovery
        // round so survivors still backfill the dead shard's share and
        // report their corrected counters, keeping arrival coverage exact
        self.convert_crashes(max)?;
        let churn = std::mem::take(&mut self.pending_churn);
        if !churn.is_empty() {
            self.uniform_round(
                max,
                GOSSIP_NONE,
                false,
                churn,
                classification,
                &mut roll_loss,
                &mut roll_acc,
                &mut rolling,
            )?;
            self.convert_crashes(max)?;
            if !self.pending_churn.is_empty() {
                // a second death during recovery: nobody left to backfill
                // for it — surface the coverage gap instead of hiding it
                log::warn!(
                    "coordinator: {} churn event(s) could not be backfilled before \
                     shutdown; arrival coverage may be short",
                    self.pending_churn.len()
                );
            }
        }

        // graceful shutdown; the final barrier already reported every
        // worker's end-of-run counters
        for w in &mut self.workers {
            if w.alive && !w.crashed {
                let _ = w.send(&Message::Shutdown);
            }
        }
        for w in &mut self.workers {
            if w.alive {
                if let Some(mut c) = w.child.take() {
                    let _ = c.wait();
                }
            }
        }

        let elapsed = clock.elapsed_secs();
        let mut digest = FNV_OFFSET;
        let mut samples_seen = 0u64;
        let mut samples_trained = 0u64;
        let mut samples_replayed = 0u64;
        let mut drift_detections = 0u64;
        let mut store_live_total = 0usize;
        let mut summaries = Vec::new();
        for w in &self.workers {
            digest = fnv_fold(digest, w.digest);
            samples_seen += w.samples_seen;
            samples_trained += w.samples_trained;
            samples_replayed += w.samples_replayed;
            drift_detections += w.drift_detections;
            if w.alive {
                store_live_total += w.store_len;
            }
            summaries.push(NodeSummary {
                id: w.id,
                ticks_processed: w.ticks_processed,
                samples_seen: w.samples_seen,
                samples_trained: w.samples_trained,
                samples_replayed: w.samples_replayed,
                store_len: w.store_len,
                alive_at_end: w.alive,
            });
        }
        let mut remaps = std::mem::take(&mut self.remaps);
        remaps.sort_by(|a, b| a.0.cmp(&b.0));

        Ok(ClusterResult {
            nodes_started: cfg.nodes,
            ticks: max,
            samples_seen,
            samples_trained,
            samples_replayed,
            drift_detections,
            final_rolling_loss: roll_loss.mean() as f32,
            final_rolling_acc: if classification {
                roll_acc.mean() as f32
            } else {
                f32::NAN
            },
            rolling,
            digest,
            samples_per_sec: samples_seen as f64 / elapsed.max(1e-9),
            gossip_rounds: self.gossip_rounds,
            merges: self.merges,
            gossip_bytes: self.gossip_bytes,
            merge_bytes: self.merge_bytes,
            store_live_total,
            remaps,
            node_summaries: summaries,
            phases: PhaseTimer::default(),
        })
    }

    /// The scheduled-join barrier: boot a fresh worker process from the
    /// survivors' merged state, then run the same join round the thread
    /// coordinator runs — an immediate full-gossip seeding (plus the
    /// cadence merge when it lands on the join tick), joiner included.
    #[allow(clippy::too_many_arguments)]
    fn join_round(
        &mut self,
        sync: u64,
        cadence_merge: bool,
        classification: bool,
        roll_loss: &mut RollingWindow,
        roll_acc: &mut RollingWindow,
        rolling: &mut Vec<RollingPoint>,
    ) -> anyhow::Result<()> {
        let join_id = self.cfg.nodes;
        // boot material: every survivor sent its State with the `boot`
        // flag at the segment barrier (bytes uncounted — the in-process
        // join bootstrap never crosses a transport either)
        let (mat, _, contributed) = self.take_states();
        anyhow::ensure!(contributed >= 1, "join bootstrap: no surviving contributors");
        let (tensors, snap) = mat.merged().map_err(|e| anyhow::anyhow!("join bootstrap: {e}"))?;

        self.current_ring.add_node(join_id);
        let mut children = BTreeMap::new();
        children.insert(join_id, self.spawn_child(join_id)?);
        self.accept_workers(children)?;
        let ji = self
            .workers
            .iter()
            .position(|w| w.id == join_id)
            .expect("joiner registered");
        let assign = Message::Assign {
            node: join_id,
            first_tick: sync,
            config: self.cfg_json.clone(),
            chaos: self.chaos_events.clone(),
        };
        let boot = Message::MergePayload { round: self.round, tensors, policy: snap };
        anyhow::ensure!(
            self.workers[ji].send(&assign) && self.workers[ji].send(&boot),
            "coordinator: joiner dropped during bootstrap"
        );
        log::info!("cluster: worker {join_id} joined at tick {sync}");

        // join mini-round: no ticks to run (everyone is already at `sync`),
        // but every live worker — joiner included — re-synchronizes via a
        // full gossip round and, on a merge cadence, a cluster merge
        self.uniform_round(
            sync,
            GOSSIP_FULL,
            cadence_merge,
            Vec::new(),
            classification,
            roll_loss,
            roll_acc,
            rolling,
        )?;
        self.convert_crashes(sync)?;
        let gossip_start = self.span_clock.elapsed_secs();
        let bytes = self.relay_gossip(GOSSIP_FULL);
        self.gossip_bytes += bytes;
        self.gossip_rounds += 1;
        self.trace_event("gossip", sync, bytes);
        let dur = self.span_clock.elapsed_secs() - gossip_start;
        self.trace_span("gossip_relay", sync, None, gossip_start, dur);
        if cadence_merge {
            let merge_start = self.span_clock.elapsed_secs();
            let bytes = self.do_merge()?;
            self.merge_bytes += bytes;
            self.merges += 1;
            self.trace_event("merge", sync, bytes);
            let dur = self.span_clock.elapsed_secs() - merge_start;
            self.trace_span("merge", sync, None, merge_start, dur);
        }
        Ok(())
    }
}

/// Run a multi-process cluster job, spawning workers from the current
/// executable (the CLI path — `adaselection cluster --workers processes`).
pub fn run(cfg: &ClusterConfig) -> anyhow::Result<ClusterResult> {
    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("coordinator: resolve current executable: {e}"))?;
    run_with_exe(cfg, &exe)
}

/// Run with an explicit worker binary — tests and benches pass
/// `env!("CARGO_BIN_EXE_adaselection")` because *their* executable has no
/// `worker` subcommand.
pub fn run_with_exe(cfg: &ClusterConfig, exe: &Path) -> anyhow::Result<ClusterResult> {
    Coordinator::new(cfg, exe.to_path_buf())?.run()
}

//! The cluster coordinator: N in-process worker nodes sharding one stream.
//!
//! Topology and life cycle:
//!
//!   * a seeded consistent-hash [`HashRing`] (vnodes per node) assigns
//!     every instance id to exactly one owner; the deterministic churn
//!     schedule (optional kill + join) is compiled into a [`RingSchedule`]
//!     up front, so each node's [`PartitionProducer`] resolves ownership
//!     purely from the tick;
//!   * between *sync barriers* (gossip/merge cadences, churn events, run
//!     end) nodes train their shards concurrently on scoped threads —
//!     they share nothing but the barrier protocol, so the run is
//!     deterministic regardless of scheduling;
//!   * at a gossip barrier every node broadcasts its [`InstanceStore`]
//!     snapshot over the [`Transport`] and merges peers' snapshots
//!     freshest-tick-wins — every node converges on cluster-wide
//!     loss/gnorm statistics;
//!   * at a merge barrier every node broadcasts `Backend::export_state`
//!     tensors plus its AdaSelection snapshot, each weighted by training
//!     volume since the last merge, and replaces its own state with the
//!     weighted average (`runtime::average_states`,
//!     `selection::merge_snapshots`) — federated-averaging style;
//!   * a killed node stops mid-run (its un-gossiped store tail is lost,
//!     exactly like a real crash); a joining node boots from the merged
//!     cluster state and is seeded by an immediate gossip round, and the
//!     ring remaps only the bounded key fraction consistent hashing
//!     guarantees (`ClusterResult::remaps` measures it).
//!
//! Prequential quality is cluster-wide: per tick, the coordinator sums
//! each shard's (loss, correct, arrivals) and feeds the combined mean to
//! one rolling window — directly comparable to a single-node
//! `StreamTrainer` run over the same traffic.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::node::ClusterNode;
use crate::cluster::ring::{HashRing, NodeId, RingSchedule};
use crate::cluster::tcp::Tcp;
use crate::cluster::transport::{Loopback, Message, Transport};
use crate::cluster::wire;
use crate::config::ClusterConfig;
use crate::metrics::rolling::{RollingPoint, RollingWindow};
use crate::obs::trace::{span_line, wire_event_line};
use crate::obs::{self, flight, HealthEngine, HealthInputs, HealthMode, StatusServer, TraceJournal};
use crate::runtime::{average_states, Backend, NativeBackend, TaskKind, Tensor};
use crate::selection::adaselection::merge_snapshots;
use crate::selection::policy::Policy;
use crate::selection::AdaSnapshot;
use crate::stream::source::{build_source, StreamKnobs};
use crate::stream::store::InstanceStore;
use crate::stream::tick::{fnv_fold, DriftGamma, TickEngine, FNV_OFFSET};
use crate::util::timer::{PhaseTimer, Stopwatch};

/// Keys sampled when measuring churn remap fractions.
pub(crate) const REMAP_SAMPLE: u64 = 4096;

/// Per-node accounting in the run report.
#[derive(Clone, Debug)]
pub struct NodeSummary {
    pub id: NodeId,
    pub ticks_processed: u64,
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    pub store_len: usize,
    pub alive_at_end: bool,
}

/// Result of one cluster run.
pub struct ClusterResult {
    pub nodes_started: usize,
    pub ticks: u64,
    /// arrivals across all shards (every chunk row is owned exactly once)
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    pub drift_detections: u64,
    pub final_rolling_loss: f32,
    pub final_rolling_acc: f32,
    /// cluster-wide rolling prequential trace (one point per eval tick)
    pub rolling: Vec<RollingPoint>,
    /// node digests folded in id order — two identical runs match exactly
    pub digest: u64,
    /// aggregate arrivals per wall-clock second
    pub samples_per_sec: f64,
    pub gossip_rounds: u64,
    pub merges: u64,
    /// wire bytes of every store-gossip frame sent (computed with
    /// [`wire::frame_len`] for *all* transports, so a loopback run reports
    /// exactly the bandwidth a socket run ships)
    pub gossip_bytes: u64,
    /// wire bytes of every model/policy merge frame sent
    pub merge_bytes: u64,
    /// live store records summed over surviving nodes
    pub store_live_total: usize,
    /// per churn event: (tick, fraction of sampled keys that changed owner)
    pub remaps: Vec<(u64, f64)>,
    pub node_summaries: Vec<NodeSummary>,
    /// phase totals summed across nodes
    pub phases: PhaseTimer,
}

/// Barrier ticks: gossip/merge cadences, churn events, and the run end.
/// Shared with the process coordinator (`cluster::proc`), which must drive
/// the exact same barrier sequence for bit-identical runs.
pub(crate) fn sync_points(cfg: &ClusterConfig) -> Vec<u64> {
    let max = cfg.stream.max_ticks as u64;
    let mut pts: Vec<u64> = Vec::new();
    for every in [cfg.gossip_every as u64, cfg.merge_every as u64] {
        if every > 0 {
            let mut t = every;
            while t < max {
                pts.push(t);
                t += every;
            }
        }
    }
    if cfg.kill_at > 0 {
        pts.push(cfg.kill_at as u64);
    }
    if cfg.join_at > 0 {
        pts.push(cfg.join_at as u64);
    }
    pts.push(max);
    pts.sort_unstable();
    pts.dedup();
    pts.retain(|&t| t > 0);
    pts
}

/// Compile the churn schedule into ring epochs, measuring the remapped key
/// fraction at every membership change. `extra_kills` carries churn the
/// config never scheduled — the process coordinator converts a crashed
/// worker into exactly such an event, and every surviving worker rebuilds
/// its schedule from the same list so ownership stays a pure function of
/// the tick.
pub(crate) fn build_ring_schedule_with(
    cfg: &ClusterConfig,
    extra_kills: &[(u64, NodeId)],
) -> (Arc<RingSchedule>, Vec<(u64, f64)>) {
    build_ring_schedule_with_events(cfg, extra_kills, &[])
}

/// [`build_ring_schedule_with`], plus unscheduled joins: the elastic
/// coordinator admits registered standbys at runtime, and every worker
/// (survivors via `BarrierGo::joins`, late joiners via `Assign::joins`)
/// rebuilds its schedule from the same `(tick, node)` lists so ownership
/// stays a pure function of the tick across the whole fleet.
pub(crate) fn build_ring_schedule_with_events(
    cfg: &ClusterConfig,
    extra_kills: &[(u64, NodeId)],
    extra_joins: &[(u64, NodeId)],
) -> (Arc<RingSchedule>, Vec<(u64, f64)>) {
    let mut ring = HashRing::with_nodes(cfg.stream.seed, cfg.vnodes, 0..cfg.nodes);
    let mut sched = RingSchedule::new(ring.clone());
    // group events by tick so a same-tick kill+join becomes one epoch
    let mut events: BTreeMap<u64, Vec<MembershipEvent>> = BTreeMap::new();
    if cfg.kill_at > 0 {
        events
            .entry(cfg.kill_at as u64)
            .or_default()
            .push(MembershipEvent::Kill(cfg.kill_node));
    }
    if cfg.join_at > 0 {
        events
            .entry(cfg.join_at as u64)
            .or_default()
            .push(MembershipEvent::Join(cfg.nodes));
    }
    for &(tick, node) in extra_kills {
        events.entry(tick).or_default().push(MembershipEvent::Kill(node));
    }
    for &(tick, node) in extra_joins {
        events.entry(tick).or_default().push(MembershipEvent::Join(node));
    }
    let mut remaps = Vec::new();
    for (tick, evs) in events {
        let before = ring.clone();
        for ev in evs {
            match ev {
                MembershipEvent::Kill(n) => ring.remove_node(n),
                MembershipEvent::Join(n) => ring.add_node(n),
            }
        }
        remaps.push((tick, HashRing::remap_fraction(&before, &ring, REMAP_SAMPLE)));
        sched.push(tick, ring.clone());
    }
    (Arc::new(sched), remaps)
}

fn build_ring_schedule(cfg: &ClusterConfig) -> (Arc<RingSchedule>, Vec<(u64, f64)>) {
    build_ring_schedule_with(cfg, &[])
}

#[derive(Clone, Copy, Debug)]
enum MembershipEvent {
    Kill(NodeId),
    Join(NodeId),
}

/// Per-node replay budget: the node's fair share of ⌈γB⌉. One definition
/// for both worker runtimes — thread/process digest parity depends on
/// this arithmetic being identical.
pub(crate) fn replay_budget(cfg: &ClusterConfig, b: usize) -> usize {
    (((cfg.stream.gamma * b as f64) / cfg.nodes as f64).ceil() as usize).clamp(1, b)
}

/// Build one node's tick engine from the stream config.
pub(crate) fn make_engine(
    cfg: &ClusterConfig,
    node: NodeId,
    chunk_rows: usize,
    replay_budget: usize,
) -> anyhow::Result<TickEngine> {
    let s = &cfg.stream;
    // fold the node id into the policy seed so stochastic baselines
    // (uniform/adaboost) draw independent streams per shard
    let policy = Policy::from_config_with_seed(s, s.seed.wrapping_add(node as u64))?;
    let drift = DriftGamma::from_config(s, &policy)?;
    let store = InstanceStore::new(s.store_capacity, s.store_shards);
    if cfg.gossip == "delta" {
        store.enable_dirty_tracking();
    }
    let mut engine = TickEngine::new(policy, store, s.gamma, s.lr, chunk_rows);
    engine.drift = drift;
    if s.replay {
        engine.replay_budget = Some(replay_budget);
    }
    Ok(engine)
}

/// Run every alive node up to `end` on its own thread, then surface any
/// captured worker error.
fn run_segment(
    nodes: &mut [ClusterNode<NativeBackend>],
    end: u64,
) -> anyhow::Result<Vec<(NodeId, f64)>> {
    // per-node ready lag: seconds from barrier open (all threads start
    // together) until that node finished its share — the straggler is the
    // max. Telemetry-only; the scope still joins every thread.
    let mut lags: Vec<(NodeId, f64)> = Vec::new();
    std::thread::scope(|scope| {
        let handles: Vec<_> = nodes
            .iter_mut()
            .filter(|n| n.alive)
            .map(|node| {
                let id = node.id;
                let h = scope.spawn(move || {
                    let sw = Stopwatch::new();
                    node.run_until(end);
                    sw.elapsed_secs()
                });
                (id, h)
            })
            .collect();
        for (id, h) in handles {
            let secs = h.join().expect("cluster worker thread panicked");
            lags.push((id, secs));
        }
    });
    for n in nodes.iter() {
        if let Some(e) = &n.failed {
            anyhow::bail!("cluster worker failed: {e}");
        }
    }
    Ok(lags)
}

/// One gossip round: every alive node broadcasts its store entries (full
/// snapshot or dirty delta, in node-id order) and merges what it
/// received, freshest-tick-wins. Returns the wire bytes sent.
fn gossip_stores(
    nodes: &mut [ClusterNode<NativeBackend>],
    transport: &dyn Transport,
    full: bool,
) -> anyhow::Result<u64> {
    let ids: Vec<NodeId> = nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
    if ids.len() < 2 {
        return Ok(0);
    }
    let mut bytes = 0u64;
    for n in nodes.iter().filter(|n| n.alive) {
        let msg = n.gossip_message(full);
        // a quiet shard's delta is empty: merging it is a no-op, so skip
        // the frames (and, over TCP, the per-peer ack round-trips)
        if !full {
            if let Message::StoreGossip { entries, .. } = &msg {
                if entries.is_empty() {
                    continue;
                }
            }
        }
        let peers: Vec<NodeId> = ids.iter().copied().filter(|&to| to != n.id).collect();
        transport.broadcast(&peers, &msg)?;
        bytes += wire::frame_len(&msg) as u64 * peers.len() as u64;
    }
    for n in nodes.iter_mut().filter(|n| n.alive) {
        for m in transport.drain(n.id) {
            if let Message::StoreGossip { entries, .. } = m {
                n.merge_store(entries.as_slice());
            }
        }
    }
    Ok(bytes)
}

/// Merge material accumulated from `Message::State`s — the single owner
/// of the weighted-average semantics shared by barrier merges, join
/// bootstrapping, and the process coordinator's `MergePayload` rounds.
#[derive(Default)]
pub(crate) struct MergeMaterial {
    states: Vec<Vec<Tensor>>,
    snaps: Vec<AdaSnapshot>,
    weights: Vec<f64>,
    missing_snaps: bool,
}

impl MergeMaterial {
    pub(crate) fn push(&mut self, m: Message) {
        if let Message::State { weight, tensors, policy, .. } = m {
            self.weights.push(weight);
            self.states.push(tensors);
            match policy {
                Some(s) => self.snaps.push(s),
                None => self.missing_snaps = true,
            }
        }
    }

    /// Weighted-average model tensors + merged policy snapshot (None when
    /// any contributor has no snapshot — stateless policies stay local).
    pub(crate) fn merged(&self) -> anyhow::Result<(Vec<Tensor>, Option<AdaSnapshot>)> {
        anyhow::ensure!(!self.states.is_empty(), "merge with no contributing nodes");
        let avg = average_states(&self.states, &self.weights)?;
        let snap = if !self.missing_snaps && !self.snaps.is_empty() {
            Some(merge_snapshots(&self.snaps, &self.weights)?)
        } else {
            None
        };
        Ok((avg, snap))
    }
}

/// One merge round: every alive node broadcasts (state tensors, policy
/// snapshot, volume weight); each replaces its state with the weighted
/// average over the identical, id-ordered message set — so all nodes
/// leave the barrier bit-identical. Every node averaging for itself is
/// deliberate (decentralized semantics a socket transport keeps); at
/// in-process scale the redundant arithmetic is noise. Returns the wire
/// bytes sent.
fn merge_models(
    nodes: &mut [ClusterNode<NativeBackend>],
    transport: &dyn Transport,
) -> anyhow::Result<u64> {
    let ids: Vec<NodeId> = nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
    if ids.len() < 2 {
        return Ok(0);
    }
    // export once per node, broadcast to peers, keep the original for self
    let mut own: BTreeMap<NodeId, Message> = BTreeMap::new();
    for n in nodes.iter().filter(|n| n.alive) {
        own.insert(n.id, n.state_message()?);
    }
    let mut bytes = 0u64;
    for (&from, msg) in &own {
        let peers: Vec<NodeId> = ids.iter().copied().filter(|&to| to != from).collect();
        transport.broadcast(&peers, msg)?;
        bytes += wire::frame_len(msg) as u64 * peers.len() as u64;
    }
    for n in nodes.iter_mut().filter(|n| n.alive) {
        let mut msgs = transport.drain(n.id);
        msgs.push(own.remove(&n.id).expect("alive node exported its state"));
        msgs.sort_by_key(|m| m.from_node());
        let mut mat = MergeMaterial::default();
        for m in msgs {
            mat.push(m);
        }
        let (avg, snap) = mat.merged()?;
        n.apply_merged(&avg, snap.as_ref())?;
    }
    Ok(bytes)
}

/// The merged cluster state a joining node boots from.
fn merged_boot_state(
    nodes: &[ClusterNode<NativeBackend>],
) -> anyhow::Result<(Vec<Tensor>, Option<AdaSnapshot>)> {
    let mut mat = MergeMaterial::default();
    for n in nodes.iter().filter(|n| n.alive) {
        mat.push(n.state_message()?);
    }
    mat.merged()
        .map_err(|e| anyhow::anyhow!("join bootstrap: {e}"))
}

/// Fold one barrier's prequential records (grouped per node, in node-id
/// order) into the cluster-wide rolling windows. Shared with the process
/// coordinator: the per-node iteration order fixes the float summation
/// order, so both coordinators produce bit-identical rolling traces.
pub(crate) fn fold_preq_records(
    per_node: &[Vec<crate::cluster::node::NodePreq>],
    classification: bool,
    roll_loss: &mut RollingWindow,
    roll_acc: &mut RollingWindow,
    rolling: &mut Vec<RollingPoint>,
) {
    let mut per_tick: BTreeMap<u64, (f64, f64, u64)> = BTreeMap::new();
    for records in per_node {
        for p in records {
            let e = per_tick.entry(p.tick).or_insert((0.0, 0.0, 0));
            e.0 += p.loss_sum as f64;
            e.1 += p.correct as f64;
            e.2 += p.arrivals as u64;
        }
    }
    for (tick, (loss_sum, correct, arrivals)) in per_tick {
        if arrivals == 0 {
            continue;
        }
        roll_loss.push(loss_sum / arrivals as f64);
        if classification {
            roll_acc.push(correct / arrivals as f64);
        }
        rolling.push(RollingPoint {
            tick,
            loss: roll_loss.mean() as f32,
            acc: roll_acc.mean() as f32,
        });
    }
}

/// Fold the barrier's drained prequential records into the cluster-wide
/// rolling windows (ticks are complete once every alive node passed them).
fn fold_preq(
    nodes: &mut [ClusterNode<NativeBackend>],
    classification: bool,
    roll_loss: &mut RollingWindow,
    roll_acc: &mut RollingWindow,
    rolling: &mut Vec<RollingPoint>,
) {
    let per_node: Vec<Vec<crate::cluster::node::NodePreq>> =
        nodes.iter_mut().map(|n| n.take_preq()).collect();
    fold_preq_records(&per_node, classification, roll_loss, roll_acc, rolling);
}

/// Publish fleet-wide rolling gauges plus per-node liveness gauges at a
/// sync barrier — the thread-mode equivalent of the heartbeat telemetry
/// the process coordinator aggregates, so `/status` reads the same
/// series in both worker modes.
fn publish_barrier_gauges(
    nodes: &[ClusterNode<NativeBackend>],
    classification: bool,
    roll_loss: &RollingWindow,
    roll_acc: &RollingWindow,
) {
    let reg = obs::registry();
    let loss = roll_loss.mean();
    if loss.is_finite() {
        reg.gauge("adaselection_rolling_loss").set(loss);
    }
    let acc = roll_acc.mean();
    if classification && acc.is_finite() {
        reg.gauge("adaselection_rolling_acc").set(acc);
    }
    let mut live = 0usize;
    let mut alive = 0usize;
    for n in nodes.iter() {
        let id = n.id.to_string();
        let gauge = |name: &str, v: f64| {
            reg.gauge(&obs::series(name, &[("node", id.as_str())])).set(v);
        };
        gauge("adaselection_node_alive", n.alive as u8 as f64);
        if !n.alive {
            continue;
        }
        alive += 1;
        live += n.engine.store.len();
        gauge("adaselection_node_heartbeat_uptime_seconds", obs::uptime_seconds());
        gauge("adaselection_node_ticks_total", n.tick_digests.len() as f64);
        gauge("adaselection_node_store_live", n.engine.store.len() as f64);
    }
    reg.gauge("adaselection_store_live").set(live as f64);
    reg.gauge("adaselection_cluster_nodes").set(alive as f64);
    // the thread runtime has no registration pool; the process coordinator
    // overwrites this with the real standby count
    reg.gauge("adaselection_cluster_standbys").set(0.0);
}

/// Publish per-node barrier ready-lag gauges — the series the
/// `straggler_ready_lag` health rule medians over. Shared with the
/// process coordinator so both worker modes feed the same rule.
pub(crate) fn publish_ready_lag_gauges(lags: &[(NodeId, f64)]) {
    let reg = obs::registry();
    for &(id, secs) in lags {
        let id = id.to_string();
        reg.gauge(&obs::series(
            "adaselection_node_ready_lag_seconds",
            &[("node", id.as_str())],
        ))
        .set(secs);
    }
}

/// Run a full cluster job on the native backend. Dispatches on
/// `worker_mode`: the in-process thread runtime below, or the
/// multi-process runtime (`cluster::proc`) spawning one OS process per
/// node from the current executable.
pub fn run(cfg: &ClusterConfig) -> anyhow::Result<ClusterResult> {
    // the status endpoint serves both worker modes from the coordinator
    // process; it only reads the registry, never the training state
    let _status = match &cfg.stream.status_addr {
        Some(addr) => Some(StatusServer::start(addr)?),
        None => None,
    };
    if cfg.worker_mode == "processes" {
        return crate::cluster::proc::run(cfg);
    }
    cfg.validate()?;
    let s = &cfg.stream;
    anyhow::ensure!(
        s.backend == "native",
        "cluster runs are native-only (got backend '{}')",
        s.backend
    );
    let source = build_source(
        &s.dataset,
        StreamKnobs {
            seed: s.seed,
            drift_period: s.drift_period,
            burst_period: s.burst_period,
            burst_min: s.burst_min,
        },
    )?;
    let probe = NativeBackend::new();
    let meta = probe.family_meta(source.family())?;
    let b = meta.batch;
    let max_ticks = s.max_ticks as u64;
    let classification = meta.task != TaskKind::Regression;

    let (rings, remaps) = build_ring_schedule(cfg);
    let transport: Box<dyn Transport> = match cfg.transport.as_str() {
        "tcp" => Box::new(Tcp::new()),
        _ => Box::new(Loopback::new()),
    };
    let delta_gossip = cfg.gossip == "delta";
    let replay_budget = replay_budget(cfg, b);

    let mut nodes: Vec<ClusterNode<NativeBackend>> = Vec::new();
    for id in 0..cfg.nodes {
        let mut backend = NativeBackend::new();
        // every node boots the same seed → identical initial weights
        let state = backend.init_state(&meta.name, s.seed as i32)?;
        let engine = make_engine(cfg, id, b, replay_budget)?;
        transport.register(id);
        nodes.push(ClusterNode::new(
            id,
            backend,
            state,
            engine,
            meta.name.clone(),
            source.clone(),
            rings.clone(),
            b,
            0,
            s.max_ticks,
            s.eval_every,
            s.workers,
            s.capacity,
        ));
    }

    // one journal for the whole in-process cluster: per-node tick events
    // interleave across nodes but stay tick-contiguous within each node,
    // and gossip/merge events are emitted coordinator-side
    let journal = match &s.trace {
        Some(path) => Some(TraceJournal::open(path)?),
        None => None,
    };
    let trace = journal.as_ref().map(|j| j.handle());
    for n in nodes.iter_mut() {
        n.attach_observer(trace.clone());
    }
    // the flight ring records tick/span/wire/alert lines whether or not a
    // journal is open; a panic or SIGTERM dumps the last rounds to disk
    flight::set_dump_path(flight::default_dump_path(s.trace.as_deref()));
    flight::install_crash_hooks();
    let mut health = HealthEngine::new(HealthMode::parse(&s.health)?);
    health.attach_trace(trace.clone());

    log::info!(
        "cluster start: nodes={} vnodes={} stream={} γ={} B={} ticks={} gossip={}({}) merge={} transport={} kill@{} join@{}",
        cfg.nodes,
        cfg.vnodes,
        s.dataset,
        s.gamma,
        b,
        s.max_ticks,
        cfg.gossip_every,
        cfg.gossip,
        cfg.merge_every,
        cfg.transport,
        cfg.kill_at,
        cfg.join_at
    );

    let mut roll_loss = RollingWindow::new(s.window);
    let mut roll_acc = RollingWindow::new(s.window);
    let mut rolling: Vec<RollingPoint> = Vec::new();
    let mut gossip_rounds = 0u64;
    let mut merges = 0u64;
    let mut gossip_bytes = 0u64;
    let mut merge_bytes = 0u64;
    let clock = Stopwatch::new();
    let mut round = 0u64;

    for &sync in &sync_points(cfg) {
        round += 1;
        for n in nodes.iter_mut().filter(|n| n.alive) {
            n.set_round(round);
        }
        let barrier_start = clock.elapsed_secs();
        let lags = run_segment(&mut nodes, sync)?;
        // barrier span covers open → all nodes ready; per-node ready_lag
        // spans time each node's share of the segment. Lines flow through
        // emit_journal so the flight ring sees them even without --trace.
        let dur = clock.elapsed_secs() - barrier_start;
        obs::emit_journal(trace.as_ref(), span_line("barrier", round, sync, None, barrier_start, dur));
        for &(id, secs) in &lags {
            obs::emit_journal(
                trace.as_ref(),
                span_line("ready_lag", round, sync, Some(id), barrier_start, secs),
            );
        }
        fold_preq(&mut nodes, classification, &mut roll_loss, &mut roll_acc, &mut rolling);
        publish_barrier_gauges(&nodes, classification, &roll_loss, &roll_acc);
        publish_ready_lag_gauges(&lags);
        if !health.mode().is_off() {
            let m = roll_loss.mean();
            health.evaluate(round, sync, &HealthInputs::from_registry(m.is_finite().then_some(m)));
        }

        // churn first: a killed node must not gossip, a joined node must
        if cfg.kill_at > 0 && cfg.kill_at as u64 == sync {
            let victim = cfg.kill_node;
            transport.unregister(victim);
            if let Some(n) = nodes.iter_mut().find(|n| n.id == victim) {
                n.kill();
            }
            log::info!("cluster: killed node {victim} at tick {sync}");
        }
        let mut did_gossip = false;
        if cfg.join_at > 0 && cfg.join_at as u64 == sync {
            let id = cfg.nodes; // fresh id after the initial 0..nodes
            let (tensors, snap) = merged_boot_state(&nodes)?;
            let mut backend = NativeBackend::new();
            let state = backend.import_state(&meta.name, &tensors)?;
            let mut engine = make_engine(cfg, id, b, replay_budget)?;
            if let (Some(snap), Some(ada)) = (snap, engine.policy.as_ada()) {
                ada.state_mut().restore(snap)?;
            }
            transport.register(id);
            nodes.push(ClusterNode::new(
                id,
                backend,
                state,
                engine,
                meta.name.clone(),
                source.clone(),
                rings.clone(),
                b,
                sync,
                s.max_ticks,
                s.eval_every,
                s.workers,
                s.capacity,
            ));
            nodes
                .last_mut()
                .expect("joiner just pushed")
                .attach_observer(trace.clone());
            // seed the newcomer's store right away — always with full
            // snapshots, whatever the steady-state gossip mode
            let gossip_start = clock.elapsed_secs();
            let bytes = gossip_stores(&mut nodes, transport.as_ref(), true)?;
            gossip_bytes += bytes;
            gossip_rounds += 1;
            obs::emit_journal(trace.as_ref(), wire_event_line("gossip", round, sync, bytes));
            let dur = clock.elapsed_secs() - gossip_start;
            obs::emit_journal(
                trace.as_ref(),
                span_line("gossip_relay", round, sync, None, gossip_start, dur),
            );
            did_gossip = true;
            log::info!("cluster: node {id} joined at tick {sync}");
        }

        if sync < max_ticks {
            if !did_gossip
                && cfg.gossip_every > 0
                && sync % cfg.gossip_every as u64 == 0
            {
                // a generation rotation anywhere escalates the round to
                // full: deltas cannot represent evictions, so a delta-mode
                // sync after one would leave peers holding records the
                // evictor no longer has — diverging from a full-gossip run.
                // Checked before any gossip_message resets the marks.
                let any_evicted = delta_gossip
                    && nodes
                        .iter()
                        .filter(|n| n.alive)
                        .any(|n| n.store_evicted_since_gossip());
                let full = !delta_gossip
                    || gossip_rounds % cfg.full_gossip_every as u64 == 0
                    || any_evicted;
                let gossip_start = clock.elapsed_secs();
                let bytes = gossip_stores(&mut nodes, transport.as_ref(), full)?;
                gossip_bytes += bytes;
                gossip_rounds += 1;
                obs::emit_journal(trace.as_ref(), wire_event_line("gossip", round, sync, bytes));
                let dur = clock.elapsed_secs() - gossip_start;
                obs::emit_journal(
                    trace.as_ref(),
                    span_line("gossip_relay", round, sync, None, gossip_start, dur),
                );
            }
            if cfg.merge_every > 0 && sync % cfg.merge_every as u64 == 0 {
                let merge_start = clock.elapsed_secs();
                let bytes = merge_models(&mut nodes, transport.as_ref())?;
                merge_bytes += bytes;
                merges += 1;
                obs::emit_journal(trace.as_ref(), wire_event_line("merge", round, sync, bytes));
                let dur = clock.elapsed_secs() - merge_start;
                obs::emit_journal(
                    trace.as_ref(),
                    span_line("merge", round, sync, None, merge_start, dur),
                );
            }
        }
    }

    // release every trace sender (node observers, the health engine, the
    // coordinator handle) before finish() joins the journal's writer
    // thread; a strict-mode health failure is surfaced only after the
    // journal is flushed so the firing alerts reach disk first
    for n in nodes.iter_mut() {
        n.detach_observer();
    }
    let health_verdict = health.finish();
    drop(health);
    drop(trace);
    if let Some(j) = journal {
        j.finish()?;
    }
    health_verdict?;

    let elapsed = clock.elapsed_secs();
    let mut digest = FNV_OFFSET;
    let mut phases = PhaseTimer::default();
    let mut summaries = Vec::new();
    let mut samples_seen = 0u64;
    let mut samples_trained = 0u64;
    let mut samples_replayed = 0u64;
    let mut drift_detections = 0u64;
    let mut store_live_total = 0usize;
    for n in &nodes {
        digest = fnv_fold(digest, n.digest);
        phases.merge(&n.phases);
        samples_seen += n.engine.samples_seen;
        samples_trained += n.engine.samples_trained;
        samples_replayed += n.engine.samples_replayed;
        drift_detections += n.engine.drift_detections();
        if n.alive {
            store_live_total += n.engine.store.len();
        }
        summaries.push(NodeSummary {
            id: n.id,
            ticks_processed: n.tick_digests.len() as u64,
            samples_seen: n.engine.samples_seen,
            samples_trained: n.engine.samples_trained,
            samples_replayed: n.engine.samples_replayed,
            store_len: n.engine.store.len(),
            alive_at_end: n.alive,
        });
    }

    Ok(ClusterResult {
        nodes_started: cfg.nodes,
        ticks: max_ticks,
        samples_seen,
        samples_trained,
        samples_replayed,
        drift_detections,
        final_rolling_loss: roll_loss.mean() as f32,
        final_rolling_acc: if classification {
            roll_acc.mean() as f32
        } else {
            f32::NAN
        },
        rolling,
        digest,
        samples_per_sec: samples_seen as f64 / elapsed.max(1e-9),
        gossip_rounds,
        merges,
        gossip_bytes,
        merge_bytes,
        store_live_total,
        remaps,
        node_summaries: summaries,
        phases,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(nodes: usize, ticks: usize) -> ClusterConfig {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = nodes;
        cfg.stream.max_ticks = ticks;
        cfg.stream.window = 10;
        cfg.stream.workers = 0; // synchronous loaders keep unit tests lean
        cfg.gossip_every = 8;
        cfg.merge_every = 8;
        cfg
    }

    #[test]
    fn sync_points_cover_cadences_and_events() {
        let mut cfg = quick_cfg(4, 40);
        cfg.kill_at = 10;
        cfg.kill_node = 1;
        cfg.join_at = 20;
        let pts = sync_points(&cfg);
        assert_eq!(pts, vec![8, 10, 16, 20, 24, 32, 40]);
        // no cadences at all: only the end barrier
        cfg.gossip_every = 0;
        cfg.merge_every = 0;
        cfg.kill_at = 0;
        cfg.join_at = 0;
        assert_eq!(sync_points(&cfg), vec![40]);
    }

    #[test]
    fn ring_schedule_tracks_churn() {
        let mut cfg = quick_cfg(4, 100);
        cfg.kill_at = 30;
        cfg.kill_node = 2;
        cfg.join_at = 60;
        let (sched, remaps) = build_ring_schedule(&cfg);
        assert_eq!(sched.at(0).len(), 4);
        assert_eq!(sched.at(30).len(), 3);
        assert!(!sched.at(30).contains(2));
        assert_eq!(sched.at(60).len(), 4);
        assert!(sched.at(60).contains(4));
        assert_eq!(remaps.len(), 2);
        for &(_, f) in &remaps {
            // one node of four: roughly a quarter of keys move, never most
            assert!(f > 0.05 && f < 0.6, "remap fraction {f}");
        }
    }

    #[test]
    fn two_node_smoke_runs_and_accounts() {
        let cfg = quick_cfg(2, 24);
        let r = run(&cfg).unwrap();
        assert_eq!(r.ticks, 24);
        assert_eq!(r.node_summaries.len(), 2);
        assert!(r.final_rolling_loss.is_finite());
        // every arrival is owned exactly once: totals match a replayed
        // generator pass
        let source = build_source(
            "drift-class",
            StreamKnobs {
                seed: cfg.stream.seed,
                drift_period: cfg.stream.drift_period,
                burst_period: cfg.stream.burst_period,
                burst_min: cfg.stream.burst_min,
            },
        )
        .unwrap();
        let expect: u64 = (0..24u64).map(|t| source.gen_chunk(t, 128).ids.len() as u64).sum();
        assert_eq!(r.samples_seen, expect);
        assert!(r.merges >= 1 && r.gossip_rounds >= 1);
        assert!(r.gossip_bytes > 0 && r.merge_bytes > 0, "wire accounting missing");
    }

    #[test]
    fn tcp_delta_smoke_matches_loopback_full() {
        let base = run(&quick_cfg(2, 24)).unwrap();
        let mut cfg = quick_cfg(2, 24);
        cfg.transport = "tcp".into();
        cfg.gossip = "delta".into();
        let r = run(&cfg).unwrap();
        // a corrupted wire path would skew the merged weights and with
        // them the selection sequence — digest equality covers it
        assert_eq!(r.digest, base.digest, "tcp/delta run diverged");
        assert_eq!(r.samples_trained, base.samples_trained);
        assert!(r.gossip_bytes > 0);
        assert!(
            r.gossip_bytes < base.gossip_bytes,
            "delta gossip must ship fewer bytes: {} vs {}",
            r.gossip_bytes,
            base.gossip_bytes
        );
        assert_eq!(r.merge_bytes, base.merge_bytes, "merges are mode-independent");
    }
}

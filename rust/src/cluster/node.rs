//! One cluster worker: a stream-trainer partition over the ids the ring
//! assigns to it.
//!
//! Each node owns a full training stack — backend, model state, a
//! [`TickEngine`] (policy + instance store + drift/replay control) and a
//! pipeline [`Loader`] fed by a [`PartitionProducer`]. Between sync
//! barriers nodes run completely independently (the coordinator steps
//! them on parallel threads); at barriers the coordinator moves store
//! gossip and merge material between them over the [`Transport`].

use std::sync::Arc;

use crate::cluster::ring::{HashRing, NodeId, RingSchedule};
use crate::cluster::transport::{Message, SharedTelemetry, TelemetrySnapshot};
use crate::obs::{TickObserver, TickSample};
use crate::pipeline::{gather, Batch, BatchProducer, Loader};
use crate::runtime::Backend;
use crate::selection::AdaSnapshot;
use crate::stream::source::StreamSource;
use crate::stream::tick::{fnv_fold, TickEngine, TickOutcome, FNV_OFFSET};
use crate::util::timer::PhaseTimer;

/// Feeds a node's loader: batch `id` is stream tick `first_tick + id`,
/// filtered to the rows the ring assigns to this node at that tick.
/// Ownership is pure in the tick (the [`RingSchedule`] is fixed up
/// front), so loader workers stay deterministic. Unlike the single-node
/// producer the output is *dense* — no padding to the family batch size —
/// because the native backend trains any size and a quarter-shard padded
/// to full B would burn the parallel speedup on padding rows.
pub struct PartitionProducer {
    pub source: Arc<dyn StreamSource>,
    pub rings: Arc<RingSchedule>,
    pub node: NodeId,
    /// chunk width (the family batch size B)
    pub chunk_rows: usize,
    pub first_tick: u64,
    pub max_ticks: usize,
}

impl BatchProducer for PartitionProducer {
    fn total(&self) -> usize {
        self.max_ticks
    }

    fn produce(&self, id: usize) -> Batch {
        let tick = self.first_tick + id as u64;
        let chunk = self.source.gen_chunk(tick, self.chunk_rows);
        if chunk.data.is_empty() {
            return Batch::empty_padded(&chunk.data, 1, id);
        }
        let ring = self.rings.at(tick);
        let owned: Vec<usize> = (0..chunk.ids.len())
            .filter(|&r| ring.owner(chunk.ids[r]) == self.node)
            .collect();
        // gather needs >= 1 slot; an unowned tick yields real = 0 with one
        // placeholder row the engine ignores
        let size = owned.len().max(1);
        let mut b = gather(&chunk.data, &owned, size, 0, id);
        let mut ids: Vec<usize> = owned.iter().map(|&r| chunk.ids[r] as usize).collect();
        let pad = ids
            .first()
            .copied()
            .unwrap_or_else(|| chunk.ids.first().copied().unwrap_or(0) as usize);
        ids.resize(size, pad);
        b.indices = ids;
        b
    }
}

/// One per-tick prequential record a node hands the coordinator (the
/// cluster-wide rolling window sums these across the tick's shards).
#[derive(Clone, Copy, Debug)]
pub struct NodePreq {
    pub tick: u64,
    pub loss_sum: f32,
    pub correct: f32,
    pub arrivals: u32,
}

/// A cluster worker node.
pub struct ClusterNode<B: Backend> {
    pub id: NodeId,
    pub backend: B,
    pub state: B::State,
    pub engine: TickEngine,
    family: String,
    source: Arc<dyn StreamSource>,
    /// the ownership timeline the loader partitions by (swappable at
    /// runtime when the process coordinator converts a crash into churn)
    rings: Arc<RingSchedule>,
    /// loader rebuild parameters (see [`ClusterNode::adopt_schedule`])
    chunk_rows: usize,
    max_ticks: usize,
    workers: usize,
    capacity: usize,
    loader: Option<Loader>,
    /// next tick this node will process
    pub next_tick: u64,
    eval_every: usize,
    /// per-tick digests (kept for determinism checks) + their running fold
    pub tick_digests: Vec<u64>,
    pub digest: u64,
    /// prequential records since the last coordinator drain
    preq: Vec<NodePreq>,
    pub phases: PhaseTimer,
    /// error captured inside a worker thread, surfaced at the barrier
    pub failed: Option<String>,
    pub alive: bool,
    /// samples_trained at the last merge (merge weights = volume since)
    trained_at_last_merge: u64,
    /// telemetry sinks — strictly read-only over tick state, so both stay
    /// off the digest path (see `obs`); None keeps the node silent
    observer: Option<TickObserver>,
    telemetry_out: Option<Arc<SharedTelemetry>>,
    /// the coordinator's barrier round; stamped into every journal line
    /// so offline analysis can merge journals by `(round, node)`
    round: u64,
}

impl<B: Backend> ClusterNode<B> {
    /// Build a node whose loader starts at `first_tick` and ends at the
    /// run's `max_ticks`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: NodeId,
        backend: B,
        state: B::State,
        engine: TickEngine,
        family: String,
        source: Arc<dyn StreamSource>,
        rings: Arc<RingSchedule>,
        chunk_rows: usize,
        first_tick: u64,
        max_ticks: usize,
        eval_every: usize,
        workers: usize,
        capacity: usize,
    ) -> ClusterNode<B> {
        let producer: Arc<dyn BatchProducer> = Arc::new(PartitionProducer {
            source: source.clone(),
            rings: rings.clone(),
            node: id,
            chunk_rows,
            first_tick,
            max_ticks: max_ticks.saturating_sub(first_tick as usize),
        });
        ClusterNode {
            id,
            backend,
            state,
            engine,
            family,
            source,
            rings,
            chunk_rows,
            max_ticks,
            workers,
            capacity,
            loader: Some(Loader::from_producer(producer, workers, capacity)),
            next_tick: first_tick,
            eval_every,
            tick_digests: Vec::new(),
            digest: FNV_OFFSET,
            preq: Vec::new(),
            phases: PhaseTimer::default(),
            failed: None,
            alive: true,
            trained_at_last_merge: 0,
            observer: None,
            telemetry_out: None,
            round: 0,
        }
    }

    /// Adopt the coordinator's barrier round (stamped by `BarrierGo` in
    /// the process runtime, set directly by the thread coordinator).
    /// Telemetry-only: the round never feeds selection.
    pub fn set_round(&mut self, round: u64) {
        self.round = round;
    }

    /// The last round adopted via [`ClusterNode::set_round`].
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Attach a registry/trace observer. Per-node series get a
    /// `{node="<id>"}` label; `trace` journals one event per tick.
    pub fn attach_observer(&mut self, trace: Option<crate::obs::TraceHandle>) {
        self.observer = Some(TickObserver::new(Some(self.id), trace));
    }

    /// Attach the lock-free mailbox a heartbeat side thread samples
    /// (process workers piggyback it on `Heartbeat`).
    pub fn attach_telemetry_out(&mut self, out: Arc<SharedTelemetry>) {
        self.telemetry_out = Some(out);
    }

    /// Drop the observer (and with it its trace sender). Must happen
    /// before the owning journal's `finish()` or the writer-thread join
    /// would wait on this sender forever.
    pub fn detach_observer(&mut self) {
        self.observer = None;
    }

    /// Process ticks `[next_tick, end_tick)`. Errors are captured in
    /// `failed` (worker threads cannot propagate them directly).
    pub fn run_until(&mut self, end_tick: u64) {
        while self.next_tick < end_tick && self.failed.is_none() {
            let batch = {
                let t0 = std::time::Instant::now();
                let b = self.loader.as_mut().and_then(|l| l.next_batch());
                self.phases.add("data", t0.elapsed());
                match b {
                    Some(b) => b,
                    None => {
                        self.failed =
                            Some(format!("node {}: loader ended early", self.id));
                        return;
                    }
                }
            };
            let tick = self.next_tick;
            let do_eval = self.eval_every > 0 && tick % self.eval_every as u64 == 0;
            match self.engine.process(
                &mut self.backend,
                &mut self.state,
                self.source.as_ref(),
                &batch,
                tick,
                do_eval,
                &mut self.phases,
            ) {
                Ok(out) => {
                    if let Some((loss_sum, correct)) = out.eval {
                        self.preq.push(NodePreq {
                            tick,
                            loss_sum,
                            correct,
                            arrivals: out.arrivals as u32,
                        });
                    }
                    self.tick_digests.push(out.digest);
                    self.digest = fnv_fold(self.digest, out.digest);
                    self.publish_telemetry(tick, &out);
                }
                Err(e) => {
                    self.failed = Some(format!("node {}: {e:#}", self.id));
                    return;
                }
            }
            self.next_tick += 1;
        }
    }

    /// Publish one tick's telemetry to whatever sinks are attached.
    /// Backfill ticks deliberately skip this: they replay another node's
    /// share out of order, which would break per-node tick contiguity in
    /// the journal and double-count rows in the per-node rates.
    fn publish_telemetry(&mut self, tick: u64, out: &TickOutcome) {
        if self.observer.is_none() && self.telemetry_out.is_none() {
            return;
        }
        let telem = self.engine.telemetry();
        if let Some(sink) = &self.telemetry_out {
            sink.store(TelemetrySnapshot {
                ticks: self.tick_digests.len() as u64,
                samples_seen: telem.samples_seen,
                samples_trained: telem.samples_trained,
                samples_replayed: telem.samples_replayed,
                drift_detections: telem.drift_detections,
                store_len: telem.store_len,
            });
        }
        if let Some(obs) = self.observer.as_mut() {
            let counters = self.engine.store.counters();
            obs.observe(TickSample {
                tick,
                round: self.round,
                gamma: self.engine.effective_gamma() as f32,
                arrivals: out.arrivals,
                trained: out.trained,
                replayed: out.replayed,
                forward_total: telem.samples_forward,
                drift_total: telem.drift_detections,
                weights: self.engine.policy.weight_pairs(),
                store_live: self.engine.store.len(),
                store_capacity: self.engine.store.capacity(),
                store_hits: counters.hits,
                store_misses: counters.misses,
                store_evictions: counters.evictions,
                // nodes see only their shard; the coordinator owns the
                // cluster-wide rolling window
                rolling: None,
                phases: &self.phases,
            });
        }
    }

    /// Hand the coordinator the prequential records gathered since the
    /// last barrier.
    pub fn take_preq(&mut self) -> Vec<NodePreq> {
        std::mem::take(&mut self.preq)
    }

    /// This node's store-gossip message: the full live snapshot, or (delta
    /// gossip) only the entries touched since the last sync. A full
    /// snapshot also clears the dirty marks — everything live was just
    /// shared, so re-sending it as a delta would only echo. Either way the
    /// store's eviction mark advances: the next
    /// [`ClusterNode::store_evicted_since_gossip`] answers "rotated since
    /// this message was built".
    pub fn gossip_message(&self, full: bool) -> Message {
        let entries = if full {
            let snap = self.engine.store.snapshot();
            self.engine.store.clear_dirty();
            snap
        } else {
            self.engine.store.take_dirty()
        };
        self.engine.store.mark_gossip_synced();
        Message::StoreGossip { from: self.id, entries: Arc::new(entries) }
    }

    /// Whether this node's store rotated a generation since its last
    /// gossip message. Delta gossip cannot represent an eviction (a
    /// dropped id is simply absent from the delta, and `take_dirty` skips
    /// ids evicted after being touched), so any rotation forces the next
    /// gossip round cluster-wide to full mode — that is what keeps delta
    /// runs bit-identical to full-gossip runs under eviction pressure.
    pub fn store_evicted_since_gossip(&self) -> bool {
        self.engine.store.evicted_since_sync()
    }

    /// This node's merge material: exported tensors + policy snapshot,
    /// weighted by training volume since the last merge (+1 so an idle
    /// node still contributes instead of zeroing the average).
    pub fn state_message(&self) -> anyhow::Result<Message> {
        Ok(Message::State {
            from: self.id,
            weight: (self.engine.samples_trained - self.trained_at_last_merge) as f64 + 1.0,
            tensors: self.backend.export_state(&self.state)?,
            policy: self.ada_snapshot(),
        })
    }

    pub fn ada_snapshot(&self) -> Option<AdaSnapshot> {
        self.engine
            .policy
            .as_ada_ref()
            .map(|a| a.state().snapshot())
    }

    /// Apply freshest-tick-wins gossip from a peer.
    pub fn merge_store(&self, entries: &[(u64, crate::stream::InstanceRecord)]) {
        self.engine.store.merge(entries);
    }

    /// Replace model + policy state with the cluster-merged versions.
    pub fn apply_merged(
        &mut self,
        tensors: &[crate::runtime::Tensor],
        policy: Option<&AdaSnapshot>,
    ) -> anyhow::Result<()> {
        self.state = self.backend.import_state(&self.family, tensors)?;
        if let (Some(snap), Some(ada)) = (policy, self.engine.policy.as_ada()) {
            ada.state_mut().restore(snap.clone())?;
        }
        self.trained_at_last_merge = self.engine.samples_trained;
        Ok(())
    }

    /// Remove the node from duty: stop its loader (joins worker threads)
    /// and mark it dead. Counters and digests stay readable for reports.
    pub fn kill(&mut self) {
        self.alive = false;
        self.loader = None;
    }

    /// The current ownership timeline (shared with the partition
    /// producer; the process worker keeps it to diff against on churn).
    pub fn rings(&self) -> Arc<RingSchedule> {
        self.rings.clone()
    }

    /// Replace the ownership timeline and rebuild the loader from the
    /// current tick — the crash-conversion path: batches the old loader
    /// prefetched past `next_tick` were partitioned under the stale ring
    /// and must be regenerated, so the old loader is dropped (joining its
    /// threads) and a fresh one starts at `next_tick`.
    pub fn adopt_schedule(&mut self, rings: Arc<RingSchedule>) {
        self.rings = rings;
        self.loader = None; // join the stale workers before respawning
        let producer: Arc<dyn BatchProducer> = Arc::new(PartitionProducer {
            source: self.source.clone(),
            rings: self.rings.clone(),
            node: self.id,
            chunk_rows: self.chunk_rows,
            first_tick: self.next_tick,
            max_ticks: self.max_ticks.saturating_sub(self.next_tick as usize),
        });
        self.loader = Some(Loader::from_producer(producer, self.workers, self.capacity));
    }

    /// Re-process `dead`'s share of ticks `[from, to)`: the rows that
    /// node owned under `old` and that the current schedule now assigns
    /// to this node. The crashed worker's work since its last barrier
    /// died with it, so the survivors redo it — that is what keeps
    /// arrival coverage exact across a crash. Runs without prequential
    /// eval (those ticks' rolling points were already folded) and
    /// without replay top-up (the rows are themselves back-work).
    /// Returns the number of arrivals re-processed.
    pub fn backfill(
        &mut self,
        dead: NodeId,
        old: &RingSchedule,
        from: u64,
        to: u64,
    ) -> anyhow::Result<u64> {
        let saved_replay = self.engine.replay_budget.take();
        let mut redone = 0u64;
        for tick in from..to {
            let chunk = self.source.gen_chunk(tick, self.chunk_rows);
            if chunk.data.is_empty() {
                continue;
            }
            let ring_old: &HashRing = old.at(tick);
            let ring_new: &HashRing = self.rings.at(tick);
            let owned: Vec<usize> = (0..chunk.ids.len())
                .filter(|&r| {
                    ring_old.owner(chunk.ids[r]) == dead
                        && ring_new.owner(chunk.ids[r]) == self.id
                })
                .collect();
            if owned.is_empty() {
                continue;
            }
            let mut b = gather(&chunk.data, &owned, owned.len(), 0, tick as usize);
            b.indices = owned.iter().map(|&r| chunk.ids[r] as usize).collect();
            let out = self.engine.process(
                &mut self.backend,
                &mut self.state,
                self.source.as_ref(),
                &b,
                tick,
                false,
                &mut self.phases,
            )?;
            self.digest = fnv_fold(self.digest, out.digest);
            redone += out.arrivals as u64;
        }
        self.engine.replay_budget = saved_replay;
        Ok(redone)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ring::HashRing;
    use crate::stream::source::{build_source, StreamKnobs};

    fn schedule(nodes: usize) -> Arc<RingSchedule> {
        Arc::new(RingSchedule::new(HashRing::with_nodes(5, 64, 0..nodes)))
    }

    #[test]
    fn partitions_are_disjoint_and_cover() {
        let source = build_source(
            "drift-class",
            StreamKnobs { seed: 2, drift_period: 32, burst_period: 8, burst_min: 0.25 },
        )
        .unwrap();
        let rings = schedule(3);
        let producers: Vec<PartitionProducer> = (0..3)
            .map(|node| PartitionProducer {
                source: source.clone(),
                rings: rings.clone(),
                node,
                chunk_rows: 32,
                first_tick: 0,
                max_ticks: 20,
            })
            .collect();
        for tick in 0..20usize {
            let chunk = source.gen_chunk(tick as u64, 32);
            let mut seen: Vec<usize> = Vec::new();
            for p in &producers {
                let b = p.produce(tick);
                assert!(!b.is_empty());
                // real rows carry distinct owned ids
                seen.extend(b.indices[..b.real].iter().copied());
            }
            seen.sort_unstable();
            let mut want: Vec<usize> = chunk.ids.iter().map(|&g| g as usize).collect();
            want.sort_unstable();
            assert_eq!(seen, want, "tick {tick}: shards must partition the chunk");
        }
    }

    #[test]
    fn producer_is_pure_per_id() {
        let source = build_source(
            "drift-reg",
            StreamKnobs { seed: 4, drift_period: 16, burst_period: 4, burst_min: 0.5 },
        )
        .unwrap();
        let p = PartitionProducer {
            source,
            rings: schedule(2),
            node: 1,
            chunk_rows: 16,
            first_tick: 3,
            max_ticks: 50,
        };
        let a = p.produce(5);
        let b = p.produce(5);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.real, b.real);
        assert_eq!(a.x_f32, b.x_f32);
    }

    #[test]
    fn backfill_covers_exactly_the_inherited_rows() {
        use crate::runtime::{Backend, NativeBackend};
        use crate::selection::policy::build_policy;
        use crate::stream::store::InstanceStore;

        let source = build_source(
            "drift-class",
            StreamKnobs { seed: 6, drift_period: 32, burst_period: 8, burst_min: 0.5 },
        )
        .unwrap();
        let mut backend = NativeBackend::new();
        let state = backend.init_state("stream_class", 6).unwrap();
        let policy = build_policy("uniform", 6, 0.5, true, -0.5).unwrap();
        let engine = TickEngine::new(policy, InstanceStore::new(1024, 4), 0.5, 0.05, 32);

        // node 2 dies: the survivor (node 0) must redo exactly the rows it
        // inherited from 2 over the backfill range
        let old = RingSchedule::new(HashRing::with_nodes(5, 64, 0..3));
        let mut shrunk = HashRing::with_nodes(5, 64, 0..3);
        shrunk.remove_node(2);
        let new_sched = Arc::new(RingSchedule::new(shrunk));
        let mut node = ClusterNode::new(
            0,
            backend,
            state,
            engine,
            "stream_class".into(),
            source.clone(),
            new_sched.clone(),
            32,
            0,
            20,
            1,
            0,
            4,
        );
        let redone = node.backfill(2, &old, 4, 8).unwrap();
        let mut expect = 0u64;
        for tick in 4..8u64 {
            let chunk = source.gen_chunk(tick, 32);
            expect += chunk
                .ids
                .iter()
                .filter(|&&id| {
                    old.at(tick).owner(id) == 2 && new_sched.at(tick).owner(id) == 0
                })
                .count() as u64;
        }
        assert!(expect > 0, "no rows moved 2 -> 0 over the range");
        assert_eq!(redone, expect);
        assert_eq!(node.engine.samples_seen, expect);
    }
}

//! Node-to-node messaging: the [`Transport`] trait and its deterministic
//! in-process implementation.
//!
//! The cluster's sync protocol only needs two message kinds — instance-
//! store gossip and model/policy merge material — delivered reliably
//! between sync barriers. [`Loopback`] is the reference transport: a
//! per-node mailbox behind one mutex, draining in insertion order, so a
//! coordinator that sends in node-id order makes the whole exchange
//! deterministic. [`Tcp`](crate::cluster::tcp::Tcp) implements the same
//! trait over 127.0.0.1 sockets (acked frame writes keep arrival order
//! identical), so the node and coordinator code is transport-agnostic.
//! `tests/transport_conformance.rs` pins the shared contract for both.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cluster::ring::NodeId;
use crate::runtime::Tensor;
use crate::selection::AdaSnapshot;
use crate::stream::InstanceRecord;

/// What nodes exchange at sync points.
#[derive(Clone, Debug)]
pub enum Message {
    /// Instance-store gossip: a snapshot to merge freshest-tick-wins.
    /// The entries ride behind an `Arc` so broadcasting one snapshot to
    /// N-1 peers shares a single allocation (stores are the largest
    /// payload on the wire).
    StoreGossip {
        from: NodeId,
        entries: Arc<Vec<(u64, InstanceRecord)>>,
    },
    /// Model/policy merge material: exported state tensors plus the
    /// AdaSelection snapshot (None for stateless policies), weighted by
    /// the sender's training volume since the last merge.
    State {
        from: NodeId,
        weight: f64,
        tensors: Vec<Tensor>,
        policy: Option<AdaSnapshot>,
    },
}

impl Message {
    pub fn from_node(&self) -> NodeId {
        match self {
            Message::StoreGossip { from, .. } | Message::State { from, .. } => *from,
        }
    }
}

/// Reliable, ordered delivery between cluster sync barriers.
///
/// Contract (deliberately asymmetric, pinned for every implementation by
/// `tests/transport_conformance.rs`):
///
///   * `send` to a node that is not registered is an **error** — the
///     coordinator always knows its peers, so an unknown destination is a
///     bug worth surfacing;
///   * `drain` of a node that is not registered returns **empty** — after
///     a kill the coordinator may still sweep the victim's id in a
///     barrier loop without special-casing dead nodes;
///   * `send` returns only once the message is in the destination
///     mailbox, so sequential sends drain in send order (per-sender FIFO
///     under concurrency) and `register`/`unregister` are linearized with
///     respect to completed sends.
pub trait Transport: Send + Sync {
    /// Open a mailbox for `node`. Idempotent: re-registering an open node
    /// must keep its queued mail.
    fn register(&self, node: NodeId);

    /// Close a node's mailbox, dropping anything queued (node kill).
    /// Subsequent `send`s to it error; subsequent `drain`s return empty.
    fn unregister(&self, node: NodeId);

    /// Queue `msg` for `node`. Errors when the destination is unknown
    /// (never registered, or unregistered).
    fn send(&self, to: NodeId, msg: Message) -> anyhow::Result<()>;

    /// Deliver one message to every node in `to`, in order. Semantically
    /// identical to looping [`Transport::send`] (the default does exactly
    /// that); implementations that serialize may encode the frame once
    /// for the whole fan-out.
    fn broadcast(&self, to: &[NodeId], msg: &Message) -> anyhow::Result<()> {
        for &node in to {
            self.send(node, msg.clone())?;
        }
        Ok(())
    }

    /// Drain `node`'s mailbox in arrival order, emptying it. An unknown
    /// node yields an empty vec (see the trait-level contract).
    fn drain(&self, node: NodeId) -> Vec<Message>;
}

/// The deterministic in-process transport (mailboxes behind one mutex).
#[derive(Default)]
pub struct Loopback {
    boxes: Mutex<BTreeMap<NodeId, Vec<Message>>>,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Transport for Loopback {
    fn register(&self, node: NodeId) {
        self.boxes.lock().unwrap().entry(node).or_default();
    }

    fn unregister(&self, node: NodeId) {
        self.boxes.lock().unwrap().remove(&node);
    }

    fn send(&self, to: NodeId, msg: Message) -> anyhow::Result<()> {
        let mut boxes = self.boxes.lock().unwrap();
        match boxes.get_mut(&to) {
            Some(q) => {
                q.push(msg);
                Ok(())
            }
            None => anyhow::bail!("transport: unknown destination node {to}"),
        }
    }

    fn drain(&self, node: NodeId) -> Vec<Message> {
        let mut boxes = self.boxes.lock().unwrap();
        match boxes.get_mut(&node) {
            Some(q) => std::mem::take(q),
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gossip(from: NodeId) -> Message {
        Message::StoreGossip { from, entries: Arc::new(Vec::new()) }
    }

    #[test]
    fn delivers_in_order() {
        let t = Loopback::new();
        t.register(1);
        t.send(1, gossip(3)).unwrap();
        t.send(1, gossip(2)).unwrap();
        let got = t.drain(1);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].from_node(), 3);
        assert_eq!(got[1].from_node(), 2);
        assert!(t.drain(1).is_empty(), "drain must empty the box");
    }

    #[test]
    fn unknown_destination_errors() {
        let t = Loopback::new();
        assert!(t.send(9, gossip(0)).is_err());
        assert!(t.drain(9).is_empty());
        t.register(9);
        t.send(9, gossip(0)).unwrap();
        t.unregister(9);
        assert!(t.send(9, gossip(0)).is_err());
        assert!(t.drain(9).is_empty(), "unregister drops queued mail");
    }

    #[test]
    fn register_is_idempotent() {
        let t = Loopback::new();
        t.register(4);
        t.send(4, gossip(1)).unwrap();
        t.register(4); // must not clear the queue
        assert_eq!(t.drain(4).len(), 1);
    }
}

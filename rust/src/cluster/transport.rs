//! Node-to-node messaging: the [`Transport`] trait and its deterministic
//! in-process implementation.
//!
//! The cluster's sync protocol only needs two message kinds — instance-
//! store gossip and model/policy merge material — delivered reliably
//! between sync barriers. [`Loopback`] is the reference transport: a
//! per-node mailbox behind one mutex, draining in insertion order, so a
//! coordinator that sends in node-id order makes the whole exchange
//! deterministic. [`Tcp`](crate::cluster::tcp::Tcp) implements the same
//! trait over 127.0.0.1 sockets (acked frame writes keep arrival order
//! identical), so the node and coordinator code is transport-agnostic.
//! `tests/transport_conformance.rs` pins the shared contract for both.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cluster::node::NodePreq;
use crate::cluster::ring::NodeId;
use crate::runtime::Tensor;
use crate::selection::AdaSnapshot;
use crate::stream::InstanceRecord;

/// `BarrierGo` gossip orders: skip the round, ship the dirty delta, or
/// ship the full live snapshot. `GOSSIP_AUTO` defers the delta/full
/// choice to a post-barrier [`Message::GossipGo`]: workers report whether
/// their store evicted since the last gossip sync in `BarrierReady`, and
/// the coordinator escalates the whole round to full when any did — the
/// eviction-safe delta cadence (a delta cannot resurrect entries a
/// receiver evicted, a full snapshot can).
pub const GOSSIP_NONE: u8 = 0;
pub const GOSSIP_DELTA: u8 = 1;
pub const GOSSIP_FULL: u8 = 2;
pub const GOSSIP_AUTO: u8 = 3;

/// `Hello` sentinel for a worker that registers without a preassigned
/// node id (`adaselection worker --coordinator HOST:PORT` with no
/// `--node-id`): the coordinator picks an id and the worker adopts it
/// from its `Assign`.
pub const UNASSIGNED: NodeId = NodeId::MAX;

/// Unplanned-churn instruction carried by [`Message::BarrierGo`]: remove
/// `dead` from the ring as of `epoch_tick`, then re-process the dead
/// node's share of ticks `[epoch_tick, backfill_to)` under the new
/// ownership before continuing (the crash-recovery path of the process
/// coordinator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChurnOrder {
    pub dead: NodeId,
    pub epoch_tick: u64,
    pub backfill_to: u64,
}

/// What cluster peers exchange at sync points — the two data-plane
/// payloads (store gossip + merge material) every coordinator moves, and
/// the control-plane family the multi-process runtime (`cluster::proc`)
/// speaks over the same `cluster::wire` frames: `Hello`/`Assign` for the
/// handshake, `BarrierGo`/`BarrierReady` for the sync-barrier protocol,
/// `MergePayload` for the cluster-averaged state, `Shutdown`/`Heartbeat`
/// for life-cycle and liveness.
#[derive(Clone, Debug)]
pub enum Message {
    /// Instance-store gossip: a snapshot to merge freshest-tick-wins.
    /// The entries ride behind an `Arc` so broadcasting one snapshot to
    /// N-1 peers shares a single allocation (stores are the largest
    /// payload on the wire).
    StoreGossip {
        from: NodeId,
        entries: Arc<Vec<(u64, InstanceRecord)>>,
    },
    /// Model/policy merge material: exported state tensors plus the
    /// AdaSelection snapshot (None for stateless policies), weighted by
    /// the sender's training volume since the last merge.
    State {
        from: NodeId,
        weight: f64,
        tensors: Vec<Tensor>,
        policy: Option<AdaSnapshot>,
    },
    /// Worker → coordinator: first frame on a fresh control connection,
    /// announcing which node id this process was spawned as.
    Hello { from: NodeId },
    /// Coordinator → worker: the run assignment — the full
    /// `ClusterConfig` as JSON (the worker derives its ring schedule,
    /// engine and loader from it, exactly like a thread node would),
    /// the first tick of this worker's shard, any unplanned kills
    /// already converted to churn, and any elastic joins already
    /// admitted (so late joiners compile the same ownership timeline the
    /// survivors use).
    Assign {
        node: NodeId,
        first_tick: u64,
        config: String,
        chaos: Vec<(u64, NodeId)>,
        joins: Vec<(u64, NodeId)>,
    },
    /// Coordinator → worker: run to `until`, then report. `round` is the
    /// coordinator's monotonically increasing barrier-round id — workers
    /// echo it into every trace-journal line so offline analysis can
    /// merge journals by `(round, node)`. `gossip` (GOSSIP_*) and
    /// `merge`/`boot` order the barrier payloads the worker must send
    /// after its `BarrierReady`; `churn` carries crash conversions and
    /// `joins` carries elastic admissions, both to apply *before*
    /// running.
    BarrierGo {
        round: u64,
        until: u64,
        gossip: u8,
        merge: bool,
        boot: bool,
        churn: Vec<ChurnOrder>,
        joins: Vec<(u64, NodeId)>,
    },
    /// Worker → coordinator: barrier reached. Carries the prequential
    /// records gathered since the last barrier plus the worker's running
    /// counters, so the coordinator's last-seen values double as the
    /// node summary even if the process later dies. `failed` is empty on
    /// success (a non-empty string aborts the run, mirroring the
    /// thread coordinator's error propagation). `round` echoes the
    /// triggering `BarrierGo`'s round id. `store_evicted` reports whether
    /// the instance store evicted records since the last gossip sync —
    /// the coordinator's input for resolving a `GOSSIP_AUTO` round.
    BarrierReady {
        from: NodeId,
        round: u64,
        until: u64,
        preq: Vec<NodePreq>,
        digest: u64,
        ticks_processed: u64,
        samples_seen: u64,
        samples_trained: u64,
        samples_replayed: u64,
        drift_detections: u64,
        store_len: u64,
        store_evicted: bool,
        failed: String,
    },
    /// Coordinator → worker: resolve a `GOSSIP_AUTO` barrier — ship your
    /// gossip now, in `mode` (GOSSIP_DELTA or GOSSIP_FULL, escalated to
    /// full when any peer's store evicted since its last sync).
    GossipGo { round: u64, mode: u8 },
    /// Coordinator → worker: the cluster-averaged model tensors + policy
    /// snapshot to adopt (merge barriers and join bootstrap), stamped
    /// with the barrier round that produced the merge.
    MergePayload {
        round: u64,
        tensors: Vec<Tensor>,
        policy: Option<AdaSnapshot>,
    },
    /// Coordinator → worker: exit cleanly.
    Shutdown,
    /// Liveness keep-alive (worker → coordinator, from a side thread, so
    /// a hung process is distinguishable from a long training segment).
    /// Piggybacks a compact telemetry snapshot so the coordinator can
    /// aggregate fleet-wide metrics without a second channel, plus the
    /// last barrier round the worker has started.
    Heartbeat {
        from: NodeId,
        round: u64,
        telemetry: TelemetrySnapshot,
    },
}

/// Compact per-worker counters riding on `Heartbeat`. All cumulative
/// since worker start; the coordinator publishes them as per-node gauges.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TelemetrySnapshot {
    pub ticks: u64,
    pub samples_seen: u64,
    pub samples_trained: u64,
    pub samples_replayed: u64,
    pub drift_detections: u64,
    pub store_len: u64,
}

/// Lock-free mailbox a worker's training loop writes each tick and its
/// heartbeat side thread reads — relaxed ordering is fine, heartbeats
/// only need an eventually-consistent view.
#[derive(Debug, Default)]
pub struct SharedTelemetry {
    ticks: std::sync::atomic::AtomicU64,
    samples_seen: std::sync::atomic::AtomicU64,
    samples_trained: std::sync::atomic::AtomicU64,
    samples_replayed: std::sync::atomic::AtomicU64,
    drift_detections: std::sync::atomic::AtomicU64,
    store_len: std::sync::atomic::AtomicU64,
}

impl SharedTelemetry {
    pub fn store(&self, snap: TelemetrySnapshot) {
        use std::sync::atomic::Ordering::Relaxed;
        self.ticks.store(snap.ticks, Relaxed);
        self.samples_seen.store(snap.samples_seen, Relaxed);
        self.samples_trained.store(snap.samples_trained, Relaxed);
        self.samples_replayed.store(snap.samples_replayed, Relaxed);
        self.drift_detections.store(snap.drift_detections, Relaxed);
        self.store_len.store(snap.store_len, Relaxed);
    }

    pub fn load(&self) -> TelemetrySnapshot {
        use std::sync::atomic::Ordering::Relaxed;
        TelemetrySnapshot {
            ticks: self.ticks.load(Relaxed),
            samples_seen: self.samples_seen.load(Relaxed),
            samples_trained: self.samples_trained.load(Relaxed),
            samples_replayed: self.samples_replayed.load(Relaxed),
            drift_detections: self.drift_detections.load(Relaxed),
            store_len: self.store_len.load(Relaxed),
        }
    }
}

impl Message {
    /// The sending node, for messages that have one; coordinator-
    /// originated control frames return `NodeId::MAX` (they are never
    /// sorted by sender).
    pub fn from_node(&self) -> NodeId {
        match self {
            Message::StoreGossip { from, .. }
            | Message::State { from, .. }
            | Message::Hello { from }
            | Message::BarrierReady { from, .. }
            | Message::Heartbeat { from, .. } => *from,
            Message::Assign { node, .. } => *node,
            Message::BarrierGo { .. }
            | Message::GossipGo { .. }
            | Message::MergePayload { .. }
            | Message::Shutdown => NodeId::MAX,
        }
    }
}

/// Reliable, ordered delivery between cluster sync barriers.
///
/// Contract (deliberately asymmetric, pinned for every implementation by
/// `tests/transport_conformance.rs`):
///
///   * `send` to a node that is not registered is an **error** — the
///     coordinator always knows its peers, so an unknown destination is a
///     bug worth surfacing;
///   * `drain` of a node that is not registered returns **empty** — after
///     a kill the coordinator may still sweep the victim's id in a
///     barrier loop without special-casing dead nodes;
///   * `send` returns only once the message is in the destination
///     mailbox, so sequential sends drain in send order (per-sender FIFO
///     under concurrency) and `register`/`unregister` are linearized with
///     respect to completed sends.
pub trait Transport: Send + Sync {
    /// Open a mailbox for `node`. Idempotent: re-registering an open node
    /// must keep its queued mail.
    fn register(&self, node: NodeId);

    /// Close a node's mailbox, dropping anything queued (node kill).
    /// Subsequent `send`s to it error; subsequent `drain`s return empty.
    fn unregister(&self, node: NodeId);

    /// Queue `msg` for `node`. Errors when the destination is unknown
    /// (never registered, or unregistered).
    fn send(&self, to: NodeId, msg: Message) -> anyhow::Result<()>;

    /// Deliver one message to every node in `to`, in order. Semantically
    /// identical to looping [`Transport::send`] (the default does exactly
    /// that); implementations that serialize may encode the frame once
    /// for the whole fan-out.
    fn broadcast(&self, to: &[NodeId], msg: &Message) -> anyhow::Result<()> {
        for &node in to {
            self.send(node, msg.clone())?;
        }
        Ok(())
    }

    /// Drain `node`'s mailbox in arrival order, emptying it. An unknown
    /// node yields an empty vec (see the trait-level contract).
    fn drain(&self, node: NodeId) -> Vec<Message>;
}

/// The deterministic in-process transport (mailboxes behind one mutex).
#[derive(Default)]
pub struct Loopback {
    boxes: Mutex<BTreeMap<NodeId, Vec<Message>>>,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Transport for Loopback {
    fn register(&self, node: NodeId) {
        self.boxes.lock().unwrap().entry(node).or_default();
    }

    fn unregister(&self, node: NodeId) {
        self.boxes.lock().unwrap().remove(&node);
    }

    fn send(&self, to: NodeId, msg: Message) -> anyhow::Result<()> {
        let mut boxes = self.boxes.lock().unwrap();
        match boxes.get_mut(&to) {
            Some(q) => {
                q.push(msg);
                Ok(())
            }
            None => anyhow::bail!("transport: unknown destination node {to}"),
        }
    }

    fn drain(&self, node: NodeId) -> Vec<Message> {
        let mut boxes = self.boxes.lock().unwrap();
        match boxes.get_mut(&node) {
            Some(q) => std::mem::take(q),
            None => Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gossip(from: NodeId) -> Message {
        Message::StoreGossip { from, entries: Arc::new(Vec::new()) }
    }

    #[test]
    fn delivers_in_order() {
        let t = Loopback::new();
        t.register(1);
        t.send(1, gossip(3)).unwrap();
        t.send(1, gossip(2)).unwrap();
        let got = t.drain(1);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].from_node(), 3);
        assert_eq!(got[1].from_node(), 2);
        assert!(t.drain(1).is_empty(), "drain must empty the box");
    }

    #[test]
    fn unknown_destination_errors() {
        let t = Loopback::new();
        assert!(t.send(9, gossip(0)).is_err());
        assert!(t.drain(9).is_empty());
        t.register(9);
        t.send(9, gossip(0)).unwrap();
        t.unregister(9);
        assert!(t.send(9, gossip(0)).is_err());
        assert!(t.drain(9).is_empty(), "unregister drops queued mail");
    }

    #[test]
    fn register_is_idempotent() {
        let t = Loopback::new();
        t.register(4);
        t.send(4, gossip(1)).unwrap();
        t.register(4); // must not clear the queue
        assert_eq!(t.drain(4).len(), 1);
    }
}

//! Length-prefixed binary wire format for cluster [`Message`]s.
//!
//! Every frame is:
//!
//! ```text
//! magic    u16  = 0xAD51          (little-endian, like every field)
//! version  u8   = 3               (v1/v2 frames still decode; see below)
//! len      u32  — payload bytes that follow
//! payload  [u8; len]
//! checksum u32  — FNV-1a-32 over the payload
//! ```
//!
//! The payload starts with a one-byte message tag. Floats are carried as
//! raw IEEE-754 little-endian bytes, so a `Message` round-trips *bitwise*
//! — the TCP transport is exactly as deterministic as the in-process
//! loopback. Decoding is total: truncated frames, bad magic/version,
//! checksum mismatches, absurd length prefixes and malformed payloads all
//! return errors, never panic, so a misbehaving peer cannot take a node
//! down.
//!
//! **v1 → v2:** v2 adds a `round: u64` barrier-round id to the
//! `BarrierGo`/`BarrierReady`/`MergePayload`/`Heartbeat` control frames
//! (round-scoped tracing). **v2 → v3:** v3 adds elastic-membership
//! fields — `joins` on `Assign`/`BarrierGo`, a `store_evicted` flag on
//! `BarrierReady`, and the `GossipGo` frame resolving `GOSSIP_AUTO`
//! rounds. Encoding always writes v3; decoding accepts v1/v2 frames and
//! defaults the missing fields (`round` 0, empty `joins`, false
//! `store_evicted`), so an old capture or an old peer's control frames
//! still parse. Versions above [`VERSION`] are rejected with an explicit
//! error.
//!
//! [`frame_len`] computes a message's on-wire size without encoding it;
//! the coordinator uses it to report gossip/merge bandwidth for *every*
//! transport (a loopback run reports the bytes a socket run would ship).

use std::io::Read;
use std::sync::Arc;

use crate::cluster::node::NodePreq;
use crate::cluster::ring::NodeId;
use crate::cluster::transport::{ChurnOrder, Message, TelemetrySnapshot};
use crate::runtime::Tensor;
use crate::selection::AdaSnapshot;
use crate::stream::InstanceRecord;

/// Frame magic ("AdaSelection wire").
pub const MAGIC: u16 = 0xAD51;
/// Current wire-format version; bumped on any layout change.
pub const VERSION: u8 = 3;
/// Oldest version this node still decodes (v1 control frames carry no
/// `round`, v1/v2 frames no elastic-membership fields; all default).
pub const MIN_VERSION: u8 = 1;
/// Bytes before the payload: magic (2) + version (1) + length (4).
pub const HEADER_LEN: usize = 7;
/// Bytes after the payload: the FNV-1a-32 checksum.
pub const TRAILER_LEN: usize = 4;
/// Largest accepted payload (64 MiB) — rejects absurd length prefixes
/// before any allocation happens.
pub const MAX_PAYLOAD: usize = 64 << 20;

const TAG_GOSSIP: u8 = 0;
const TAG_STATE: u8 = 1;
// control-plane family (multi-process workers, `cluster::proc`) — new
// tags in the same versioned frame; a v1 peer that predates them rejects
// the unknown tag with an error, never a panic
const TAG_HELLO: u8 = 2;
const TAG_ASSIGN: u8 = 3;
const TAG_BARRIER_GO: u8 = 4;
const TAG_BARRIER_READY: u8 = 5;
const TAG_MERGE_PAYLOAD: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_HEARTBEAT: u8 = 8;
const TAG_GOSSIP_GO: u8 = 9;
/// Encoded bytes per store-gossip entry: id + loss + gnorm + tick + visits.
const ENTRY_LEN: usize = 24;
/// Encoded bytes per prequential record: tick + loss_sum + correct + arrivals.
const PREQ_LEN: usize = 20;
/// Encoded bytes per churn order: dead + epoch_tick + backfill_to.
const CHURN_LEN: usize = 24;
/// Encoded bytes per membership event (`Assign` chaos kills, and the
/// elastic joins on `Assign`/`BarrierGo`): tick + node.
const CHAOS_LEN: usize = 16;
/// Decode-side sanity bounds (far above anything the cluster produces).
const MAX_RANK: usize = 8;
const MAX_TENSORS: usize = 4096;

/// FNV-1a over the payload — cheap, endian-free, catches the bit flips and
/// short writes a length-prefixed stream protocol cares about.
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Encoded size of a tensor list (count prefix + per-tensor payload).
fn tensors_len(tensors: &[Tensor]) -> usize {
    let mut n = 4;
    for t in tensors {
        n += 4 + 4 * t.shape.len() + 4 + 4 * t.data.len();
    }
    n
}

/// Encoded size of an optional policy snapshot (flag + payload).
fn policy_len(policy: &Option<AdaSnapshot>) -> usize {
    let mut n = 1;
    if let Some(p) = policy {
        n += 4 + 4 * p.w.len() + 1 + 8;
        if let Some(v) = &p.prev_loss {
            n += 4 + 4 * v.len();
        }
    }
    n
}

/// Exact payload size of `msg` (no allocation).
pub fn payload_len(msg: &Message) -> usize {
    match msg {
        Message::StoreGossip { entries, .. } => 1 + 8 + 4 + entries.len() * ENTRY_LEN,
        Message::State { tensors, policy, .. } => {
            1 + 8 + 8 + tensors_len(tensors) + policy_len(policy)
        }
        Message::Hello { .. } => 1 + 8,
        Message::Assign { config, chaos, joins, .. } => {
            1 + 8 + 8 + 4 + config.len() + 4 + chaos.len() * CHAOS_LEN + 4
                + joins.len() * CHAOS_LEN
        }
        Message::BarrierGo { churn, joins, .. } => {
            1 + 8 + 8 + 1 + 1 + 1 + 4 + churn.len() * CHURN_LEN + 4
                + joins.len() * CHAOS_LEN
        }
        Message::BarrierReady { preq, failed, .. } => {
            1 + 8 + 8 + 8 + 4 + preq.len() * PREQ_LEN + 7 * 8 + 1 + 4 + failed.len()
        }
        Message::GossipGo { .. } => 1 + 8 + 1,
        Message::MergePayload { tensors, policy, .. } => {
            1 + 8 + tensors_len(tensors) + policy_len(policy)
        }
        Message::Shutdown => 1,
        Message::Heartbeat { .. } => 1 + 8 + 8 + 6 * 8,
    }
}

/// Exact on-wire size of `msg`'s frame (header + payload + checksum).
pub fn frame_len(msg: &Message) -> usize {
    HEADER_LEN + payload_len(msg) + TRAILER_LEN
}

/// Most store entries one gossip frame can carry without its payload
/// exceeding [`MAX_PAYLOAD`]. Config validation caps `store-capacity`
/// with this for TCP clusters, so a full-snapshot gossip always fits one
/// frame (~2.79M entries — far above any practical store).
pub fn max_gossip_entries() -> usize {
    (MAX_PAYLOAD - (1 + 8 + 4)) / ENTRY_LEN
}

/// Tensor bounds shared by the `State` and `MergePayload` guards.
fn check_tensors(tensors: &[Tensor]) -> anyhow::Result<()> {
    anyhow::ensure!(
        tensors.len() <= MAX_TENSORS,
        "wire: message carries {} tensors (max {MAX_TENSORS})",
        tensors.len()
    );
    for t in tensors {
        anyhow::ensure!(
            t.shape.len() <= MAX_RANK,
            "wire: tensor rank {} exceeds {MAX_RANK}",
            t.shape.len()
        );
        let product = t
            .shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("wire: tensor shape {:?} overflows", t.shape))?;
        anyhow::ensure!(
            product == t.data.len(),
            "wire: tensor shape {:?} does not match data length {}",
            t.shape,
            t.data.len()
        );
    }
    Ok(())
}

/// Encode-side guard mirroring every decode-side bound, so a message the
/// peer would reject fails at the *sender* with a clear error instead of
/// poisoning the connection. Transports call this before [`encode`].
pub fn check_encodable(msg: &Message) -> anyhow::Result<()> {
    match msg {
        Message::State { tensors, .. } | Message::MergePayload { tensors, .. } => {
            check_tensors(tensors)?
        }
        Message::BarrierGo { gossip, .. } => {
            anyhow::ensure!(*gossip <= 3, "wire: bad gossip order {gossip}")
        }
        Message::GossipGo { mode, .. } => {
            // the resolved mode is always concrete: delta or full
            anyhow::ensure!(
                *mode == 1 || *mode == 2,
                "wire: bad resolved gossip mode {mode}"
            )
        }
        _ => {}
    }
    let len = payload_len(msg);
    anyhow::ensure!(len <= MAX_PAYLOAD, "wire: message payload {len} exceeds {MAX_PAYLOAD} bytes");
    Ok(())
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_tensors(b: &mut Vec<u8>, tensors: &[Tensor]) {
    put_u32(b, tensors.len() as u32);
    for t in tensors {
        put_u32(b, t.shape.len() as u32);
        for &d in &t.shape {
            put_u32(b, d as u32);
        }
        put_u32(b, t.data.len() as u32);
        for &x in &t.data {
            put_f32(b, x);
        }
    }
}

fn put_policy(b: &mut Vec<u8>, policy: &Option<AdaSnapshot>) {
    match policy {
        None => b.push(0),
        Some(p) => {
            b.push(1);
            put_u32(b, p.w.len() as u32);
            for &x in &p.w {
                put_f32(b, x);
            }
            match &p.prev_loss {
                None => b.push(0),
                Some(v) => {
                    b.push(1);
                    put_u32(b, v.len() as u32);
                    for &x in v {
                        put_f32(b, x);
                    }
                }
            }
            put_u64(b, p.t as u64);
        }
    }
}

fn encode_payload(msg: &Message) -> Vec<u8> {
    let mut b = Vec::with_capacity(payload_len(msg));
    match msg {
        Message::StoreGossip { from, entries } => {
            b.push(TAG_GOSSIP);
            put_u64(&mut b, *from as u64);
            put_u32(&mut b, entries.len() as u32);
            for &(id, r) in entries.iter() {
                put_u64(&mut b, id);
                put_f32(&mut b, r.loss);
                put_f32(&mut b, r.gnorm);
                put_u32(&mut b, r.last_tick);
                put_u32(&mut b, r.visits);
            }
        }
        Message::State { from, weight, tensors, policy } => {
            b.push(TAG_STATE);
            put_u64(&mut b, *from as u64);
            put_f64(&mut b, *weight);
            put_tensors(&mut b, tensors);
            put_policy(&mut b, policy);
        }
        Message::Hello { from } => {
            b.push(TAG_HELLO);
            put_u64(&mut b, *from as u64);
        }
        Message::Assign { node, first_tick, config, chaos, joins } => {
            b.push(TAG_ASSIGN);
            put_u64(&mut b, *node as u64);
            put_u64(&mut b, *first_tick);
            put_u32(&mut b, config.len() as u32);
            b.extend_from_slice(config.as_bytes());
            put_u32(&mut b, chaos.len() as u32);
            for &(tick, node) in chaos {
                put_u64(&mut b, tick);
                put_u64(&mut b, node as u64);
            }
            put_u32(&mut b, joins.len() as u32);
            for &(tick, node) in joins {
                put_u64(&mut b, tick);
                put_u64(&mut b, node as u64);
            }
        }
        Message::BarrierGo { round, until, gossip, merge, boot, churn, joins } => {
            b.push(TAG_BARRIER_GO);
            put_u64(&mut b, *round);
            put_u64(&mut b, *until);
            b.push(*gossip);
            b.push(*merge as u8);
            b.push(*boot as u8);
            put_u32(&mut b, churn.len() as u32);
            for c in churn {
                put_u64(&mut b, c.dead as u64);
                put_u64(&mut b, c.epoch_tick);
                put_u64(&mut b, c.backfill_to);
            }
            put_u32(&mut b, joins.len() as u32);
            for &(tick, node) in joins {
                put_u64(&mut b, tick);
                put_u64(&mut b, node as u64);
            }
        }
        Message::BarrierReady {
            from,
            round,
            until,
            preq,
            digest,
            ticks_processed,
            samples_seen,
            samples_trained,
            samples_replayed,
            drift_detections,
            store_len,
            store_evicted,
            failed,
        } => {
            b.push(TAG_BARRIER_READY);
            put_u64(&mut b, *from as u64);
            put_u64(&mut b, *round);
            put_u64(&mut b, *until);
            put_u32(&mut b, preq.len() as u32);
            for p in preq {
                put_u64(&mut b, p.tick);
                put_f32(&mut b, p.loss_sum);
                put_f32(&mut b, p.correct);
                put_u32(&mut b, p.arrivals);
            }
            put_u64(&mut b, *digest);
            put_u64(&mut b, *ticks_processed);
            put_u64(&mut b, *samples_seen);
            put_u64(&mut b, *samples_trained);
            put_u64(&mut b, *samples_replayed);
            put_u64(&mut b, *drift_detections);
            put_u64(&mut b, *store_len);
            b.push(*store_evicted as u8);
            put_u32(&mut b, failed.len() as u32);
            b.extend_from_slice(failed.as_bytes());
        }
        Message::GossipGo { round, mode } => {
            b.push(TAG_GOSSIP_GO);
            put_u64(&mut b, *round);
            b.push(*mode);
        }
        Message::MergePayload { round, tensors, policy } => {
            b.push(TAG_MERGE_PAYLOAD);
            put_u64(&mut b, *round);
            put_tensors(&mut b, tensors);
            put_policy(&mut b, policy);
        }
        Message::Shutdown => b.push(TAG_SHUTDOWN),
        Message::Heartbeat { from, round, telemetry } => {
            b.push(TAG_HEARTBEAT);
            put_u64(&mut b, *from as u64);
            put_u64(&mut b, *round);
            put_u64(&mut b, telemetry.ticks);
            put_u64(&mut b, telemetry.samples_seen);
            put_u64(&mut b, telemetry.samples_trained);
            put_u64(&mut b, telemetry.samples_replayed);
            put_u64(&mut b, telemetry.drift_detections);
            put_u64(&mut b, telemetry.store_len);
        }
    }
    b
}

/// Encode one message as a complete frame.
pub fn encode(msg: &Message) -> Vec<u8> {
    let payload = encode_payload(msg);
    debug_assert_eq!(payload.len(), payload_len(msg), "frame_len model drifted");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
    out
}

/// Validate a header slice (≥ [`HEADER_LEN`] bytes); returns the frame
/// version and the payload length. Any version in
/// `[MIN_VERSION, VERSION]` is accepted — the payload decoder handles
/// per-version layout differences.
fn parse_header(h: &[u8]) -> anyhow::Result<(u8, usize)> {
    let magic = u16::from_le_bytes([h[0], h[1]]);
    anyhow::ensure!(magic == MAGIC, "wire: bad magic {magic:#06x} (want {MAGIC:#06x})");
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&h[2]),
        "wire: version mismatch: peer speaks v{}, this node v{VERSION} (accepts v{MIN_VERSION}..v{VERSION})",
        h[2]
    );
    let len = u32::from_le_bytes([h[3], h[4], h[5], h[6]]) as usize;
    anyhow::ensure!(len <= MAX_PAYLOAD, "wire: payload length {len} exceeds {MAX_PAYLOAD}");
    Ok((h[2], len))
}

/// Bounds-checked payload reader.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "wire: payload truncated at byte {} (need {n} more)",
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32_vec(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        let bytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("wire: float vector length {n} overflows"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn string(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| anyhow::anyhow!("wire: string field is not valid UTF-8"))
    }

    fn bool(&mut self) -> anyhow::Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => anyhow::bail!("wire: bad bool byte {other}"),
        }
    }

    fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "wire: {} trailing payload bytes",
            self.remaining()
        );
        Ok(())
    }
}

fn read_tensors(c: &mut Cursor) -> anyhow::Result<Vec<Tensor>> {
    let n_tensors = c.u32()? as usize;
    anyhow::ensure!(
        n_tensors <= MAX_TENSORS,
        "wire: tensor count {n_tensors} exceeds {MAX_TENSORS}"
    );
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let rank = c.u32()? as usize;
        anyhow::ensure!(rank <= MAX_RANK, "wire: tensor rank {rank} exceeds {MAX_RANK}");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(c.u32()? as usize);
        }
        let data_len = c.u32()? as usize;
        let product = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("wire: tensor shape {shape:?} overflows"))?;
        anyhow::ensure!(
            data_len == product,
            "wire: tensor data length {data_len} != shape product {product}"
        );
        let data = c.f32_vec(data_len)?;
        tensors.push(Tensor { shape, data });
    }
    Ok(tensors)
}

fn read_policy(c: &mut Cursor) -> anyhow::Result<Option<AdaSnapshot>> {
    Ok(match c.u8()? {
        0 => None,
        1 => {
            let wn = c.u32()? as usize;
            let w = c.f32_vec(wn)?;
            let prev_loss = match c.u8()? {
                0 => None,
                1 => {
                    let pn = c.u32()? as usize;
                    Some(c.f32_vec(pn)?)
                }
                other => anyhow::bail!("wire: bad prev-loss flag {other}"),
            };
            let t = c.u64()? as usize;
            // arm ids never ride the wire: same-config peers restore
            // positionally, which `AdaState::restore` accepts for id-less
            // snapshots of matching arity
            Some(AdaSnapshot { w, prev_loss, t, ids: None })
        }
        other => anyhow::bail!("wire: bad policy flag {other}"),
    })
}

fn decode_payload(version: u8, payload: &[u8]) -> anyhow::Result<Message> {
    let mut c = Cursor { buf: payload, pos: 0 };
    // v1 control frames carry no round id; default it to 0
    let round_field = |c: &mut Cursor| -> anyhow::Result<u64> {
        if version >= 2 {
            c.u64()
        } else {
            Ok(0)
        }
    };
    // v1/v2 frames carry no elastic joins; default to none
    let joins_field = |c: &mut Cursor| -> anyhow::Result<Vec<(u64, NodeId)>> {
        if version < 3 {
            return Ok(Vec::new());
        }
        let n = c.u32()? as usize;
        anyhow::ensure!(
            n.saturating_mul(CHAOS_LEN) <= c.remaining(),
            "wire: join event count {n} exceeds the payload"
        );
        let mut joins = Vec::with_capacity(n);
        for _ in 0..n {
            let tick = c.u64()?;
            let node = c.u64()? as NodeId;
            joins.push((tick, node));
        }
        Ok(joins)
    };
    let msg = match c.u8()? {
        TAG_GOSSIP => {
            let from = c.u64()? as NodeId;
            let n = c.u32()? as usize;
            anyhow::ensure!(
                n.saturating_mul(ENTRY_LEN) <= c.remaining(),
                "wire: gossip entry count {n} exceeds the payload"
            );
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let id = c.u64()?;
                let loss = c.f32()?;
                let gnorm = c.f32()?;
                let last_tick = c.u32()?;
                let visits = c.u32()?;
                entries.push((id, InstanceRecord { loss, gnorm, last_tick, visits }));
            }
            Message::StoreGossip { from, entries: Arc::new(entries) }
        }
        TAG_STATE => {
            let from = c.u64()? as NodeId;
            let weight = c.f64()?;
            let tensors = read_tensors(&mut c)?;
            let policy = read_policy(&mut c)?;
            Message::State { from, weight, tensors, policy }
        }
        TAG_HELLO => Message::Hello { from: c.u64()? as NodeId },
        TAG_ASSIGN => {
            let node = c.u64()? as NodeId;
            let first_tick = c.u64()?;
            let config = c.string()?;
            let n = c.u32()? as usize;
            anyhow::ensure!(
                n.saturating_mul(CHAOS_LEN) <= c.remaining(),
                "wire: chaos event count {n} exceeds the payload"
            );
            let mut chaos = Vec::with_capacity(n);
            for _ in 0..n {
                let tick = c.u64()?;
                let dead = c.u64()? as NodeId;
                chaos.push((tick, dead));
            }
            let joins = joins_field(&mut c)?;
            Message::Assign { node, first_tick, config, chaos, joins }
        }
        TAG_BARRIER_GO => {
            let round = round_field(&mut c)?;
            let until = c.u64()?;
            let gossip = c.u8()?;
            anyhow::ensure!(gossip <= 3, "wire: bad gossip order {gossip}");
            let merge = c.bool()?;
            let boot = c.bool()?;
            let n = c.u32()? as usize;
            anyhow::ensure!(
                n.saturating_mul(CHURN_LEN) <= c.remaining(),
                "wire: churn order count {n} exceeds the payload"
            );
            let mut churn = Vec::with_capacity(n);
            for _ in 0..n {
                let dead = c.u64()? as NodeId;
                let epoch_tick = c.u64()?;
                let backfill_to = c.u64()?;
                churn.push(ChurnOrder { dead, epoch_tick, backfill_to });
            }
            let joins = joins_field(&mut c)?;
            Message::BarrierGo { round, until, gossip, merge, boot, churn, joins }
        }
        TAG_BARRIER_READY => {
            let from = c.u64()? as NodeId;
            let round = round_field(&mut c)?;
            let until = c.u64()?;
            let n = c.u32()? as usize;
            anyhow::ensure!(
                n.saturating_mul(PREQ_LEN) <= c.remaining(),
                "wire: preq record count {n} exceeds the payload"
            );
            let mut preq = Vec::with_capacity(n);
            for _ in 0..n {
                let tick = c.u64()?;
                let loss_sum = c.f32()?;
                let correct = c.f32()?;
                let arrivals = c.u32()?;
                preq.push(NodePreq { tick, loss_sum, correct, arrivals });
            }
            let digest = c.u64()?;
            let ticks_processed = c.u64()?;
            let samples_seen = c.u64()?;
            let samples_trained = c.u64()?;
            let samples_replayed = c.u64()?;
            let drift_detections = c.u64()?;
            let store_len = c.u64()?;
            // v1/v2 frames carry no eviction flag; default to false
            let store_evicted = if version >= 3 { c.bool()? } else { false };
            let failed = c.string()?;
            Message::BarrierReady {
                from,
                round,
                until,
                preq,
                digest,
                ticks_processed,
                samples_seen,
                samples_trained,
                samples_replayed,
                drift_detections,
                store_len,
                store_evicted,
                failed,
            }
        }
        TAG_GOSSIP_GO => {
            let round = c.u64()?;
            let mode = c.u8()?;
            anyhow::ensure!(
                mode == 1 || mode == 2,
                "wire: bad resolved gossip mode {mode}"
            );
            Message::GossipGo { round, mode }
        }
        TAG_MERGE_PAYLOAD => {
            let round = round_field(&mut c)?;
            let tensors = read_tensors(&mut c)?;
            let policy = read_policy(&mut c)?;
            Message::MergePayload { round, tensors, policy }
        }
        TAG_SHUTDOWN => Message::Shutdown,
        TAG_HEARTBEAT => Message::Heartbeat {
            from: c.u64()? as NodeId,
            round: round_field(&mut c)?,
            telemetry: TelemetrySnapshot {
                ticks: c.u64()?,
                samples_seen: c.u64()?,
                samples_trained: c.u64()?,
                samples_replayed: c.u64()?,
                drift_detections: c.u64()?,
                store_len: c.u64()?,
            },
        },
        other => anyhow::bail!("wire: unknown message tag {other}"),
    };
    c.done()?;
    Ok(msg)
}

/// Decode exactly one complete frame from `buf` (length must match the
/// frame exactly — shorter is truncation, longer is trailing garbage).
pub fn decode(buf: &[u8]) -> anyhow::Result<Message> {
    anyhow::ensure!(
        buf.len() >= HEADER_LEN + TRAILER_LEN,
        "wire: frame truncated ({} bytes, header+checksum need {})",
        buf.len(),
        HEADER_LEN + TRAILER_LEN
    );
    let (version, payload_len) = parse_header(&buf[..HEADER_LEN])?;
    let total = HEADER_LEN + payload_len + TRAILER_LEN;
    anyhow::ensure!(
        buf.len() == total,
        "wire: frame length mismatch (got {}, framed {total})",
        buf.len()
    );
    let payload = &buf[HEADER_LEN..HEADER_LEN + payload_len];
    let want = u32::from_le_bytes(buf[total - TRAILER_LEN..].try_into().unwrap());
    anyhow::ensure!(want == fnv1a32(payload), "wire: checksum mismatch");
    decode_payload(version, payload)
}

/// Read one frame from a byte stream. `Ok(None)` on a clean EOF *between*
/// frames (the peer closed the connection); EOF mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Option<Message>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0usize;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                anyhow::bail!("wire: EOF inside a frame header ({got}/{HEADER_LEN} bytes)");
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let (version, payload_len) = parse_header(&header)?;
    let mut rest = vec![0u8; payload_len + TRAILER_LEN];
    r.read_exact(&mut rest)
        .map_err(|e| anyhow::anyhow!("wire: EOF inside a frame body: {e}"))?;
    let payload = &rest[..payload_len];
    let want = u32::from_le_bytes(rest[payload_len..].try_into().unwrap());
    anyhow::ensure!(want == fnv1a32(payload), "wire: checksum mismatch");
    decode_payload(version, payload).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::prop_check;
    use crate::util::rng::Pcg64;

    fn rand_gossip(rng: &mut Pcg64) -> Message {
        let n = rng.next_below(50) as usize; // sometimes empty
        let entries: Vec<(u64, InstanceRecord)> = (0..n)
            .map(|_| {
                (
                    rng.next_u64(),
                    InstanceRecord {
                        loss: rng.next_f32() * 10.0,
                        gnorm: rng.next_f32() * 3.0,
                        last_tick: rng.next_below(1 << 20) as u32,
                        visits: rng.next_below(1000) as u32,
                    },
                )
            })
            .collect();
        Message::StoreGossip {
            from: rng.next_below(64) as NodeId,
            entries: Arc::new(entries),
        }
    }

    fn rand_state(rng: &mut Pcg64) -> Message {
        let n_tensors = rng.next_below(4) as usize;
        let tensors: Vec<Tensor> = (0..n_tensors)
            .map(|_| {
                // includes genuinely empty tensors (a zero dim)
                let rows = rng.next_below(5) as usize;
                let cols = 1 + rng.next_below(7) as usize;
                let data = (0..rows * cols).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
                Tensor { shape: vec![rows, cols], data }
            })
            .collect();
        let policy = if rng.next_below(2) == 0 {
            None
        } else {
            let m = 1 + rng.next_below(7) as usize;
            let prev = if rng.next_below(2) == 0 {
                None
            } else {
                Some((0..m).map(|_| rng.next_f32() * 4.0).collect())
            };
            Some(AdaSnapshot {
                w: (0..m).map(|_| rng.next_f32()).collect(),
                prev_loss: prev,
                t: rng.next_below(10_000) as usize,
                ids: None,
            })
        };
        Message::State {
            from: rng.next_below(64) as NodeId,
            weight: rng.next_f64() * 100.0 + 1.0,
            tensors,
            policy,
        }
    }

    fn rand_message(rng: &mut Pcg64) -> Message {
        if rng.next_below(2) == 0 {
            rand_gossip(rng)
        } else {
            rand_state(rng)
        }
    }

    /// Bitwise message equality (f32/f64 compared via to_bits).
    fn same(a: &Message, b: &Message) -> Result<(), String> {
        match (a, b) {
            (
                Message::StoreGossip { from: f0, entries: e0 },
                Message::StoreGossip { from: f1, entries: e1 },
            ) => {
                if f0 != f1 {
                    return Err(format!("from {f0} != {f1}"));
                }
                if e0.len() != e1.len() {
                    return Err(format!("entry count {} != {}", e0.len(), e1.len()));
                }
                for (x, y) in e0.iter().zip(e1.iter()) {
                    if x.0 != y.0
                        || x.1.loss.to_bits() != y.1.loss.to_bits()
                        || x.1.gnorm.to_bits() != y.1.gnorm.to_bits()
                        || x.1.last_tick != y.1.last_tick
                        || x.1.visits != y.1.visits
                    {
                        return Err(format!("entry {x:?} != {y:?}"));
                    }
                }
                Ok(())
            }
            (
                Message::State { from: f0, weight: w0, tensors: t0, policy: p0 },
                Message::State { from: f1, weight: w1, tensors: t1, policy: p1 },
            ) => {
                if f0 != f1 || w0.to_bits() != w1.to_bits() {
                    return Err("from/weight mismatch".into());
                }
                if t0.len() != t1.len() {
                    return Err("tensor count mismatch".into());
                }
                for (x, y) in t0.iter().zip(t1.iter()) {
                    if x.shape != y.shape {
                        return Err(format!("shape {:?} != {:?}", x.shape, y.shape));
                    }
                    let xb: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
                    let yb: Vec<u32> = y.data.iter().map(|v| v.to_bits()).collect();
                    if xb != yb {
                        return Err("tensor data not bitwise equal".into());
                    }
                }
                match (p0, p1) {
                    (None, None) => Ok(()),
                    (Some(x), Some(y)) => {
                        if x.w != y.w || x.prev_loss != y.prev_loss || x.t != y.t {
                            return Err("policy snapshot mismatch".into());
                        }
                        Ok(())
                    }
                    _ => Err("policy presence mismatch".into()),
                }
            }
            _ => Err("variant mismatch".into()),
        }
    }

    #[test]
    fn round_trips_every_variant_bitwise() {
        prop_check(
            "wire round-trip",
            0xC0FF_EE00,
            200,
            rand_message,
            |msg| {
                let frame = encode(msg);
                if frame.len() != frame_len(msg) {
                    return Err(format!(
                        "frame_len model {} != encoded {}",
                        frame_len(msg),
                        frame.len()
                    ));
                }
                let back = decode(&frame).map_err(|e| format!("decode failed: {e}"))?;
                same(msg, &back)
            },
        );
    }

    #[test]
    fn round_trips_edge_messages() {
        // empty gossip, empty tensor list, None policy, zero-dim tensor
        let edges = vec![
            Message::StoreGossip { from: 0, entries: Arc::new(Vec::new()) },
            Message::State { from: 3, weight: 1.0, tensors: Vec::new(), policy: None },
            Message::State {
                from: 7,
                weight: 2.5,
                tensors: vec![Tensor { shape: vec![0, 4], data: Vec::new() }],
                policy: Some(AdaSnapshot { w: vec![0.5; 7], prev_loss: None, t: 0, ids: None }),
            },
        ];
        for msg in &edges {
            let frame = encode(msg);
            assert_eq!(frame.len(), frame_len(msg));
            same(msg, &decode(&frame).unwrap()).unwrap();
        }
    }

    #[test]
    fn rejects_truncation_corruption_and_bad_versions() {
        let msg = Message::StoreGossip {
            from: 2,
            entries: Arc::new(vec![(
                9,
                InstanceRecord { loss: 1.5, gnorm: 0.5, last_tick: 3, visits: 2 },
            )]),
        };
        let frame = encode(&msg);
        assert!(decode(&frame).is_ok());

        // every strict prefix is an error, never a panic
        for cut in 0..frame.len() {
            assert!(decode(&frame[..cut]).is_err(), "truncation at {cut} accepted");
        }
        // trailing garbage
        let mut long = frame.clone();
        long.push(0);
        assert!(decode(&long).is_err(), "trailing byte accepted");
        // bad magic
        let mut bad = frame.clone();
        bad[0] ^= 0xFF;
        assert!(decode(&bad).is_err(), "bad magic accepted");
        // version skew must be an explicit error
        let mut bad = frame.clone();
        bad[2] = VERSION + 1;
        let err = decode(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "unhelpful version error: {err}");
        // checksum trailer flip
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(decode(&bad).is_err(), "bad checksum accepted");
        // payload flip is caught by the checksum
        let mut bad = frame;
        bad[HEADER_LEN] ^= 0x01;
        assert!(decode(&bad).is_err(), "payload corruption accepted");
    }

    #[test]
    fn random_bytes_never_panic() {
        prop_check(
            "wire fuzz",
            0xDEAD_0001,
            300,
            |rng| {
                let n = rng.next_below(200) as usize;
                (0..n).map(|_| rng.next_below(256) as u8).collect::<Vec<u8>>()
            },
            |bytes| {
                let _ = decode(bytes); // must return, Ok or Err
                let _ = read_frame(&mut &bytes[..]);
                Ok(())
            },
        );
        // valid header + checksum around a garbage payload: parse errors
        let payload = vec![0xFFu8; 16]; // unknown tag 0xFF
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC.to_le_bytes());
        frame.push(VERSION);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
        let err = decode(&frame).unwrap_err().to_string();
        assert!(err.contains("tag"), "garbage payload: {err}");
        // absurd length prefix is rejected before allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&MAGIC.to_le_bytes());
        huge.push(VERSION);
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_frame(&mut &huge[..]).is_err());
    }

    #[test]
    fn encode_guard_mirrors_decode_bounds() {
        // everything the cluster actually produces passes
        prop_check(
            "encodable messages pass the guard",
            0xFACE_0002,
            100,
            rand_message,
            |msg| check_encodable(msg).map_err(|e| e.to_string()),
        );
        // a tensor the decoder would reject fails at the sender instead
        let bad_rank = Message::State {
            from: 0,
            weight: 1.0,
            tensors: vec![Tensor { shape: vec![1; MAX_RANK + 1], data: vec![0.0] }],
            policy: None,
        };
        let err = check_encodable(&bad_rank).unwrap_err().to_string();
        assert!(err.contains("rank"), "unhelpful guard error: {err}");
        assert!(decode(&encode(&bad_rank)).is_err(), "decoder accepted what the guard rejects");
        // shape/data mismatch is caught before it hits the wire
        let bad_len = Message::State {
            from: 0,
            weight: 1.0,
            tensors: vec![Tensor { shape: vec![2, 2], data: vec![0.0; 3] }],
            policy: None,
        };
        assert!(check_encodable(&bad_len).is_err());
    }

    /// Bitwise equality for the control-plane variants (Debug-format
    /// compare is enough for integers/strings; floats go through bits).
    fn same_control(a: &Message, b: &Message) -> Result<(), String> {
        match (a, b) {
            (
                Message::BarrierReady { preq: p0, .. },
                Message::BarrierReady { preq: p1, .. },
            ) => {
                if p0.len() != p1.len() {
                    return Err("preq length mismatch".into());
                }
                for (x, y) in p0.iter().zip(p1.iter()) {
                    if x.tick != y.tick
                        || x.loss_sum.to_bits() != y.loss_sum.to_bits()
                        || x.correct.to_bits() != y.correct.to_bits()
                        || x.arrivals != y.arrivals
                    {
                        return Err(format!("preq {x:?} != {y:?}"));
                    }
                }
                let da = format!("{a:?}");
                let db = format!("{b:?}");
                if da != db {
                    return Err(format!("{da} != {db}"));
                }
                Ok(())
            }
            _ => {
                let da = format!("{a:?}");
                let db = format!("{b:?}");
                if da != db {
                    return Err(format!("{da} != {db}"));
                }
                Ok(())
            }
        }
    }

    #[test]
    fn control_family_round_trips() {
        let msgs = vec![
            Message::Hello { from: 3 },
            Message::Assign {
                node: 4,
                first_tick: 120,
                config: r#"{"nodes": 4, "max-ticks": 200}"#.to_string(),
                chaos: vec![(64, 1), (96, 2)],
                joins: vec![(80, 5)],
            },
            Message::BarrierGo {
                round: 6,
                until: 96,
                gossip: 2,
                merge: true,
                boot: false,
                churn: vec![ChurnOrder { dead: 1, epoch_tick: 64, backfill_to: 96 }],
                joins: vec![(96, 6)],
            },
            Message::BarrierGo {
                round: 0,
                until: 8,
                gossip: 3,
                merge: false,
                boot: true,
                churn: vec![],
                joins: vec![],
            },
            Message::GossipGo { round: 7, mode: 2 },
            Message::GossipGo { round: 8, mode: 1 },
            Message::BarrierReady {
                from: 2,
                round: 6,
                until: 96,
                preq: vec![
                    NodePreq { tick: 90, loss_sum: 1.25, correct: 11.0, arrivals: 17 },
                    NodePreq { tick: 91, loss_sum: 0.5, correct: 3.0, arrivals: 4 },
                ],
                digest: 0xdead_beef_cafe_f00d,
                ticks_processed: 96,
                samples_seen: 1200,
                samples_trained: 600,
                samples_replayed: 12,
                drift_detections: 1,
                store_len: 512,
                store_evicted: true,
                failed: String::new(),
            },
            Message::BarrierReady {
                from: 0,
                round: 0,
                until: 0,
                preq: vec![],
                digest: 0,
                ticks_processed: 0,
                samples_seen: 0,
                samples_trained: 0,
                samples_replayed: 0,
                drift_detections: 0,
                store_len: 0,
                store_evicted: false,
                failed: "node 0: loader ended early".to_string(),
            },
            Message::MergePayload {
                round: 12,
                tensors: vec![Tensor { shape: vec![2, 3], data: vec![0.5; 6] }],
                policy: Some(AdaSnapshot {
                    w: vec![0.25, 0.75],
                    prev_loss: Some(vec![1.0, 2.0]),
                    t: 9,
                    ids: None,
                }),
            },
            Message::MergePayload { round: 0, tensors: Vec::new(), policy: None },
            Message::Shutdown,
            Message::Heartbeat {
                from: 7,
                round: 11,
                telemetry: TelemetrySnapshot {
                    ticks: 41,
                    samples_seen: 1312,
                    samples_trained: 650,
                    samples_replayed: 12,
                    drift_detections: 2,
                    store_len: 96,
                },
            },
        ];
        for msg in &msgs {
            check_encodable(msg).unwrap();
            let frame = encode(msg);
            assert_eq!(frame.len(), frame_len(msg), "frame_len model drifted: {msg:?}");
            let back = decode(&frame).unwrap();
            same_control(msg, &back).unwrap();
            // and through the stream reader
            let mut r = &frame[..];
            same_control(msg, &read_frame(&mut r).unwrap().unwrap()).unwrap();
        }
        // oversized merge payloads fail at the sender, like State
        let bad = Message::MergePayload {
            round: 0,
            tensors: vec![Tensor { shape: vec![1; MAX_RANK + 1], data: vec![0.0] }],
            policy: None,
        };
        assert!(check_encodable(&bad).is_err());
        // an unresolved gossip mode never rides a GossipGo frame
        assert!(check_encodable(&Message::GossipGo { round: 1, mode: 0 }).is_err());
        assert!(check_encodable(&Message::GossipGo { round: 1, mode: 3 }).is_err());
        // a non-UTF-8 config string is rejected at decode, never a panic
        let ok = Message::Assign {
            node: 0,
            first_tick: 0,
            config: "ab".to_string(),
            chaos: vec![],
            joins: vec![],
        };
        let mut frame = encode(&ok);
        // config bytes start after tag(1) + node(8) + first_tick(8) + len(4)
        frame[HEADER_LEN + 21] = 0xFF;
        // fix the checksum so only the UTF-8 validation can complain
        let plen = frame.len() - HEADER_LEN - TRAILER_LEN;
        let sum = fnv1a32(&frame[HEADER_LEN..HEADER_LEN + plen]);
        let at = frame.len() - TRAILER_LEN;
        frame[at..].copy_from_slice(&sum.to_le_bytes());
        let err = decode(&frame).unwrap_err().to_string();
        assert!(err.contains("UTF-8"), "unexpected error: {err}");
    }

    /// Frame `payload` under an explicit header version (encode always
    /// writes [`VERSION`]; v1 frames must be built by hand).
    fn frame_with_version(version: u8, payload: &[u8]) -> Vec<u8> {
        let mut f = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
        f.extend_from_slice(&MAGIC.to_le_bytes());
        f.push(version);
        f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        f.extend_from_slice(payload);
        f.extend_from_slice(&fnv1a32(payload).to_le_bytes());
        f
    }

    #[test]
    fn v1_control_frames_still_decode_with_round_zero() {
        // a v1 BarrierGo payload: tag, until, gossip, merge, boot, churn
        // (no round field existed in v1)
        let mut go = vec![TAG_BARRIER_GO];
        go.extend_from_slice(&96u64.to_le_bytes()); // until
        go.push(2); // gossip = FULL
        go.push(1); // merge
        go.push(0); // boot
        go.extend_from_slice(&1u32.to_le_bytes()); // one churn order
        go.extend_from_slice(&1u64.to_le_bytes()); // dead
        go.extend_from_slice(&64u64.to_le_bytes()); // epoch_tick
        go.extend_from_slice(&96u64.to_le_bytes()); // backfill_to
        match decode(&frame_with_version(1, &go)).unwrap() {
            Message::BarrierGo { round, until, gossip, merge, boot, churn, joins } => {
                assert_eq!(round, 0, "v1 frames default round to 0");
                assert_eq!(until, 96);
                assert_eq!(gossip, 2);
                assert!(merge);
                assert!(!boot);
                assert_eq!(churn, vec![ChurnOrder { dead: 1, epoch_tick: 64, backfill_to: 96 }]);
                assert!(joins.is_empty(), "v1 frames default joins to empty");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // the same payload under a v2 header is short by the round field
        assert!(decode(&frame_with_version(2, &go)).is_err());

        // a v1 Heartbeat payload: tag, from, 6 telemetry u64s
        let mut hb = vec![TAG_HEARTBEAT];
        hb.extend_from_slice(&7u64.to_le_bytes()); // from
        for v in [41u64, 1312, 650, 12, 2, 96] {
            hb.extend_from_slice(&v.to_le_bytes());
        }
        match decode(&frame_with_version(1, &hb)).unwrap() {
            Message::Heartbeat { from, round, telemetry } => {
                assert_eq!(from, 7);
                assert_eq!(round, 0);
                assert_eq!(telemetry.ticks, 41);
                assert_eq!(telemetry.store_len, 96);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(decode(&frame_with_version(2, &hb)).is_err());

        // a v1 MergePayload: tag, empty tensor list, no policy
        let mut mp = vec![TAG_MERGE_PAYLOAD];
        mp.extend_from_slice(&0u32.to_le_bytes()); // 0 tensors
        mp.push(0); // policy = None
        match decode(&frame_with_version(1, &mp)).unwrap() {
            Message::MergePayload { round, tensors, policy } => {
                assert_eq!(round, 0);
                assert!(tensors.is_empty());
                assert!(policy.is_none());
            }
            other => panic!("wrong variant: {other:?}"),
        }

        // non-control v1 frames (unchanged layout) decode identically
        let hello = vec![TAG_HELLO, 3, 0, 0, 0, 0, 0, 0, 0];
        assert!(matches!(
            decode(&frame_with_version(1, &hello)).unwrap(),
            Message::Hello { from: 3 }
        ));
        // the stream reader is version-aware too
        let mut r = &frame_with_version(1, &go)[..];
        assert!(matches!(
            read_frame(&mut r).unwrap().unwrap(),
            Message::BarrierGo { round: 0, until: 96, .. }
        ));
        // versions above VERSION stay rejected
        let err = decode(&frame_with_version(VERSION + 1, &go)).unwrap_err().to_string();
        assert!(err.contains("version"), "unhelpful version error: {err}");
    }

    #[test]
    fn v2_control_frames_still_decode_with_default_elastic_fields() {
        // a v2 BarrierGo payload: tag, round, until, gossip, merge, boot,
        // churn (no joins list existed in v2)
        let mut go = vec![TAG_BARRIER_GO];
        go.extend_from_slice(&6u64.to_le_bytes()); // round
        go.extend_from_slice(&96u64.to_le_bytes()); // until
        go.push(1); // gossip = DELTA
        go.push(0); // merge
        go.push(0); // boot
        go.extend_from_slice(&0u32.to_le_bytes()); // no churn
        match decode(&frame_with_version(2, &go)).unwrap() {
            Message::BarrierGo { round, until, gossip, joins, .. } => {
                assert_eq!((round, until, gossip), (6, 96, 1));
                assert!(joins.is_empty(), "v2 frames default joins to empty");
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // the same payload under a v3 header is short by the joins list
        assert!(decode(&frame_with_version(3, &go)).is_err());

        // a v2 BarrierReady payload: no store_evicted flag before `failed`
        let mut ready = vec![TAG_BARRIER_READY];
        ready.extend_from_slice(&2u64.to_le_bytes()); // from
        ready.extend_from_slice(&6u64.to_le_bytes()); // round
        ready.extend_from_slice(&96u64.to_le_bytes()); // until
        ready.extend_from_slice(&0u32.to_le_bytes()); // no preq
        for v in [0xBEEFu64, 96, 1200, 600, 12, 1, 512] {
            // digest + the six counters
            ready.extend_from_slice(&v.to_le_bytes());
        }
        ready.extend_from_slice(&0u32.to_le_bytes()); // failed = ""
        match decode(&frame_with_version(2, &ready)).unwrap() {
            Message::BarrierReady { from, store_len, store_evicted, failed, .. } => {
                assert_eq!((from, store_len), (2, 512));
                assert!(!store_evicted, "v2 frames default store_evicted to false");
                assert!(failed.is_empty());
            }
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(decode(&frame_with_version(3, &ready)).is_err());

        // a v2 Assign payload: chaos list but no joins list
        let mut assign = vec![TAG_ASSIGN];
        assign.extend_from_slice(&4u64.to_le_bytes()); // node
        assign.extend_from_slice(&120u64.to_le_bytes()); // first_tick
        assign.extend_from_slice(&2u32.to_le_bytes()); // config len
        assign.extend_from_slice(b"{}");
        assign.extend_from_slice(&1u32.to_le_bytes()); // one chaos event
        assign.extend_from_slice(&64u64.to_le_bytes());
        assign.extend_from_slice(&1u64.to_le_bytes());
        match decode(&frame_with_version(2, &assign)).unwrap() {
            Message::Assign { node, chaos, joins, .. } => {
                assert_eq!(node, 4);
                assert_eq!(chaos, vec![(64, 1)]);
                assert!(joins.is_empty());
            }
            other => panic!("wrong variant: {other:?}"),
        }
        assert!(decode(&frame_with_version(3, &assign)).is_err());
    }

    #[test]
    fn read_frame_streams_back_to_back_frames() {
        let a = Message::StoreGossip { from: 1, entries: Arc::new(Vec::new()) };
        let b = Message::State { from: 2, weight: 3.0, tensors: Vec::new(), policy: None };
        let mut bytes = encode(&a);
        bytes.extend_from_slice(&encode(&b));
        let mut r = &bytes[..];
        same(&a, &read_frame(&mut r).unwrap().unwrap()).unwrap();
        same(&b, &read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF must be None");
    }
}

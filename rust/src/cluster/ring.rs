//! Seeded consistent-hash ring with virtual nodes: the cluster's shard map.
//!
//! Every worker node owns `vnodes` pseudo-random points on a `u64` ring;
//! an instance id belongs to the node owning the first point at or after
//! the id's hash (wrapping). Virtual nodes smooth the load (max/mean shard
//! load stays near 1 at 128 vnodes — property-tested), and consistent
//! hashing makes churn cheap: adding or removing one node only remaps the
//! keys whose successor point changed, ~K/N of them, never reshuffling
//! keys between surviving nodes (`tests/cluster_properties.rs`).
//!
//! The ring is a pure function of `(seed, vnodes, membership)`, so every
//! node — and the deterministic churn schedule in [`RingSchedule`] —
//! derives identical ownership without coordination.

use crate::util::rng::avalanche;

/// Worker-node identifier (dense indices assigned by the coordinator).
pub type NodeId = usize;

/// A consistent-hash ring over the current membership.
#[derive(Clone, Debug)]
pub struct HashRing {
    seed: u64,
    vnodes: usize,
    /// sorted (point, owner) pairs — the ring
    points: Vec<(u64, NodeId)>,
    /// sorted membership
    nodes: Vec<NodeId>,
}

impl HashRing {
    /// An empty ring; add nodes with [`HashRing::add_node`].
    pub fn new(seed: u64, vnodes: usize) -> HashRing {
        HashRing {
            seed,
            vnodes: vnodes.max(1),
            points: Vec::new(),
            nodes: Vec::new(),
        }
    }

    /// A ring pre-populated with `nodes`.
    pub fn with_nodes(
        seed: u64,
        vnodes: usize,
        nodes: impl IntoIterator<Item = NodeId>,
    ) -> HashRing {
        let mut r = HashRing::new(seed, vnodes);
        for n in nodes {
            r.add_node(n);
        }
        r
    }

    /// The ring point of `(node, vnode)` — pure in the seed.
    fn point(&self, node: NodeId, v: usize) -> u64 {
        avalanche(
            self.seed
                ^ (node as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ (v as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f),
        )
    }

    pub fn add_node(&mut self, node: NodeId) {
        if self.contains(node) {
            return;
        }
        self.nodes.push(node);
        self.nodes.sort_unstable();
        for v in 0..self.vnodes {
            let p = self.point(node, v);
            self.points.push((p, node));
        }
        // sort by point; owner id breaks the (astronomically rare) point tie
        self.points.sort_unstable();
    }

    pub fn remove_node(&mut self, node: NodeId) {
        self.nodes.retain(|&n| n != node);
        self.points.retain(|&(_, n)| n != node);
    }

    pub fn contains(&self, node: NodeId) -> bool {
        self.nodes.binary_search(&node).is_ok()
    }

    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The node owning instance id `key`. Panics on an empty ring.
    pub fn owner(&self, key: u64) -> NodeId {
        assert!(!self.points.is_empty(), "owner() on an empty ring");
        let h = avalanche(key ^ self.seed.rotate_left(32));
        // first point at or after h, wrapping to the start
        let i = self.points.partition_point(|&(p, _)| p < h);
        let i = if i == self.points.len() { 0 } else { i };
        self.points[i].1
    }

    /// Fraction of `sample` sequential keys whose owner differs between
    /// two rings (the churn-remap measurement).
    pub fn remap_fraction(a: &HashRing, b: &HashRing, sample: u64) -> f64 {
        let sample = sample.max(1);
        let moved = (0..sample).filter(|&k| a.owner(k) != b.owner(k)).count();
        moved as f64 / sample as f64
    }
}

/// The deterministic ownership timeline: a sorted list of `(start_tick,
/// ring)` epochs derived from the churn schedule up front, so partition
/// producers on every loader worker resolve ownership purely from the
/// tick.
#[derive(Clone, Debug)]
pub struct RingSchedule {
    epochs: Vec<(u64, HashRing)>,
}

impl RingSchedule {
    /// Schedule starting with `initial` at tick 0.
    pub fn new(initial: HashRing) -> RingSchedule {
        RingSchedule { epochs: vec![(0, initial)] }
    }

    /// Register the ring in force from `tick` on (ticks must be pushed in
    /// increasing order; equal ticks overwrite).
    pub fn push(&mut self, tick: u64, ring: HashRing) {
        if let Some(last) = self.epochs.last_mut() {
            assert!(tick >= last.0, "RingSchedule epochs must be pushed in order");
            if last.0 == tick {
                last.1 = ring;
                return;
            }
        }
        self.epochs.push((tick, ring));
    }

    /// The ring in force at `tick`.
    pub fn at(&self, tick: u64) -> &HashRing {
        let i = self.epochs.partition_point(|&(start, _)| start <= tick);
        &self.epochs[i - 1].1
    }

    /// All epochs, in order (diagnostics / remap accounting).
    pub fn epochs(&self) -> &[(u64, HashRing)] {
        &self.epochs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ownership_is_total_and_deterministic() {
        let a = HashRing::with_nodes(7, 64, 0..4);
        let b = HashRing::with_nodes(7, 64, 0..4);
        for key in 0..1000u64 {
            let o = a.owner(key);
            assert!(o < 4);
            assert_eq!(o, b.owner(key));
        }
        assert_eq!(a.nodes(), &[0, 1, 2, 3]);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn different_seeds_shard_differently() {
        let a = HashRing::with_nodes(1, 64, 0..4);
        let b = HashRing::with_nodes(2, 64, 0..4);
        let moved = HashRing::remap_fraction(&a, &b, 2048);
        assert!(moved > 0.5, "seed change barely moved keys: {moved}");
    }

    #[test]
    fn add_remove_round_trips() {
        let mut r = HashRing::with_nodes(3, 32, 0..3);
        let before: Vec<NodeId> = (0..500).map(|k| r.owner(k)).collect();
        r.add_node(7);
        assert!(r.contains(7));
        r.add_node(7); // idempotent
        assert_eq!(r.len(), 4);
        r.remove_node(7);
        assert!(!r.contains(7));
        let after: Vec<NodeId> = (0..500).map(|k| r.owner(k)).collect();
        assert_eq!(before, after, "remove must undo add exactly");
    }

    #[test]
    fn single_node_owns_everything() {
        let r = HashRing::with_nodes(9, 128, [5]);
        for k in 0..100u64 {
            assert_eq!(r.owner(k), 5);
        }
    }

    #[test]
    #[should_panic(expected = "empty ring")]
    fn empty_ring_panics_on_owner() {
        HashRing::new(0, 8).owner(1);
    }

    #[test]
    fn schedule_resolves_epochs() {
        let r0 = HashRing::with_nodes(1, 16, 0..2);
        let mut r1 = r0.clone();
        r1.add_node(2);
        let mut r2 = r1.clone();
        r2.remove_node(0);
        let mut s = RingSchedule::new(r0);
        s.push(10, r1);
        s.push(20, r2);
        assert_eq!(s.at(0).len(), 2);
        assert_eq!(s.at(9).len(), 2);
        assert_eq!(s.at(10).len(), 3);
        assert_eq!(s.at(19).len(), 3);
        assert!(!s.at(25).contains(0));
        assert_eq!(s.epochs().len(), 3);
    }
}

//! Multi-node sharded streaming training (ROADMAP open item #1: the
//! mutex-sharded `InstanceStore` was the single-node seed of exactly this
//! design).
//!
//!   * [`ring`] — seeded consistent-hash [`ring::HashRing`] with virtual
//!     nodes (instance-id → owner), plus the deterministic
//!     [`ring::RingSchedule`] that compiles a churn schedule into
//!     ownership epochs;
//!   * [`transport`] — the [`transport::Transport`] trait with the
//!     deterministic in-process [`transport::Loopback`] implementation;
//!   * [`tcp`] — the same trait over 127.0.0.1 sockets ([`tcp::Tcp`]),
//!     acked frame writes keeping drain order identical to loopback;
//!   * [`wire`] — the versioned, checksummed, length-prefixed frame
//!     format both gossip and merge messages travel in (bitwise-exact
//!     float round-trips, so TCP runs stay deterministic);
//!   * [`node`] — [`node::ClusterNode`]: one worker's backend + model
//!     state + `TickEngine` + pipeline loader over its ring partition;
//!   * [`trainer`] — the coordinator: scoped-thread segments between sync
//!     barriers, store gossip (freshest-tick-wins merge), weighted
//!     model/policy averaging, and kill/join churn with bounded key
//!     remapping.
//!
//!   * [`proc`] — the multi-process runtime: a `worker` subcommand body
//!     plus a process coordinator speaking a control-plane barrier
//!     protocol (`Hello`/`Assign`/`BarrierGo`/`BarrierReady`/
//!     `MergePayload`/`Shutdown`/`Heartbeat`) over the same wire frames;
//!     selected with `--workers processes`, bit-identical to the thread
//!     runtime.
//!
//! CLI surface: `adaselection cluster --nodes 4 --max-ticks 400
//! [--workers threads|processes] [--transport loopback|tcp]
//! [--gossip full|delta] [--gossip-every N] [--full-gossip-every K]
//! [--merge-every N] [--kill-at T --kill-node I] [--join-at T]`.

pub mod node;
pub mod proc;
pub mod ring;
pub mod tcp;
pub mod trainer;
pub mod transport;
pub mod wire;

pub use node::{ClusterNode, NodePreq, PartitionProducer};
pub use ring::{HashRing, NodeId, RingSchedule};
pub use tcp::Tcp;
pub use trainer::{run, ClusterResult, NodeSummary};
pub use transport::{ChurnOrder, Loopback, Message, Transport};

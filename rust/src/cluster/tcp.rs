//! TCP socket transport: [`Transport`] over 127.0.0.1 sockets speaking the
//! [`wire`](crate::cluster::wire) frame format.
//!
//! Topology: `register` binds one loopback `TcpListener` per node and
//! spawns an acceptor thread; each accepted connection gets a reader
//! thread that decodes frames into the node's mailbox — the same
//! Vec-behind-a-mutex the in-process [`Loopback`] uses, so `drain`
//! semantics are identical and the node/coordinator code does not change.
//!
//! Determinism: a `send` writes one frame and then blocks on a one-byte
//! acknowledgement the reader emits *after* enqueueing the message. A
//! sender therefore knows its message is in the destination mailbox when
//! `send` returns — sequential sends from the coordinator land in send
//! order exactly like loopback pushes, and concurrent senders keep
//! per-sender FIFO. Sender connections are cached per destination and
//! re-established transparently if a peer re-registers on a new port.
//!
//! [`Loopback`]: crate::cluster::transport::Loopback

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::ring::NodeId;
use crate::cluster::transport::{Message, Transport};
use crate::cluster::wire;

/// Frame accepted and enqueued in the destination mailbox.
const ACK_OK: u8 = 1;
/// Destination was unregistered while the frame was in flight.
const ACK_CLOSED: u8 = 0;

/// One registered node's receive side.
struct Endpoint {
    addr: SocketAddr,
    mailbox: Arc<Mutex<Vec<Message>>>,
    /// cleared on unregister: readers stop enqueueing, the acceptor exits
    open: Arc<AtomicBool>,
}

/// The socket transport (see module docs).
pub struct Tcp {
    endpoints: Mutex<HashMap<NodeId, Endpoint>>,
    /// cached sender connections, keyed by destination
    conns: Mutex<HashMap<NodeId, TcpStream>>,
}

impl Tcp {
    pub fn new() -> Tcp {
        Tcp {
            endpoints: Mutex::new(HashMap::new()),
            conns: Mutex::new(HashMap::new()),
        }
    }

    /// The listener address of a registered node (tests and diagnostics).
    pub fn addr_of(&self, node: NodeId) -> Option<SocketAddr> {
        self.endpoints.lock().unwrap().get(&node).map(|e| e.addr)
    }

    /// Deliver one pre-encoded frame to `to` and wait for its ack.
    ///
    /// Delivery is **at-most-once**: a cached connection is reused only
    /// while it still points at the destination's current listener, and a
    /// failure once the frame may have hit the wire surfaces as an error
    /// instead of a silent re-send — retrying could double-deliver when
    /// the failure races the ack (the receiver enqueued, the ack was
    /// lost), and a duplicated merge frame would skew the weighted model
    /// average without any visible symptom.
    fn send_frame(&self, to: NodeId, frame: &[u8]) -> anyhow::Result<()> {
        let addr = {
            let eps = self.endpoints.lock().unwrap();
            match eps.get(&to) {
                Some(ep) => ep.addr,
                None => anyhow::bail!("transport: unknown destination node {to}"),
            }
        };
        // the conns lock is held across write+ack: sends serialize, so a
        // mailbox's arrival order is exactly the senders' completion order
        let mut conns = self.conns.lock().unwrap();
        if let Some(mut stream) = conns.remove(&to) {
            let same_peer = stream.peer_addr().map(|a| a == addr).unwrap_or(false);
            if same_peer {
                // a live cached connection: use it, no fallback after this
                send_on(&mut stream, frame)
                    .map_err(|e| anyhow::anyhow!("transport: send to node {to}: {e}"))?;
                conns.insert(to, stream);
                return Ok(());
            }
            // stale endpoint (peer re-registered on a new port): nothing
            // was written yet, so a fresh connect is still exactly-once
        }
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("transport: connect to node {to} at {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        send_on(&mut stream, frame)
            .map_err(|e| anyhow::anyhow!("transport: send to node {to}: {e}"))?;
        conns.insert(to, stream);
        Ok(())
    }
}

impl Default for Tcp {
    fn default() -> Self {
        Tcp::new()
    }
}

/// Accept connections for one node until its endpoint closes. Only the
/// shutdown flag ends the loop — `accept` errors can be transient
/// (ECONNABORTED when a connection resets before being accepted, fd
/// pressure) and a live node's listener must outlive them.
fn accept_loop(listener: TcpListener, mailbox: Arc<Mutex<Vec<Message>>>, open: Arc<AtomicBool>) {
    loop {
        if !open.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // the wake-up connection from unregister/Drop carries no
                // frames
                if !open.load(Ordering::SeqCst) {
                    break;
                }
                let mailbox = mailbox.clone();
                let open = open.clone();
                std::thread::spawn(move || serve_conn(stream, mailbox, open));
            }
            Err(_) => {
                // brief pause so a persistent errno (EMFILE) cannot spin
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
    }
}

/// Decode frames off one connection into the mailbox, acking each. Exits
/// on peer close or any protocol error (the sender then sees a dead
/// connection and reports the failure).
fn serve_conn(mut stream: TcpStream, mailbox: Arc<Mutex<Vec<Message>>>, open: Arc<AtomicBool>) {
    stream.set_nodelay(true).ok();
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(msg)) => {
                let ack = if open.load(Ordering::SeqCst) {
                    mailbox.lock().unwrap().push(msg);
                    ACK_OK
                } else {
                    ACK_CLOSED
                };
                if stream.write_all(&[ack]).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => return,
        }
    }
}

/// Write one frame and wait for the enqueue acknowledgement.
fn send_on(stream: &mut TcpStream, frame: &[u8]) -> std::io::Result<()> {
    stream.write_all(frame)?;
    stream.flush()?;
    let mut ack = [0u8; 1];
    stream.read_exact(&mut ack)?;
    if ack[0] != ACK_OK {
        return Err(std::io::Error::other("destination mailbox closed"));
    }
    Ok(())
}

impl Transport for Tcp {
    fn register(&self, node: NodeId) {
        let mut eps = self.endpoints.lock().unwrap();
        if eps.contains_key(&node) {
            return; // idempotent: the existing mailbox survives
        }
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .unwrap_or_else(|e| panic!("tcp transport: bind loopback listener: {e}"));
        let addr = listener.local_addr().expect("listener has a local addr");
        let mailbox = Arc::new(Mutex::new(Vec::new()));
        let open = Arc::new(AtomicBool::new(true));
        {
            let mailbox = mailbox.clone();
            let open = open.clone();
            std::thread::spawn(move || accept_loop(listener, mailbox, open));
        }
        eps.insert(node, Endpoint { addr, mailbox, open });
    }

    fn unregister(&self, node: NodeId) {
        let ep = self.endpoints.lock().unwrap().remove(&node);
        if let Some(ep) = ep {
            ep.open.store(false, Ordering::SeqCst);
            // wake the blocked accept() so the listener thread exits
            let _ = TcpStream::connect(ep.addr);
            ep.mailbox.lock().unwrap().clear();
        }
        self.conns.lock().unwrap().remove(&node);
    }

    fn send(&self, to: NodeId, msg: Message) -> anyhow::Result<()> {
        wire::check_encodable(&msg)?;
        self.send_frame(to, &wire::encode(&msg))
    }

    fn broadcast(&self, to: &[NodeId], msg: &Message) -> anyhow::Result<()> {
        wire::check_encodable(msg)?;
        // the whole point of overriding: one encode for the entire fan-out
        let frame = wire::encode(msg);
        for &node in to {
            self.send_frame(node, &frame)?;
        }
        Ok(())
    }

    fn drain(&self, node: NodeId) -> Vec<Message> {
        let mailbox = {
            let eps = self.endpoints.lock().unwrap();
            eps.get(&node).map(|ep| ep.mailbox.clone())
        };
        match mailbox {
            Some(m) => std::mem::take(&mut *m.lock().unwrap()),
            None => Vec::new(),
        }
    }
}

impl Drop for Tcp {
    fn drop(&mut self) {
        let eps: Vec<Endpoint> =
            self.endpoints.lock().unwrap().drain().map(|(_, ep)| ep).collect();
        // dropping cached conns EOFs the reader threads
        self.conns.lock().unwrap().clear();
        for ep in eps {
            ep.open.store(false, Ordering::SeqCst);
            let _ = TcpStream::connect(ep.addr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gossip(from: NodeId) -> Message {
        Message::StoreGossip { from, entries: Arc::new(Vec::new()) }
    }

    // the full Transport contract is covered for both implementations in
    // tests/transport_conformance.rs; these are tcp-specific edges

    #[test]
    fn reregistration_moves_the_endpoint() {
        let t = Tcp::new();
        t.register(1);
        assert!(t.addr_of(1).is_some());
        t.send(1, gossip(0)).unwrap();
        assert_eq!(t.drain(1).len(), 1);
        t.unregister(1);
        assert!(t.addr_of(1).is_none());
        t.register(1);
        assert!(t.addr_of(1).is_some());
        // usually a fresh port; even on port reuse the old connection's
        // closed flag forces a reconnect — either way delivery must work
        t.send(1, gossip(2)).unwrap();
        let got = t.drain(1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].from_node(), 2);
    }

    #[test]
    fn sends_reuse_one_connection() {
        let t = Tcp::new();
        t.register(3);
        for _ in 0..10 {
            t.send(3, gossip(1)).unwrap();
        }
        assert_eq!(t.drain(3).len(), 10);
        assert_eq!(t.conns.lock().unwrap().len(), 1, "connection not cached");
    }
}

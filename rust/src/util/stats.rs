//! Small statistics substrate: moments, quantiles, ranking.
//!
//! Used by the selection policies (standardize/softmax over batch losses),
//! the metrics layer (run summaries), and the bench harness (robust timing
//! statistics). All functions are allocation-light and operate on `f32`
//! batch vectors or `f64` aggregates.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population standard deviation.
pub fn std(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let var = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64;
    var.sqrt() as f32
}

/// Standardize in place: (x - mean) / (std + eps). Mirrors the L1 kernel.
pub fn standardize(xs: &mut [f32], eps: f32) {
    let m = mean(xs);
    let s = std_biased_eps(xs, m);
    for x in xs.iter_mut() {
        *x = (*x - m) / (s + eps);
    }
}

fn std_biased_eps(xs: &[f32], m: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let var = xs
        .iter()
        .map(|&x| ((x - m) as f64).powi(2))
        .sum::<f64>()
        / xs.len() as f64;
    ((var + 1e-12).sqrt()) as f32
}

/// Numerically-stable softmax in place (sums to 1).
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f64;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x as f64;
    }
    for x in xs.iter_mut() {
        *x = (*x as f64 / sum) as f32;
    }
}

/// q-quantile (0..=1) by linear interpolation on a sorted copy.
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Median shortcut.
pub fn median(xs: &[f32]) -> f32 {
    quantile(xs, 0.5)
}

/// Welford online mean/variance accumulator (metrics layer).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Competition ranking (1 = best). `lower_is_better` picks the direction.
/// Ties get the same (average) rank — matching how the paper's Table 3
/// averages method rankings across sampling rates.
pub fn ranks(values: &[f64], lower_is_better: bool) -> Vec<f64> {
    let n = values.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        let (x, y) = (values[a], values[b]);
        if lower_is_better {
            x.partial_cmp(&y).unwrap()
        } else {
            y.partial_cmp(&x).unwrap()
        }
    });
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && values[idx[j + 1]] == values[idx[i]] {
            j += 1;
        }
        // average rank for the tie group [i, j]
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((std(&xs) - 1.1180339).abs() < 1e-4);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut xs = [1.0f32, 2.0, 3.0];
        softmax(&mut xs);
        let sum: f32 = xs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(xs[0] < xs[1] && xs[1] < xs[2]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut xs = [1000.0f32, 1001.0];
        softmax(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn standardize_zero_mean_unit_std() {
        let mut xs = [1.0f32, 5.0, 9.0, 13.0];
        standardize(&mut xs, 1e-6);
        assert!(mean(&xs).abs() < 1e-5);
        assert!((std(&xs) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn standardize_constant_vector_is_zero() {
        let mut xs = [3.0f32; 8];
        standardize(&mut xs, 1e-6);
        assert!(xs.iter().all(|&x| x.abs() < 1e-3));
    }

    #[test]
    fn quantiles() {
        let xs = [4.0f32, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-9);
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn ranks_higher_better() {
        // accuracies: 0.9 best -> rank 1
        let r = ranks(&[0.5, 0.9, 0.7], false);
        assert_eq!(r, vec![3.0, 1.0, 2.0]);
    }

    #[test]
    fn ranks_lower_better_with_ties() {
        let r = ranks(&[1.0, 2.0, 1.0, 3.0], true);
        assert_eq!(r, vec![1.5, 3.0, 1.5, 4.0]);
    }
}

//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! [`Pcg64`] is the PCG-XSL-RR 128/64 generator: one 128-bit LCG step plus
//! an xor-shift/rotate output permutation. Seeding goes through SplitMix64
//! so small/sequential seeds still produce well-mixed streams. Everything
//! downstream (data generators, shuffles, selection tie-breaking) derives
//! from this one generator so runs are reproducible end to end.

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// The SplitMix64 finalizer: stateless avalanche of a 64-bit value. The
/// single owner of the mixing constants — seeding, shard hashing
/// (`stream::store`) and hash-chain stream generators all route here.
pub fn avalanche(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    avalanche(*x)
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let i0 = splitmix64(&mut sm) as u128;
        let i1 = splitmix64(&mut sm) as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent stream for a named sub-component.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Raw generator state as four words (checkpoint/resume support).
    pub fn state_words(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_words`] output, continuing
    /// the stream exactly where it left off.
    pub fn from_state_words(w: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: ((w[0] as u128) << 64) | w[1] as u128,
            inc: ((w[2] as u128) << 64) | w[3] as u128,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased rejection).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (polar-free, uses both trig halves).
    pub fn normal(&mut self) -> f64 {
        // guard against log(0)
        let u1 = (1.0 - self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weighted_index: zero total");
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-distributed value in `[0, n)` with exponent `s` (for the
    /// synthetic text corpus vocabulary).
    pub fn zipf(&mut self, n: usize, _s: f64, harmonic: &[f64]) -> usize {
        // harmonic[i] = sum_{k<=i+1} k^-s, precomputed by the caller
        let total = harmonic[n - 1];
        let r = self.next_f64() * total;
        match harmonic[..n].binary_search_by(|h| h.partial_cmp(&r).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(n - 1),
        }
    }
}

/// Precompute the generalized harmonic numbers used by [`Pcg64::zipf`].
pub fn zipf_harmonic(n: usize, s: f64) -> Vec<f64> {
    let mut h = Vec::with_capacity(n);
    let mut acc = 0.0;
    for k in 1..=n {
        acc += (k as f64).powf(-s);
        h.push(acc);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn state_words_resume_the_stream() {
        let mut a = Pcg64::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_state_words(a.state_words());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg64::new(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(7);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..100 {
            assert_eq!(rng.weighted_index(&w), 2);
        }
        let w2 = [1.0, 3.0];
        let picks: usize = (0..10_000).map(|_| rng.weighted_index(&w2)).sum();
        let frac = picks as f64 / 10_000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg64::new(8);
        let h = zipf_harmonic(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            let v = rng.zipf(100, 1.1, &h);
            assert!(v < 100);
            counts[v] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[80]);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Pcg64::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn permutation_deterministic_per_seed() {
        let p1 = Pcg64::new(10).permutation(50);
        let p2 = Pcg64::new(10).permutation(50);
        assert_eq!(p1, p2);
    }
}

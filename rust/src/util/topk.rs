//! Top-k selection (the eq. 6 thresholding step, L3 hot path).
//!
//! `top_k_indices` is the per-iteration call: given the fused scores
//! `s_{i,t}` from the L1 kernel, return the indices of the k largest.
//! Implemented as an O(n) quickselect partition followed by an O(k log k)
//! sort of the winners (deterministic output order: descending score,
//! index ascending as tie-break — ties must be stable for reproducibility).

/// Indices of the `k` largest values, descending by value then ascending
/// by index. `k > len` is clamped.
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let n = values.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        // quickselect: partition so the k largest occupy idx[..k]
        idx.select_nth_unstable_by(k - 1, |&a, &b| cmp_desc(values, a, b));
    }
    idx.truncate(k);
    idx.sort_unstable_by(|&a, &b| cmp_desc(values, a, b));
    idx
}

/// Indices of the `k` smallest values (ascending value, index tie-break).
pub fn bottom_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let n = values.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        idx.select_nth_unstable_by(k - 1, |&a, &b| cmp_asc(values, a, b));
    }
    idx.truncate(k);
    idx.sort_unstable_by(|&a, &b| cmp_asc(values, a, b));
    idx
}

/// Full argsort, descending.
pub fn argsort_desc(values: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_unstable_by(|&a, &b| cmp_desc(values, a, b));
    idx
}

fn cmp_desc(values: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    values[b]
        .partial_cmp(&values[a])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b))
}

fn cmp_asc(values: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    values[a]
        .partial_cmp(&values[b])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn picks_largest() {
        let v = [0.1f32, 0.9, 0.5, 0.7];
        assert_eq!(top_k_indices(&v, 2), vec![1, 3]);
        assert_eq!(bottom_k_indices(&v, 2), vec![0, 2]);
    }

    #[test]
    fn k_zero_and_k_over_len() {
        let v = [1.0f32, 2.0];
        assert!(top_k_indices(&v, 0).is_empty());
        assert_eq!(top_k_indices(&v, 10), vec![1, 0]);
    }

    #[test]
    fn ties_break_by_index() {
        let v = [5.0f32, 5.0, 5.0, 1.0];
        assert_eq!(top_k_indices(&v, 2), vec![0, 1]);
        assert_eq!(bottom_k_indices(&v, 3), vec![3, 0, 1]);
    }

    #[test]
    fn matches_full_sort_on_random_inputs() {
        let mut rng = Pcg64::new(11);
        for trial in 0..50 {
            let n = 1 + (rng.next_below(300) as usize);
            let k = rng.next_below(n as u64 + 1) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let got = top_k_indices(&v, k);
            let want: Vec<usize> = argsort_desc(&v)[..k].to_vec();
            assert_eq!(got, want, "trial={trial} n={n} k={k}");
        }
    }

    #[test]
    fn argsort_desc_is_sorted() {
        let v = [3.0f32, 1.0, 2.0];
        assert_eq!(argsort_desc(&v), vec![0, 2, 1]);
    }
}

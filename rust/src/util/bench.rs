//! Micro-benchmark harness (no `criterion` offline): warmup + timed runs,
//! robust stats, aligned table output. Used by `cargo bench` targets.

use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.median_ns.max(1e-9)
    }
}

/// Time `f` (which should perform ONE operation) adaptively: targets
/// ~`budget_ms` of total measurement after warmup.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target = (budget_ms as f64 / 1e3 / once).clamp(3.0, 10_000.0) as usize;
    for _ in 0..(target / 10).max(1) {
        f();
    }
    // measure
    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let p95_ns = samples[(samples.len() as f64 * 0.95) as usize - 1];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns,
        p95_ns,
        mean_ns,
    }
}

/// Pretty-print a group of results.
pub fn print_results(title: &str, results: &[BenchResult]) {
    println!("\n## {title}");
    println!(
        "{:<46} {:>8} {:>14} {:>14} {:>12}",
        "benchmark", "iters", "median", "p95", "ops/s"
    );
    for r in results {
        println!(
            "{:<46} {:>8} {:>14} {:>14} {:>12.1}",
            r.name,
            r.iters,
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            r.throughput_per_s()
        );
    }
}

/// Write a machine-readable summary next to the human table so the perf
/// trajectory is trackable across PRs (`BENCH_<name>.json` in the working
/// directory, or `$BENCH_JSON_DIR` when set).
pub fn write_json(bench_name: &str, results: &[BenchResult]) -> std::io::Result<()> {
    use crate::util::json::Json;
    let arr = Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::from(r.name.as_str())),
                    ("iters", Json::from(r.iters)),
                    ("median_ns", Json::from(r.median_ns)),
                    ("p95_ns", Json::from(r.p95_ns)),
                    ("mean_ns", Json::from(r.mean_ns)),
                    ("ops_per_s", Json::from(r.throughput_per_s())),
                ])
            })
            .collect(),
    );
    let j = Json::obj(vec![("bench", Json::from(bench_name)), ("results", arr)]);
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{bench_name}.json"));
    std::fs::write(&path, j.to_string())?;
    println!("[wrote {}]", path.display());
    Ok(())
}

/// This process's per-kernel continuous-profiling digests as benchmark
/// rows (`kernel/<name>`, median = streaming p50). Bench targets append
/// these after their workloads so a `bench-diff` regression can name the
/// backend kernel that moved, not just the end-to-end number.
pub fn kernel_results() -> Vec<BenchResult> {
    crate::obs::prof::kernel_stats()
        .into_iter()
        .filter(|s| s.count > 0)
        .map(|s| BenchResult {
            name: format!("kernel/{}", s.kernel),
            iters: s.count as usize,
            median_ns: s.p50_seconds * 1e9,
            p95_ns: s.p95_seconds * 1e9,
            mean_ns: s.total_seconds / s.count as f64 * 1e9,
        })
        .collect()
}

/// Outcome of comparing one run's bench JSONs against a baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchDiff {
    /// (bench, name, old_median_ns, new_median_ns) for every key in both
    pub compared: Vec<(String, String, f64, f64)>,
    /// subset of `compared` whose median regressed past the tolerance
    pub regressions: Vec<(String, String, f64, f64)>,
    /// keys present in only one side (new/renamed/deleted benchmarks)
    pub unmatched: Vec<String>,
}

/// Collect `(bench, name) -> median_ns` from every `BENCH_*.json` in
/// `dir`. With `lenient` set (the baseline side of the CI gate), a file
/// that cannot be read or parsed, or whose schema doesn't match, is
/// WARNed and skipped — its benchmarks simply go unmatched, degrading to
/// the same trivial pass as a missing baseline. The current side stays
/// strict: a corrupt file *this* run produced is a real error.
fn load_medians(
    dir: &std::path::Path,
    lenient: bool,
) -> anyhow::Result<std::collections::BTreeMap<(String, String), f64>> {
    use crate::util::json::Json;
    let mut out = std::collections::BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("bench-diff: read {}: {e}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let Some(fname) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if !(fname.starts_with("BENCH_") && fname.ends_with(".json")) {
            continue;
        }
        let parsed = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("bench-diff: read {}: {e}", path.display()))
            .and_then(|text| {
                Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("bench-diff: parse {}: {e}", path.display()))
            });
        let j = match parsed {
            Ok(j) => j,
            Err(e) if lenient => {
                log::warn!("{e:#}; skipping this baseline file (degrades to trivial pass)");
                continue;
            }
            Err(e) => return Err(e),
        };
        let bench = j
            .at(&["bench"])
            .ok()
            .and_then(|b| b.as_str().ok())
            .unwrap_or("unknown")
            .to_string();
        let Some(results) = j.at(&["results"]).ok().and_then(|r| r.as_arr().ok()) else {
            if lenient {
                log::warn!(
                    "bench-diff: {} has no results array (schema mismatch); \
                     skipping this baseline file (degrades to trivial pass)",
                    path.display()
                );
            }
            continue;
        };
        for r in results {
            let (Some(name), Some(median)) = (
                r.at(&["name"]).ok().and_then(|n| n.as_str().ok()),
                r.at(&["median_ns"]).ok().and_then(|m| m.as_f64().ok()),
            ) else {
                continue;
            };
            out.insert((bench.clone(), name.to_string()), median);
        }
    }
    Ok(out)
}

/// Compare every matching `(bench, name)` key between two directories of
/// `BENCH_*.json` files. A key regresses when its new median exceeds the
/// old by more than `tolerance` (0.15 = >15% slower). Keys on only one
/// side are reported but never fail — they are new or retired benchmarks,
/// and an empty baseline passes trivially (the first CI run has nothing
/// to compare against).
pub fn diff(
    baseline: &std::path::Path,
    current: &std::path::Path,
    tolerance: f64,
) -> anyhow::Result<BenchDiff> {
    let old = load_medians(baseline, true)?;
    let new = load_medians(current, false)?;
    let mut d = BenchDiff::default();
    for (key, &new_median) in &new {
        match old.get(key) {
            Some(&old_median) => {
                d.compared.push((key.0.clone(), key.1.clone(), old_median, new_median));
                if new_median > old_median * (1.0 + tolerance) {
                    d.regressions.push((key.0.clone(), key.1.clone(), old_median, new_median));
                }
            }
            None => d.unmatched.push(format!("{}/{} (new)", key.0, key.1)),
        }
    }
    for key in old.keys() {
        if !new.contains_key(key) {
            d.unmatched.push(format!("{}/{} (baseline only)", key.0, key.1));
        }
    }
    Ok(d)
}

/// Human time formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 5, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.median_ns > 0.0);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn write_json_emits_parseable_summary() {
        let dir = std::env::temp_dir().join(format!("ada_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("BENCH_JSON_DIR", &dir);
        let r = bench("one op", 1, || {
            std::hint::black_box((0..10).sum::<usize>());
        });
        let res = write_json("testsuite", &[r]);
        std::env::remove_var("BENCH_JSON_DIR");
        res.unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_testsuite.json")).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.at(&["bench"]).unwrap().as_str().unwrap(), "testsuite");
        let results = j.at(&["results"]).unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].at(&["name"]).unwrap().as_str().unwrap(), "one op");
        assert!(results[0].at(&["median_ns"]).unwrap().as_f64().unwrap() > 0.0);
    }

    fn write_bench_json(dir: &std::path::Path, bench: &str, items: &[(&str, f64)]) {
        let results: Vec<String> = items
            .iter()
            .map(|(name, median)| {
                format!(
                    r#"{{"name": "{name}", "iters": 10, "median_ns": {median}, "p95_ns": {median}, "mean_ns": {median}, "ops_per_s": 1.0}}"#
                )
            })
            .collect();
        let body =
            format!(r#"{{"bench": "{bench}", "results": [{}]}}"#, results.join(", "));
        std::fs::write(dir.join(format!("BENCH_{bench}.json")), body).unwrap();
    }

    #[test]
    fn diff_flags_only_regressions_past_tolerance() {
        let root =
            std::env::temp_dir().join(format!("ada_bench_diff_{}", std::process::id()));
        let (old, new) = (root.join("old"), root.join("new"));
        std::fs::create_dir_all(&old).unwrap();
        std::fs::create_dir_all(&new).unwrap();
        write_bench_json(&old, "suite", &[("fast", 100.0), ("slow", 100.0), ("gone", 5.0)]);
        write_bench_json(&new, "suite", &[("fast", 110.0), ("slow", 130.0), ("born", 5.0)]);
        let d = diff(&old, &new, 0.15).unwrap();
        assert_eq!(d.compared.len(), 2);
        assert_eq!(d.regressions.len(), 1, "{:?}", d.regressions);
        assert_eq!(d.regressions[0].1, "slow");
        assert_eq!(d.unmatched.len(), 2, "{:?}", d.unmatched);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn diff_empty_baseline_passes_trivially() {
        let root =
            std::env::temp_dir().join(format!("ada_bench_diff_empty_{}", std::process::id()));
        let (old, new) = (root.join("old"), root.join("new"));
        std::fs::create_dir_all(&old).unwrap();
        std::fs::create_dir_all(&new).unwrap();
        write_bench_json(&new, "suite", &[("anything", 42.0)]);
        let d = diff(&old, &new, 0.15).unwrap();
        assert!(d.regressions.is_empty());
        assert!(d.compared.is_empty());
        assert_eq!(d.unmatched.len(), 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn diff_corrupt_baseline_degrades_to_trivial_pass() {
        let root = std::env::temp_dir()
            .join(format!("ada_bench_diff_corrupt_{}", std::process::id()));
        let (old, new) = (root.join("old"), root.join("new"));
        std::fs::create_dir_all(&old).unwrap();
        std::fs::create_dir_all(&new).unwrap();
        // Truncated/garbage JSON in the baseline: WARN + skip, never an error.
        std::fs::write(old.join("BENCH_suite.json"), r#"{"bench": "suite", "resul"#).unwrap();
        write_bench_json(&new, "suite", &[("anything", 42.0)]);
        let d = diff(&old, &new, 0.15).unwrap();
        assert!(d.regressions.is_empty());
        assert!(d.compared.is_empty());
        assert_eq!(d.unmatched.len(), 1, "{:?}", d.unmatched);
        // The same corruption on the *current* side is a hard error.
        std::fs::write(new.join("BENCH_bad.json"), r#"not json"#).unwrap();
        assert!(diff(&old, &new, 0.15).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn diff_schema_mismatched_baseline_degrades_to_trivial_pass() {
        let root = std::env::temp_dir()
            .join(format!("ada_bench_diff_schema_{}", std::process::id()));
        let (old, new) = (root.join("old"), root.join("new"));
        std::fs::create_dir_all(&old).unwrap();
        std::fs::create_dir_all(&new).unwrap();
        // Valid JSON, wrong shape: `results` is not an array.
        std::fs::write(
            old.join("BENCH_suite.json"),
            r#"{"bench": "suite", "results": {"oops": true}}"#,
        )
        .unwrap();
        write_bench_json(&new, "suite", &[("anything", 42.0)]);
        let d = diff(&old, &new, 0.15).unwrap();
        assert!(d.regressions.is_empty());
        assert!(d.compared.is_empty());
        assert_eq!(d.unmatched.len(), 1, "{:?}", d.unmatched);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}

//! Micro-benchmark harness (no `criterion` offline): warmup + timed runs,
//! robust stats, aligned table output. Used by `cargo bench` targets.

use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.median_ns.max(1e-9)
    }
}

/// Time `f` (which should perform ONE operation) adaptively: targets
/// ~`budget_ms` of total measurement after warmup.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target = (budget_ms as f64 / 1e3 / once).clamp(3.0, 10_000.0) as usize;
    for _ in 0..(target / 10).max(1) {
        f();
    }
    // measure
    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let p95_ns = samples[(samples.len() as f64 * 0.95) as usize - 1];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns,
        p95_ns,
        mean_ns,
    }
}

/// Pretty-print a group of results.
pub fn print_results(title: &str, results: &[BenchResult]) {
    println!("\n## {title}");
    println!(
        "{:<46} {:>8} {:>14} {:>14} {:>12}",
        "benchmark", "iters", "median", "p95", "ops/s"
    );
    for r in results {
        println!(
            "{:<46} {:>8} {:>14} {:>14} {:>12.1}",
            r.name,
            r.iters,
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            r.throughput_per_s()
        );
    }
}

/// Write a machine-readable summary next to the human table so the perf
/// trajectory is trackable across PRs (`BENCH_<name>.json` in the working
/// directory, or `$BENCH_JSON_DIR` when set).
pub fn write_json(bench_name: &str, results: &[BenchResult]) -> std::io::Result<()> {
    use crate::util::json::Json;
    let arr = Json::Arr(
        results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::from(r.name.as_str())),
                    ("iters", Json::from(r.iters)),
                    ("median_ns", Json::from(r.median_ns)),
                    ("p95_ns", Json::from(r.p95_ns)),
                    ("mean_ns", Json::from(r.mean_ns)),
                    ("ops_per_s", Json::from(r.throughput_per_s())),
                ])
            })
            .collect(),
    );
    let j = Json::obj(vec![("bench", Json::from(bench_name)), ("results", arr)]);
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{bench_name}.json"));
    std::fs::write(&path, j.to_string())?;
    println!("[wrote {}]", path.display());
    Ok(())
}

/// Human time formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 5, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.median_ns > 0.0);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn write_json_emits_parseable_summary() {
        let dir = std::env::temp_dir().join(format!("ada_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("BENCH_JSON_DIR", &dir);
        let r = bench("one op", 1, || {
            std::hint::black_box((0..10).sum::<usize>());
        });
        let res = write_json("testsuite", &[r]);
        std::env::remove_var("BENCH_JSON_DIR");
        res.unwrap();
        let text = std::fs::read_to_string(dir.join("BENCH_testsuite.json")).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.at(&["bench"]).unwrap().as_str().unwrap(), "testsuite");
        let results = j.at(&["results"]).unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].at(&["name"]).unwrap().as_str().unwrap(), "one op");
        assert!(results[0].at(&["median_ns"]).unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}

//! Micro-benchmark harness (no `criterion` offline): warmup + timed runs,
//! robust stats, aligned table output. Used by `cargo bench` targets.

use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.median_ns.max(1e-9)
    }
}

/// Time `f` (which should perform ONE operation) adaptively: targets
/// ~`budget_ms` of total measurement after warmup.
pub fn bench(name: &str, budget_ms: u64, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let target = (budget_ms as f64 / 1e3 / once).clamp(3.0, 10_000.0) as usize;
    for _ in 0..(target / 10).max(1) {
        f();
    }
    // measure
    let mut samples = Vec::with_capacity(target);
    for _ in 0..target {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[samples.len() / 2];
    let p95_ns = samples[(samples.len() as f64 * 0.95) as usize - 1];
    let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns,
        p95_ns,
        mean_ns,
    }
}

/// Pretty-print a group of results.
pub fn print_results(title: &str, results: &[BenchResult]) {
    println!("\n## {title}");
    println!(
        "{:<46} {:>8} {:>14} {:>14} {:>12}",
        "benchmark", "iters", "median", "p95", "ops/s"
    );
    for r in results {
        println!(
            "{:<46} {:>8} {:>14} {:>14} {:>12.1}",
            r.name,
            r.iters,
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            r.throughput_per_s()
        );
    }
}

/// Human time formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 5, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.iters >= 3);
        assert!(r.median_ns > 0.0);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}

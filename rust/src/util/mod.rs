//! Cross-cutting substrates built from scratch for the offline environment:
//! PRNG, JSON, statistics, top-k selection, timing, logging.

pub mod bench;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod topk;

//! Minimal JSON substrate (parser + writer) — no `serde` available offline.
//!
//! Covers the full JSON grammar we exchange with the build side
//! (`artifacts/manifest.json`), experiment configs, and report files.
//! Numbers are kept as f64 (i64-exact integers round-trip losslessly for
//! the magnitudes we use: shapes, sizes, counts).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; errors with the full path on miss.
    pub fn at(&self, path: &[&str]) -> anyhow::Result<&Json> {
        let mut cur = self;
        for (i, k) in path.iter().enumerate() {
            cur = cur.get(k).ok_or_else(|| {
                anyhow::anyhow!("missing json key: {}", path[..=i].join("."))
            })?;
        }
        Ok(cur)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }

    /// Shape-style arrays: `[3, 3, 3, 16]` -> `Vec<usize>`.
    pub fn as_usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- constructors for report writing -----------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("bad surrogate pair"));
                            }
                            let lo = self.hex4()?;
                            let v = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo.wrapping_sub(0xDC00));
                            char::from_u32(v).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // re-decode utf8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        if self.pos > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- writer -----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2]
                .at(&["b"])
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "1.2.3", "\"unterminated", "[] []"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".to_string())
        );
        assert_eq!(
            Json::parse("\"é direct\"").unwrap(),
            Json::Str("é direct".to_string())
        );
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"shapes": [[3,3,3,16], []], "lr": 0.01, "name": "fwd", "ok": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Json::parse("[3, 3, 3, 16]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![3, 3, 3, 16]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
        assert!(Json::parse("[-1]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert_eq!(m.at(&["version"]).unwrap().as_usize().unwrap(), 1);
            assert_eq!(
                m.at(&["method_order"]).unwrap().as_arr().unwrap().len(),
                7
            );
        }
    }
}

//! Timing substrate: monotonic stopwatches and per-phase accounting.
//!
//! The trainer attributes every iteration's wall-clock to phases
//! (data / forward / score / select / update / eval) so the Fig-3 style
//! time accounting and the §Perf profiles come from one mechanism.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A simple restartable stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates durations per named phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    totals: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimer {
    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.totals.entry(phase).or_default() += d;
        *self.counts.entry(phase).or_default() += 1;
    }

    /// Time a closure and attribute it to `phase`.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn total_secs(&self, phase: &str) -> f64 {
        self.total(phase).as_secs_f64()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or_default()
    }

    pub fn grand_total_secs(&self) -> f64 {
        self.totals.values().map(|d| d.as_secs_f64()).sum()
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(&k, &v)| (k, v))
    }

    /// One-line human summary, phases sorted by share.
    pub fn summary(&self) -> String {
        let total = self.grand_total_secs().max(1e-12);
        let mut entries: Vec<_> = self.phases().collect();
        entries.sort_by(|a, b| b.1.cmp(&a.1));
        entries
            .iter()
            .map(|(k, d)| {
                format!("{k}={:.3}s ({:.0}%)", d.as_secs_f64(), 100.0 * d.as_secs_f64() / total)
            })
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn phase_accounting() {
        let mut pt = PhaseTimer::default();
        pt.add("fwd", Duration::from_millis(30));
        pt.add("fwd", Duration::from_millis(10));
        pt.add("update", Duration::from_millis(60));
        assert_eq!(pt.count("fwd"), 2);
        assert_eq!(pt.total("fwd"), Duration::from_millis(40));
        assert!((pt.grand_total_secs() - 0.1).abs() < 1e-9);
        let s = pt.summary();
        assert!(s.contains("update") && s.contains("fwd"), "{s}");
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimer::default();
        let v = pt.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(pt.count("x"), 1);
    }

    #[test]
    fn merge_adds() {
        let mut a = PhaseTimer::default();
        let mut b = PhaseTimer::default();
        a.add("p", Duration::from_millis(5));
        b.add("p", Duration::from_millis(7));
        a.merge(&b);
        assert_eq!(a.total("p"), Duration::from_millis(12));
        assert_eq!(a.count("p"), 2);
    }

    #[test]
    fn timed_phases_sum_to_wall_clock_within_tolerance() {
        // timing a sequence of exclusive phases must account for (almost)
        // all of the elapsed wall-clock — per-call overhead is the only
        // slack, and it is bounded
        let mut pt = PhaseTimer::default();
        let wall = Stopwatch::new();
        for _ in 0..5 {
            pt.time("a", || std::thread::sleep(Duration::from_millis(2)));
            pt.time("b", || std::thread::sleep(Duration::from_millis(1)));
        }
        let wall = wall.elapsed_secs();
        let accounted = pt.grand_total_secs();
        assert!(
            accounted <= wall,
            "phases cannot exceed the wall clock that contains them: \
             {accounted} > {wall}"
        );
        // 20ms of sleeps inside a loop: allow generous scheduler slack but
        // require the bulk of the time to land in the phases
        assert!(
            accounted >= 0.5 * (15.0 / 1000.0),
            "phases lost most of the wall clock: {accounted}s of {wall}s"
        );
        assert_eq!(pt.count("a"), 5);
        assert_eq!(pt.count("b"), 5);
    }

    #[test]
    fn stopwatch_restart_returns_lap_and_resets() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(3));
        let lap = sw.restart();
        assert!(lap >= Duration::from_millis(3), "lap too short: {lap:?}");
        // after the restart the elapsed clock starts over: it must read
        // less than the first lap took
        let after = sw.elapsed();
        assert!(after < lap, "restart must reset the origin: {after:?} vs {lap:?}");
        // and a second lap measures only its own interval
        std::thread::sleep(Duration::from_millis(1));
        let lap2 = sw.restart();
        assert!(lap2 >= Duration::from_millis(1) && lap2 < lap + Duration::from_millis(1000));
    }

    #[test]
    fn phases_iterate_in_stable_name_order() {
        // the profile is a BTreeMap: iteration order is lexicographic by
        // phase name regardless of insertion order, so emitted profiles
        // (CSV columns, trace JSON keys) are stable run to run
        let mut pt = PhaseTimer::default();
        for name in ["update", "data", "select", "forward", "eval"] {
            pt.add(name, Duration::from_millis(1));
        }
        let order: Vec<&str> = pt.phases().map(|(k, _)| k).collect();
        assert_eq!(order, vec!["data", "eval", "forward", "select", "update"]);
        // merging new phases keeps the invariant
        let mut other = PhaseTimer::default();
        other.add("cache", Duration::from_millis(1));
        pt.merge(&other);
        let order: Vec<&str> = pt.phases().map(|(k, _)| k).collect();
        assert_eq!(order, vec!["cache", "data", "eval", "forward", "select", "update"]);
    }
}

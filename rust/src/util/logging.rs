//! Leveled stderr logger implementing the `log` facade (no `env_logger`
//! offline). Level comes from `ADASELECTION_LOG` (error|warn|info|debug|trace),
//! default `info`. Messages carry elapsed wall-clock since init.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; later calls are no-ops).
pub fn init() {
    let _ = START.set(Instant::now());
    let level = std::env::var("ADASELECTION_LOG")
        .ok()
        .and_then(|s| parse_level(&s))
        .unwrap_or(LevelFilter::Info);
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke test");
    }
}

//! Leveled stderr logger implementing the `log` facade (no `env_logger`
//! offline). Level comes from `ADASELECTION_LOG` (error|warn|info|debug|trace),
//! default `info`. Messages carry elapsed wall-clock since init.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

/// The level names `ADASELECTION_LOG` accepts (case-insensitive).
const ACCEPTED: &str = "off|error|warn|info|debug|trace";

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl}] {}", record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; later calls are no-ops). An
/// unrecognized `ADASELECTION_LOG` value falls back to `info` and warns
/// once, naming the bad value — silent fallback used to hide typos like
/// `ADASELECTION_LOG=verbose`.
pub fn init() {
    let _ = START.set(Instant::now());
    let raw = std::env::var("ADASELECTION_LOG").ok();
    let (level, bad) = resolve_level(raw.as_deref());
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
        if let Some(msg) = bad {
            log::warn!("{msg}");
        }
    }
}

/// Map the env value to a level; an unparseable value yields the `info`
/// default plus the one-time warning text (testable without env races).
fn resolve_level(raw: Option<&str>) -> (LevelFilter, Option<String>) {
    match raw {
        None => (LevelFilter::Info, None),
        Some(s) => match parse_level(s) {
            Some(l) => (l, None),
            None => (
                LevelFilter::Info,
                Some(format!(
                    "ADASELECTION_LOG={s:?} is not a log level (accepted: {ACCEPTED}); \
                     using 'info'"
                )),
            ),
        },
    }
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s.to_ascii_lowercase().as_str() {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("debug"), Some(LevelFilter::Debug));
        assert_eq!(parse_level("WARN"), Some(LevelFilter::Warn));
        assert_eq!(parse_level("Off"), Some(LevelFilter::Off));
        assert_eq!(parse_level("bogus"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn resolve_unset_is_quiet_info() {
        assert_eq!(resolve_level(None), (LevelFilter::Info, None));
    }

    #[test]
    fn resolve_valid_is_quiet() {
        assert_eq!(resolve_level(Some("trace")), (LevelFilter::Trace, None));
        assert_eq!(resolve_level(Some("ERROR")), (LevelFilter::Error, None));
    }

    #[test]
    fn resolve_invalid_warns_naming_value_and_accepted_set() {
        let (level, warning) = resolve_level(Some("verbose"));
        assert_eq!(level, LevelFilter::Info);
        let msg = warning.expect("invalid value must produce a warning");
        assert!(msg.contains("verbose"), "warning must name the bad value: {msg}");
        assert!(msg.contains(ACCEPTED), "warning must list the accepted set: {msg}");
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke test");
    }
}

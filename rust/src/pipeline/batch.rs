//! Batch assembly: gather dataset rows into the flat, artifact-shaped
//! buffers the PJRT executables consume.

use crate::data::{Dataset, Task, XStore, YStore};

/// One assembled minibatch. Exactly one of `x_f32`/`x_i32` is populated
/// (matching the dataset), likewise for targets. When a batch is padded to
/// the artifact batch size, `real < indices.len()` and the tail repeats
/// row 0 — the eval mask / selection logic must ignore it.
#[derive(Clone, Debug)]
pub struct Batch {
    pub epoch: usize,
    pub index_in_epoch: usize,
    pub indices: Vec<usize>,
    pub real: usize,
    pub x_f32: Option<Vec<f32>>,
    pub x_i32: Option<Vec<i32>>,
    pub y_f32: Option<Vec<f32>>,
    pub y_i32: Option<Vec<i32>>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// 1.0 for real rows, 0.0 for padding (the eval artifact's mask input).
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0.0f32; self.len()];
        for v in m.iter_mut().take(self.real) {
            *v = 1.0;
        }
        m
    }

    /// Re-gather a sub-batch of this batch (selection step): `rows` are
    /// positions within this batch, output is a dense `rows.len()` batch.
    pub fn gather_rows(&self, rows: &[usize]) -> Batch {
        let take_f32 = |src: &Option<Vec<f32>>| {
            src.as_ref().map(|data| {
                let stride = data.len() / self.len();
                let mut out = Vec::with_capacity(rows.len() * stride);
                for &r in rows {
                    out.extend_from_slice(&data[r * stride..(r + 1) * stride]);
                }
                out
            })
        };
        let take_i32 = |src: &Option<Vec<i32>>| {
            src.as_ref().map(|data| {
                let stride = data.len() / self.len();
                let mut out = Vec::with_capacity(rows.len() * stride);
                for &r in rows {
                    out.extend_from_slice(&data[r * stride..(r + 1) * stride]);
                }
                out
            })
        };
        Batch {
            epoch: self.epoch,
            index_in_epoch: self.index_in_epoch,
            indices: rows.iter().map(|&r| self.indices[r]).collect(),
            real: rows.len(),
            x_f32: take_f32(&self.x_f32),
            x_i32: take_i32(&self.x_i32),
            y_f32: take_f32(&self.y_f32),
            y_i32: take_i32(&self.y_i32),
        }
    }

    /// An all-padding batch (`real == 0`) shaped like `ds`'s storage —
    /// what a stream producer yields for a tick with no owned arrivals
    /// (file-source gap, or a ring shard that owns none of the chunk).
    /// Consumers skip eval/forward/train on `real == 0`, so the zero
    /// payload is never read as data.
    pub fn empty_padded(ds: &Dataset, batch_size: usize, index_in_epoch: usize) -> Batch {
        let (x_f32, x_i32) = match &ds.x {
            XStore::F32 { stride, .. } => (Some(vec![0.0; batch_size * stride]), None),
            XStore::I32 { stride, .. } => (None, Some(vec![0; batch_size * stride])),
        };
        let (y_f32, y_i32) = match &ds.y {
            YStore::F32(_) => (Some(vec![0.0; batch_size]), None),
            YStore::I32(_) => (None, Some(vec![0; batch_size])),
            YStore::Seq { stride, .. } => (None, Some(vec![0; batch_size * stride])),
        };
        Batch {
            epoch: 0,
            index_in_epoch,
            indices: vec![0; batch_size],
            real: 0,
            x_f32,
            x_i32,
            y_f32,
            y_i32,
        }
    }

    /// Concatenate two *dense* batches (no padding on either side, same
    /// storage layout) — the replay scheduler joins the selected arrivals
    /// with replayed store rows before one train step.
    pub fn concat(&self, other: &Batch) -> Batch {
        debug_assert_eq!(self.real, self.len(), "concat on a padded batch");
        debug_assert_eq!(other.real, other.len(), "concat on a padded batch");
        fn join<T: Copy>(a: &Option<Vec<T>>, b: &Option<Vec<T>>) -> Option<Vec<T>> {
            match (a, b) {
                (Some(a), Some(b)) => {
                    let mut out = Vec::with_capacity(a.len() + b.len());
                    out.extend_from_slice(a);
                    out.extend_from_slice(b);
                    Some(out)
                }
                (None, None) => None,
                _ => panic!("Batch::concat: storage layout mismatch"),
            }
        }
        let mut indices = self.indices.clone();
        indices.extend_from_slice(&other.indices);
        Batch {
            epoch: self.epoch,
            index_in_epoch: self.index_in_epoch,
            real: indices.len(),
            indices,
            x_f32: join(&self.x_f32, &other.x_f32),
            x_i32: join(&self.x_i32, &other.x_i32),
            y_f32: join(&self.y_f32, &other.y_f32),
            y_i32: join(&self.y_i32, &other.y_i32),
        }
    }
}

/// Gather `indices` (padded to `batch_size` by repeating index 0) from the
/// dataset into flat buffers.
pub fn gather(
    ds: &Dataset,
    indices: &[usize],
    batch_size: usize,
    epoch: usize,
    index_in_epoch: usize,
) -> Batch {
    assert!(indices.len() <= batch_size);
    let real = indices.len();
    let mut padded: Vec<usize> = indices.to_vec();
    padded.resize(batch_size, *indices.first().unwrap_or(&0));

    let (x_f32, x_i32) = match &ds.x {
        XStore::F32 { data, stride } => {
            let mut out = Vec::with_capacity(batch_size * stride);
            for &i in &padded {
                out.extend_from_slice(&data[i * stride..(i + 1) * stride]);
            }
            (Some(out), None)
        }
        XStore::I32 { data, stride } => {
            let mut out = Vec::with_capacity(batch_size * stride);
            for &i in &padded {
                out.extend_from_slice(&data[i * stride..(i + 1) * stride]);
            }
            (None, Some(out))
        }
    };
    let (y_f32, y_i32) = match &ds.y {
        YStore::F32(v) => (Some(padded.iter().map(|&i| v[i]).collect()), None),
        YStore::I32(v) => (None, Some(padded.iter().map(|&i| v[i]).collect())),
        YStore::Seq { data, stride } => {
            let mut out = Vec::with_capacity(batch_size * stride);
            for &i in &padded {
                out.extend_from_slice(&data[i * stride..(i + 1) * stride]);
            }
            (None, Some(out))
        }
    };
    debug_assert!(matches!(
        (&ds.task, &x_f32, &x_i32),
        (Task::Classification { .. }, Some(_), None)
            | (Task::Regression, Some(_), None)
            | (Task::Lm { .. }, None, Some(_))
    ));
    Batch {
        epoch,
        index_in_epoch,
        indices: padded,
        real,
        x_f32,
        x_i32,
        y_f32,
        y_i32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, Task, XStore, YStore};

    fn toy_ds() -> Dataset {
        Dataset {
            name: "toy".into(),
            task: Task::Regression,
            feat_shape: vec![2],
            x: XStore::F32 {
                data: (0..20).map(|i| i as f32).collect(),
                stride: 2,
            },
            y: YStore::F32((0..10).map(|i| 100.0 + i as f32).collect()),
        }
    }

    #[test]
    fn gather_orders_and_pads() {
        let ds = toy_ds();
        let b = gather(&ds, &[3, 1], 4, 0, 0);
        assert_eq!(b.real, 2);
        assert_eq!(b.indices, vec![3, 1, 3, 3]);
        assert_eq!(
            b.x_f32.as_ref().unwrap(),
            &vec![6.0, 7.0, 2.0, 3.0, 6.0, 7.0, 6.0, 7.0]
        );
        assert_eq!(b.y_f32.as_ref().unwrap(), &vec![103.0, 101.0, 103.0, 103.0]);
        assert_eq!(b.mask(), vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn gather_rows_subsets() {
        let ds = toy_ds();
        let b = gather(&ds, &[0, 1, 2, 3], 4, 0, 0);
        let sub = b.gather_rows(&[2, 0]);
        assert_eq!(sub.real, 2);
        assert_eq!(sub.indices, vec![2, 0]);
        assert_eq!(sub.x_f32.as_ref().unwrap(), &vec![4.0, 5.0, 0.0, 1.0]);
        assert_eq!(sub.y_f32.as_ref().unwrap(), &vec![102.0, 100.0]);
    }

    #[test]
    fn empty_padded_matches_storage_shape() {
        let ds = toy_ds();
        let b = Batch::empty_padded(&ds, 4, 9);
        assert_eq!(b.real, 0);
        assert_eq!(b.len(), 4);
        assert_eq!(b.index_in_epoch, 9);
        assert_eq!(b.x_f32.as_ref().unwrap().len(), 8); // 4 rows x stride 2
        assert_eq!(b.y_f32.as_ref().unwrap().len(), 4);
        assert!(b.x_i32.is_none());
        assert_eq!(b.mask(), vec![0.0; 4]);
    }

    #[test]
    fn concat_joins_dense_batches() {
        let ds = toy_ds();
        let a = gather(&ds, &[0, 1], 2, 0, 0);
        let b = gather(&ds, &[4], 1, 0, 0);
        let j = a.concat(&b);
        assert_eq!(j.real, 3);
        assert_eq!(j.indices, vec![0, 1, 4]);
        assert_eq!(
            j.x_f32.as_ref().unwrap(),
            &vec![0.0, 1.0, 2.0, 3.0, 8.0, 9.0]
        );
        assert_eq!(j.y_f32.as_ref().unwrap(), &vec![100.0, 101.0, 104.0]);
        assert!(j.x_i32.is_none());
    }

    #[test]
    fn lm_batches_use_i32() {
        let ds = Dataset {
            name: "lm".into(),
            task: Task::Lm { vocab: 8, seq: 3 },
            feat_shape: vec![3],
            x: XStore::I32 {
                data: (0..12).map(|i| i % 8).collect(),
                stride: 3,
            },
            y: YStore::Seq {
                data: (1..13).map(|i| i % 8).collect(),
                stride: 3,
            },
        };
        let b = gather(&ds, &[1, 3], 2, 0, 0);
        assert!(b.x_f32.is_none());
        assert_eq!(b.x_i32.as_ref().unwrap(), &vec![3, 4, 5, 1, 2, 3]);
        assert_eq!(b.y_i32.as_ref().unwrap(), &vec![4, 5, 6, 2, 3, 4]);
    }
}

//! Streaming data pipeline (L3): deterministic shuffling, batch assembly,
//! multi-worker prefetch with bounded backpressure and order-restoring
//! dynamic rebalancing. See `loader.rs` for the concurrency design.

pub mod batch;
pub mod loader;

pub use batch::{gather, Batch};
pub use loader::{BatchProducer, Loader, LoaderConfig};

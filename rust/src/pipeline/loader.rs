//! Streaming batch loader: the L3 data-pipeline hot path.
//!
//! Worker threads materialize batches ahead of the trainer into a bounded
//! *reorder window*; the consumer always receives batches in the exact
//! deterministic order defined by the [`BatchProducer`], regardless of
//! worker count or scheduling. This gives:
//!
//!   * **prefetch** — production overlaps the trainer's backend executions;
//!   * **backpressure** — at most `capacity` batches are in flight, so a
//!     slow trainer never causes unbounded memory growth;
//!   * **dynamic rebalancing** — workers claim the next batch id from a
//!     shared counter (work stealing), so one slow worker cannot stall the
//!     stream while order is restored by the reorder window;
//!   * **reproducibility** — batch sequence depends only on the producer's
//!     pure `id → batch` function, never on thread timing.
//!
//! Two producers ride on the same machinery: the epoch-shuffled schedule
//! over an in-memory [`Dataset`] ([`Loader::start`], the batch trainer),
//! and the *unbounded* mode ([`Loader::from_producer`]) where the stream
//! trainer feeds an epochless chunk sequence — same reorder window, same
//! backpressure bound, no precomputed schedule.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::data::Dataset;
use crate::data::splits::EpochShuffler;

use super::batch::{gather, Batch};

/// A deterministic batch sequence: `produce(id)` must be a pure function
/// of `id` — workers call it concurrently and out of order, and the
/// reorder window restores sequence order for the consumer.
pub trait BatchProducer: Send + Sync + 'static {
    /// Number of batches in the sequence (`usize::MAX` = unbounded; the
    /// consumer then ends the stream by dropping the loader).
    fn total(&self) -> usize;

    /// Materialize batch `id` (0-based position in the sequence).
    fn produce(&self, id: usize) -> Batch;
}

/// Loader configuration.
#[derive(Clone, Debug)]
pub struct LoaderConfig {
    pub batch_size: usize,
    pub epochs: usize,
    pub seed: u64,
    /// worker threads; 0 = synchronous in-consumer gathering
    pub workers: usize,
    /// max batches buffered ahead of the consumer (backpressure bound)
    pub capacity: usize,
    /// drop the trailing partial batch (paper-style) or pad it
    pub drop_last: bool,
}

impl Default for LoaderConfig {
    fn default() -> Self {
        LoaderConfig {
            batch_size: 128,
            epochs: 1,
            seed: 0,
            workers: 2,
            capacity: 8,
            drop_last: true,
        }
    }
}

/// The precomputed batch schedule: for determinism the full index sequence
/// is derived up front from the seed.
struct Schedule {
    /// flattened (epoch, indices) per batch
    batches: Vec<(usize, usize, Vec<usize>)>,
    batch_size: usize,
}

fn build_schedule(n: usize, cfg: &LoaderConfig) -> Schedule {
    let mut shuffler = EpochShuffler::new(n, cfg.seed);
    let mut batches = Vec::new();
    for epoch in 0..cfg.epochs {
        let perm = shuffler.next_epoch();
        let mut index_in_epoch = 0;
        let mut start = 0;
        while start < n {
            let end = (start + cfg.batch_size).min(n);
            if end - start < cfg.batch_size && cfg.drop_last {
                break;
            }
            batches.push((epoch, index_in_epoch, perm[start..end].to_vec()));
            index_in_epoch += 1;
            start = end;
        }
    }
    Schedule {
        batches,
        batch_size: cfg.batch_size,
    }
}

/// The epoch-shuffled producer backing [`Loader::start`].
struct ScheduleProducer {
    schedule: Schedule,
    ds: Dataset,
}

impl BatchProducer for ScheduleProducer {
    fn total(&self) -> usize {
        self.schedule.batches.len()
    }

    fn produce(&self, id: usize) -> Batch {
        let (epoch, iie, idx) = &self.schedule.batches[id];
        gather(&self.ds, idx, self.schedule.batch_size, *epoch, *iie)
    }
}

struct Shared {
    ready: Mutex<HashMap<usize, Batch>>,
    cv: Condvar,
    next_claim: AtomicUsize,
    next_consume: AtomicUsize,
    capacity: usize,
    total: usize,
    /// most batches ever buffered at once (backpressure diagnostics)
    buffered_high: AtomicUsize,
}

/// A running loader; iterate with [`Loader::next_batch`].
pub struct Loader {
    producer: Arc<dyn BatchProducer>,
    shared: Option<Arc<Shared>>,
    workers: Vec<JoinHandle<()>>,
    cursor: usize,
    total: usize,
}

impl Loader {
    /// Start streaming `ds` under `cfg` (epoch-shuffled schedule).
    pub fn start(ds: Dataset, cfg: &LoaderConfig) -> Loader {
        let schedule = build_schedule(ds.len(), cfg);
        Loader::from_producer(
            Arc::new(ScheduleProducer { schedule, ds }),
            cfg.workers,
            cfg.capacity,
        )
    }

    /// Drive an arbitrary deterministic [`BatchProducer`] through the same
    /// prefetch/backpressure/reorder machinery. This is the unbounded mode
    /// the stream trainer uses: the producer's `total()` may be
    /// `usize::MAX`, in which case the consumer ends the stream by
    /// dropping the loader (workers parked on backpressure exit cleanly).
    pub fn from_producer(
        producer: Arc<dyn BatchProducer>,
        workers: usize,
        capacity: usize,
    ) -> Loader {
        let total = producer.total();
        if workers == 0 {
            return Loader {
                producer,
                shared: None,
                workers: Vec::new(),
                cursor: 0,
                total,
            };
        }

        let shared = Arc::new(Shared {
            ready: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            next_claim: AtomicUsize::new(0),
            next_consume: AtomicUsize::new(0),
            capacity: capacity.max(workers),
            total,
            buffered_high: AtomicUsize::new(0),
        });
        let mut handles = Vec::new();
        for w in 0..workers {
            let shared = shared.clone();
            let producer = producer.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("loader-{w}"))
                    .spawn(move || worker_loop(&*producer, &shared))
                    .expect("spawn loader worker"),
            );
        }
        Loader {
            producer,
            shared: Some(shared),
            workers: handles,
            cursor: 0,
            total,
        }
    }

    /// Total number of batches this loader will yield (`usize::MAX` for an
    /// unbounded producer).
    pub fn total_batches(&self) -> usize {
        self.total
    }

    /// Most batches ever buffered ahead of the consumer (0 for the
    /// synchronous path). Backpressure guarantees this never exceeds the
    /// effective capacity `max(capacity, workers)`.
    pub fn buffered_high_watermark(&self) -> usize {
        self.shared
            .as_ref()
            .map(|s| s.buffered_high.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Block (on the loader condvar — no polling) until `n` batches are
    /// buffered ahead of the consumer. `n` is clamped to what backpressure
    /// allows (`max(capacity, workers)`) and to the batches still unread,
    /// so the wait always terminates. Test/diagnostic hook for observing
    /// the backpressure window fill without sleep-loops.
    pub fn wait_until_buffered(&self, n: usize) {
        let Some(shared) = &self.shared else { return };
        let achievable = n.min(shared.capacity).min(self.total - self.cursor);
        let mut ready = shared.ready.lock().unwrap();
        while ready.len() < achievable {
            ready = shared.cv.wait(ready).unwrap();
        }
    }

    /// Next batch in deterministic order; `None` when the stream ends.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.cursor >= self.total {
            return None;
        }
        let id = self.cursor;
        self.cursor += 1;

        match &self.shared {
            None => {
                // synchronous path: produce in-consumer
                Some(self.producer.produce(id))
            }
            Some(shared) => {
                let mut ready = shared.ready.lock().unwrap();
                loop {
                    if let Some(b) = ready.remove(&id) {
                        shared.next_consume.store(id + 1, Ordering::SeqCst);
                        shared.cv.notify_all();
                        return Some(b);
                    }
                    ready = shared.cv.wait(ready).unwrap();
                }
            }
        }
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // unblock any workers parked on backpressure, then join
        if let Some(shared) = &self.shared {
            shared.next_consume.store(usize::MAX, Ordering::SeqCst);
            shared.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(producer: &dyn BatchProducer, shared: &Arc<Shared>) {
    loop {
        let id = shared.next_claim.fetch_add(1, Ordering::SeqCst);
        if id >= shared.total {
            return;
        }
        // backpressure: wait until id is within the window of the consumer
        {
            let mut ready = shared.ready.lock().unwrap();
            loop {
                let consume = shared.next_consume.load(Ordering::SeqCst);
                if consume == usize::MAX {
                    return; // loader dropped
                }
                if id < consume + shared.capacity {
                    break;
                }
                ready = shared.cv.wait(ready).unwrap();
            }
            drop(ready);
        }
        let batch = producer.produce(id);
        let mut ready = shared.ready.lock().unwrap();
        ready.insert(id, batch);
        shared.buffered_high.fetch_max(ready.len(), Ordering::SeqCst);
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Task, XStore, YStore};

    fn toy_ds(n: usize) -> Dataset {
        Dataset {
            name: "toy".into(),
            task: Task::Regression,
            feat_shape: vec![1],
            x: XStore::F32 {
                data: (0..n).map(|i| i as f32).collect(),
                stride: 1,
            },
            y: YStore::F32(vec![0.0; n]),
        }
    }

    fn drain(mut l: Loader) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = l.next_batch() {
            out.push(b);
        }
        out
    }

    #[test]
    fn covers_every_sample_once_per_epoch() {
        for workers in [0, 1, 3] {
            let cfg = LoaderConfig {
                batch_size: 16,
                epochs: 2,
                seed: 5,
                workers,
                capacity: 4,
                drop_last: false,
            };
            let batches = drain(Loader::start(toy_ds(50), &cfg));
            for epoch in 0..2 {
                let mut seen = vec![0usize; 50];
                for b in batches.iter().filter(|b| b.epoch == epoch) {
                    for &i in &b.indices[..b.real] {
                        seen[i] += 1;
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "workers={workers}");
            }
        }
    }

    #[test]
    fn deterministic_order_regardless_of_workers() {
        let mk = |workers| {
            let cfg = LoaderConfig {
                batch_size: 8,
                epochs: 3,
                seed: 9,
                workers,
                capacity: 3,
                drop_last: true,
            };
            drain(Loader::start(toy_ds(37), &cfg))
                .into_iter()
                .map(|b| b.indices)
                .collect::<Vec<_>>()
        };
        let a = mk(0);
        let b = mk(1);
        let c = mk(4);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn drop_last_drops_partial() {
        let cfg = LoaderConfig {
            batch_size: 16,
            epochs: 1,
            seed: 1,
            workers: 0,
            capacity: 2,
            drop_last: true,
        };
        let l = Loader::start(toy_ds(50), &cfg);
        assert_eq!(l.total_batches(), 3); // 50/16 = 3 full batches
        let batches = drain(l);
        assert!(batches.iter().all(|b| b.real == 16));
    }

    #[test]
    fn pad_last_when_not_dropping() {
        let cfg = LoaderConfig {
            batch_size: 16,
            epochs: 1,
            seed: 1,
            workers: 2,
            capacity: 2,
            drop_last: false,
        };
        let batches = drain(Loader::start(toy_ds(50), &cfg));
        assert_eq!(batches.len(), 4);
        let last = batches.last().unwrap();
        assert_eq!(last.real, 2);
        assert_eq!(last.len(), 16);
        assert_eq!(last.mask().iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let cfg = LoaderConfig {
            batch_size: 4,
            epochs: 10,
            seed: 2,
            workers: 3,
            capacity: 2,
            drop_last: true,
        };
        let mut l = Loader::start(toy_ds(100), &cfg);
        let _ = l.next_batch();
        drop(l); // workers blocked on backpressure must exit cleanly
    }

    /// Unbounded synthetic producer: batch `id` carries `id` in
    /// `index_in_epoch` and a payload derived from it, so sequence order
    /// and content are both checkable.
    struct Endless;

    impl BatchProducer for Endless {
        fn total(&self) -> usize {
            usize::MAX
        }

        fn produce(&self, id: usize) -> Batch {
            Batch {
                epoch: 0,
                index_in_epoch: id,
                indices: vec![id * 3, id * 3 + 1],
                real: 2,
                x_f32: Some(vec![id as f32, id as f32 + 0.5]),
                x_i32: None,
                y_f32: Some(vec![0.0, 1.0]),
                y_i32: None,
            }
        }
    }

    #[test]
    fn unbounded_mode_is_deterministic_across_worker_counts() {
        let take = |workers: usize, n: usize| -> Vec<(usize, Vec<usize>)> {
            let mut l = Loader::from_producer(Arc::new(Endless), workers, 3);
            let mut out = Vec::new();
            for _ in 0..n {
                let b = l.next_batch().unwrap();
                out.push((b.index_in_epoch, b.indices));
            }
            out
        };
        let a = take(0, 40);
        let b = take(1, 40);
        let c = take(4, 40);
        assert_eq!(a, b);
        assert_eq!(a, c);
        // the batch id sequence is exactly 0..40 in order
        for (i, (id, idx)) in a.iter().enumerate() {
            assert_eq!(*id, i);
            assert_eq!(idx, &vec![i * 3, i * 3 + 1]);
        }
    }

    #[test]
    fn unbounded_mode_honors_backpressure_bound() {
        let mut l = Loader::from_producer(Arc::new(Endless), 4, 2);
        // effective window = max(capacity, workers) = 4
        l.wait_until_buffered(4);
        assert!(l.buffered_high_watermark() >= 4);
        for _ in 0..100 {
            let _ = l.next_batch().unwrap();
        }
        assert!(
            l.buffered_high_watermark() <= 4,
            "buffer exceeded backpressure bound: {}",
            l.buffered_high_watermark()
        );
    }

    #[test]
    fn unbounded_mode_sheds_workers_on_consumer_drop() {
        // consumer walks away mid-stream: workers parked on backpressure
        // must exit cleanly (the test completing at all is the assertion —
        // Drop joins every worker)
        for consumed in [0usize, 1, 7] {
            let mut l = Loader::from_producer(Arc::new(Endless), 3, 2);
            for _ in 0..consumed {
                let _ = l.next_batch().unwrap();
            }
            drop(l);
        }
    }

    #[test]
    fn backpressure_bounds_buffer() {
        // capacity 4 (= workers): park the consumer until the window is
        // full — condvar-driven, no sleep-polling — then drain and check
        // that the buffer never grew past the backpressure bound.
        let cfg = LoaderConfig {
            batch_size: 4,
            epochs: 1,
            seed: 3,
            workers: 4,
            capacity: 2, // effective window = max(capacity, workers) = 4
            drop_last: true,
        };
        let mut l = Loader::start(toy_ds(64), &cfg);
        l.wait_until_buffered(4);
        assert!(l.buffered_high_watermark() >= 4);
        let mut count = 0;
        while let Some(b) = l.next_batch() {
            assert_eq!(b.index_in_epoch, count);
            count += 1;
        }
        assert_eq!(count, 16);
        assert!(
            l.buffered_high_watermark() <= 4,
            "buffer exceeded backpressure bound: {}",
            l.buffered_high_watermark()
        );
    }
}

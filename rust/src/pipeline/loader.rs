//! Streaming batch loader: the L3 data-pipeline hot path.
//!
//! Worker threads gather batches ahead of the trainer into a bounded
//! *reorder window*; the consumer always receives batches in the exact
//! deterministic order defined by the seeded per-epoch shuffle, regardless
//! of worker count or scheduling. This gives:
//!
//!   * **prefetch** — gathering overlaps the trainer's XLA executions;
//!   * **backpressure** — at most `capacity` batches are in flight, so a
//!     slow trainer never causes unbounded memory growth;
//!   * **dynamic rebalancing** — workers claim the next batch id from a
//!     shared counter (work stealing), so one slow worker cannot stall the
//!     stream while order is restored by the reorder window;
//!   * **reproducibility** — batch sequence depends only on (seed, epochs,
//!     batch size), never on thread timing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::data::Dataset;
use crate::data::splits::EpochShuffler;

use super::batch::{gather, Batch};

/// Loader configuration.
#[derive(Clone, Debug)]
pub struct LoaderConfig {
    pub batch_size: usize,
    pub epochs: usize,
    pub seed: u64,
    /// worker threads; 0 = synchronous in-consumer gathering
    pub workers: usize,
    /// max batches buffered ahead of the consumer (backpressure bound)
    pub capacity: usize,
    /// drop the trailing partial batch (paper-style) or pad it
    pub drop_last: bool,
}

impl Default for LoaderConfig {
    fn default() -> Self {
        LoaderConfig {
            batch_size: 128,
            epochs: 1,
            seed: 0,
            workers: 2,
            capacity: 8,
            drop_last: true,
        }
    }
}

/// The precomputed batch schedule: for determinism the full index sequence
/// is derived up front from the seed.
struct Schedule {
    /// flattened (epoch, indices) per batch
    batches: Vec<(usize, usize, Vec<usize>)>,
    batch_size: usize,
}

fn build_schedule(n: usize, cfg: &LoaderConfig) -> Schedule {
    let mut shuffler = EpochShuffler::new(n, cfg.seed);
    let mut batches = Vec::new();
    for epoch in 0..cfg.epochs {
        let perm = shuffler.next_epoch();
        let mut index_in_epoch = 0;
        let mut start = 0;
        while start < n {
            let end = (start + cfg.batch_size).min(n);
            if end - start < cfg.batch_size && cfg.drop_last {
                break;
            }
            batches.push((epoch, index_in_epoch, perm[start..end].to_vec()));
            index_in_epoch += 1;
            start = end;
        }
    }
    Schedule {
        batches,
        batch_size: cfg.batch_size,
    }
}

struct Shared {
    ready: Mutex<HashMap<usize, Batch>>,
    cv: Condvar,
    next_claim: AtomicUsize,
    next_consume: AtomicUsize,
    capacity: usize,
    total: usize,
    /// most batches ever buffered at once (backpressure diagnostics)
    buffered_high: AtomicUsize,
}

/// A running loader; iterate with [`Loader::next_batch`].
pub struct Loader {
    schedule: Option<Arc<(Schedule, Dataset)>>,
    shared: Option<Arc<Shared>>,
    workers: Vec<JoinHandle<()>>,
    cursor: usize,
    total: usize,
}

impl Loader {
    /// Start streaming `ds` under `cfg`.
    pub fn start(ds: Dataset, cfg: &LoaderConfig) -> Loader {
        let schedule = build_schedule(ds.len(), cfg);
        let total = schedule.batches.len();
        let pack = Arc::new((schedule, ds));

        if cfg.workers == 0 {
            return Loader {
                schedule: Some(pack),
                shared: None,
                workers: Vec::new(),
                cursor: 0,
                total,
            };
        }

        let shared = Arc::new(Shared {
            ready: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            next_claim: AtomicUsize::new(0),
            next_consume: AtomicUsize::new(0),
            capacity: cfg.capacity.max(cfg.workers),
            total,
            buffered_high: AtomicUsize::new(0),
        });
        let mut workers = Vec::new();
        for w in 0..cfg.workers {
            let shared = shared.clone();
            let pack = pack.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("loader-{w}"))
                    .spawn(move || worker_loop(&pack, &shared))
                    .expect("spawn loader worker"),
            );
        }
        Loader {
            schedule: Some(pack),
            shared: Some(shared),
            workers,
            cursor: 0,
            total,
        }
    }

    /// Total number of batches this loader will yield.
    pub fn total_batches(&self) -> usize {
        self.total
    }

    /// Most batches ever buffered ahead of the consumer (0 for the
    /// synchronous path). Backpressure guarantees this never exceeds the
    /// effective capacity `max(capacity, workers)`.
    pub fn buffered_high_watermark(&self) -> usize {
        self.shared
            .as_ref()
            .map(|s| s.buffered_high.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Block (on the loader condvar — no polling) until `n` batches are
    /// buffered ahead of the consumer. `n` is clamped to what backpressure
    /// allows (`max(capacity, workers)`) and to the batches still unread,
    /// so the wait always terminates. Test/diagnostic hook for observing
    /// the backpressure window fill without sleep-loops.
    pub fn wait_until_buffered(&self, n: usize) {
        let Some(shared) = &self.shared else { return };
        let achievable = n.min(shared.capacity).min(self.total - self.cursor);
        let mut ready = shared.ready.lock().unwrap();
        while ready.len() < achievable {
            ready = shared.cv.wait(ready).unwrap();
        }
    }

    /// Next batch in deterministic order; `None` when the stream ends.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.cursor >= self.total {
            return None;
        }
        let id = self.cursor;
        self.cursor += 1;

        match &self.shared {
            None => {
                // synchronous path
                let pack = self.schedule.as_ref().unwrap();
                let (sched, ds) = (&pack.0, &pack.1);
                let (epoch, iie, idx) = &sched.batches[id];
                Some(gather(ds, idx, sched.batch_size, *epoch, *iie))
            }
            Some(shared) => {
                let mut ready = shared.ready.lock().unwrap();
                loop {
                    if let Some(b) = ready.remove(&id) {
                        shared.next_consume.store(id + 1, Ordering::SeqCst);
                        shared.cv.notify_all();
                        return Some(b);
                    }
                    ready = shared.cv.wait(ready).unwrap();
                }
            }
        }
    }
}

impl Drop for Loader {
    fn drop(&mut self) {
        // unblock any workers parked on backpressure, then join
        if let Some(shared) = &self.shared {
            shared.next_consume.store(usize::MAX, Ordering::SeqCst);
            shared.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(pack: &Arc<(Schedule, Dataset)>, shared: &Arc<Shared>) {
    let (sched, ds) = (&pack.0, &pack.1);
    loop {
        let id = shared.next_claim.fetch_add(1, Ordering::SeqCst);
        if id >= shared.total {
            return;
        }
        // backpressure: wait until id is within the window of the consumer
        {
            let mut ready = shared.ready.lock().unwrap();
            loop {
                let consume = shared.next_consume.load(Ordering::SeqCst);
                if consume == usize::MAX {
                    return; // loader dropped
                }
                if id < consume + shared.capacity {
                    break;
                }
                ready = shared.cv.wait(ready).unwrap();
            }
            drop(ready);
        }
        let (epoch, iie, idx) = &sched.batches[id];
        let batch = gather(ds, idx, sched.batch_size, *epoch, *iie);
        let mut ready = shared.ready.lock().unwrap();
        ready.insert(id, batch);
        shared.buffered_high.fetch_max(ready.len(), Ordering::SeqCst);
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Task, XStore, YStore};

    fn toy_ds(n: usize) -> Dataset {
        Dataset {
            name: "toy".into(),
            task: Task::Regression,
            feat_shape: vec![1],
            x: XStore::F32 {
                data: (0..n).map(|i| i as f32).collect(),
                stride: 1,
            },
            y: YStore::F32(vec![0.0; n]),
        }
    }

    fn drain(mut l: Loader) -> Vec<Batch> {
        let mut out = Vec::new();
        while let Some(b) = l.next_batch() {
            out.push(b);
        }
        out
    }

    #[test]
    fn covers_every_sample_once_per_epoch() {
        for workers in [0, 1, 3] {
            let cfg = LoaderConfig {
                batch_size: 16,
                epochs: 2,
                seed: 5,
                workers,
                capacity: 4,
                drop_last: false,
            };
            let batches = drain(Loader::start(toy_ds(50), &cfg));
            for epoch in 0..2 {
                let mut seen = vec![0usize; 50];
                for b in batches.iter().filter(|b| b.epoch == epoch) {
                    for &i in &b.indices[..b.real] {
                        seen[i] += 1;
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "workers={workers}");
            }
        }
    }

    #[test]
    fn deterministic_order_regardless_of_workers() {
        let mk = |workers| {
            let cfg = LoaderConfig {
                batch_size: 8,
                epochs: 3,
                seed: 9,
                workers,
                capacity: 3,
                drop_last: true,
            };
            drain(Loader::start(toy_ds(37), &cfg))
                .into_iter()
                .map(|b| b.indices)
                .collect::<Vec<_>>()
        };
        let a = mk(0);
        let b = mk(1);
        let c = mk(4);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn drop_last_drops_partial() {
        let cfg = LoaderConfig {
            batch_size: 16,
            epochs: 1,
            seed: 1,
            workers: 0,
            capacity: 2,
            drop_last: true,
        };
        let l = Loader::start(toy_ds(50), &cfg);
        assert_eq!(l.total_batches(), 3); // 50/16 = 3 full batches
        let batches = drain(l);
        assert!(batches.iter().all(|b| b.real == 16));
    }

    #[test]
    fn pad_last_when_not_dropping() {
        let cfg = LoaderConfig {
            batch_size: 16,
            epochs: 1,
            seed: 1,
            workers: 2,
            capacity: 2,
            drop_last: false,
        };
        let batches = drain(Loader::start(toy_ds(50), &cfg));
        assert_eq!(batches.len(), 4);
        let last = batches.last().unwrap();
        assert_eq!(last.real, 2);
        assert_eq!(last.len(), 16);
        assert_eq!(last.mask().iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn early_drop_does_not_hang() {
        let cfg = LoaderConfig {
            batch_size: 4,
            epochs: 10,
            seed: 2,
            workers: 3,
            capacity: 2,
            drop_last: true,
        };
        let mut l = Loader::start(toy_ds(100), &cfg);
        let _ = l.next_batch();
        drop(l); // workers blocked on backpressure must exit cleanly
    }

    #[test]
    fn backpressure_bounds_buffer() {
        // capacity 4 (= workers): park the consumer until the window is
        // full — condvar-driven, no sleep-polling — then drain and check
        // that the buffer never grew past the backpressure bound.
        let cfg = LoaderConfig {
            batch_size: 4,
            epochs: 1,
            seed: 3,
            workers: 4,
            capacity: 2, // effective window = max(capacity, workers) = 4
            drop_last: true,
        };
        let mut l = Loader::start(toy_ds(64), &cfg);
        l.wait_until_buffered(4);
        assert!(l.buffered_high_watermark() >= 4);
        let mut count = 0;
        while let Some(b) = l.next_batch() {
            assert_eq!(b.index_in_epoch, count);
            count += 1;
        }
        assert_eq!(count, 16);
        assert!(
            l.buffered_high_watermark() <= 4,
            "buffer exceeded backpressure bound: {}",
            l.buffered_high_watermark()
        );
    }
}

//! From-scratch CLI argument parser (no `clap` offline).
//!
//! Grammar: `adaselection <command> [positionals...] [--flag [value]]...`
//! Boolean flags may omit the value; `--key=value` is also accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from raw argv (without the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> anyhow::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let mut out = Args {
            command: it.next().unwrap_or_else(|| "help".to_string()),
            ..Args::default()
        };
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                anyhow::ensure!(!flag.is_empty(), "bare '--' not supported");
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value is next token unless it looks like a flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(flag.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(flag.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// The help text for the binary.
pub const USAGE: &str = "adaselection — AdaSelection training coordinator

USAGE:
  adaselection <command> [options]

COMMANDS:
  train               run one training job
                      --backend native|xla --dataset D --selector S
                      (--method is an alias for --selector)
                      --gamma G --epochs N --lr X
                      --beta B --cl on|off --cl-power P --seed N
                      --data-scale F --workers N --accumulate on|off
                      --kernel-scorer on|off --config FILE --out DIR
  stream              continuous training on an unbounded sample stream
                      --dataset drift-class|drift-reg|drift-lm|file:PATH|tcp:ADDR
                      --selector S (alias --method) --gamma G --max-ticks N
                      --obftf-k K (candidate multiplier for obftf) --lr X
                      --drift-period N --burst-period N --burst-min F
                      --store-capacity N --store-shards N
                      --window N --eval-every N --workers N
                      --drift-detect off|page-hinkley|adwin --replay on|off
                      --checkpoint FILE [--checkpoint-every N] [--resume]
                      --trace FILE (per-tick JSONL trace journal; a crash
                      flight-recorder dump of the journal tail lands next
                      to it as FILE.flight.jsonl on panic/SIGTERM)
                      --status-addr HOST:PORT (/metrics + /status +
                      /profile endpoints)
                      --health off|warn|strict (fleet health rules: warn
                      journals alert events, strict also exits nonzero if
                      any alert is still firing at the end of the run)
                      --config FILE --out DIR
  cluster             multi-node sharded streaming training
                      --nodes N --vnodes N --gossip-every N --merge-every N
                      --workers threads|processes (or N for pipeline workers)
                      --transport loopback|tcp --gossip full|delta
                      [--full-gossip-every K]
                      [--kill-at T --kill-node I] [--join-at T]
                      [--chaos-kill-at T --chaos-kill-node I] (processes)
                      [--chaos-straggler-ms MS --chaos-straggler-node I]
                      (node I sleeps MS per barrier — a synthetic straggler
                      for health-rule testing; processes)
                      [--listen HOST:PORT] (accept remote worker
                      registrations; processes)
                      [--spawn on|off] (off: spawn nothing, wait for N
                      external workers to register on --listen)
                      [--elastic-admit-above R --elastic-shed-below R]
                      [--elastic-min-nodes N --elastic-max-nodes N]
                      (arrival-rate watermarks, samples/tick: admit a
                      registered standby above R, shed the worst straggler
                      below R; processes)
                      plus all stream options (--trace writes PATH.node<i>
                      per process worker; --health evaluates fleet rules
                      at every barrier — stragglers, stale heartbeats,
                      store pressure, arrival stalls); native backend only
  worker              one cluster worker process: spawned by `cluster
                      --workers processes`, or started by hand on any
                      machine to register with a listening coordinator
                      --coordinator HOST:PORT [--node-id N]
                      (no --node-id: the coordinator assigns one; extra
                      workers wait as elastic standbys)
  sweep               reproduce a paper experiment
                      --exp fig1|...|fig9|table3|table4|stream-cmp|all
                      --out DIR [--backend native|xla --epochs N
                      --data-scale F --seed N --quick]
  list-experiments    print the experiment registry (paper figure/table map)
  inspect-artifacts   print the artifact manifest summary (xla backend)
  gen-data            generate + describe a dataset
                      --dataset D [--data-scale F --seed N]
  bench-diff          compare two directories of BENCH_*.json summaries
                      --baseline DIR --current DIR [--tolerance 0.15]
                      exits nonzero when any matching benchmark's median
                      regresses past the tolerance, naming the worst
                      regressed kernel/phase (CI perf gate)
  trace-analyze       offline profiler over trace journals (schema v1–v3)
                      trace-analyze JOURNAL [JOURNAL...] [--out FILE]
                      merges coordinator + PATH.node<i> journals by
                      (round, node); reports per-arm selection efficiency,
                      the barrier critical path + straggler table, gossip
                      vs merge bandwidth, the drift/γ timeline, the health
                      alert timeline, and per-kernel p50/p95/p99 as
                      canonical sorted-key JSON (byte-identical for
                      identical inputs); summary table on stderr
  help                this text

Selector ids: benchmark, uniform, big_loss, small_loss, grad_norm, adaboost,
coreset1, coreset2, obftf, selective-backprop, adaselection, or
adaselection:<id>+<id>+... to pick the bandit arm pool. `obftf` and
`selective-backprop` are forward-cheap: they forward-score candidates and
run the backward pass only on the selected rows.

The default backend is `native` (pure Rust, no artifacts needed). The xla
backend executes the HLO artifacts from `make artifacts` and requires
building with `--features xla`.

All training options can also come from --config FILE (JSON) with CLI flags
taking precedence. Artifacts default to ./artifacts ($ADASELECTION_ARTIFACTS).
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_flags_positionals() {
        let a = parse("train --dataset cifar10 --gamma 0.2 pos1 --verbose");
        assert_eq!(a.command, "train");
        assert_eq!(a.flag("dataset"), Some("cifar10"));
        assert_eq!(a.flag("gamma"), Some("0.2"));
        assert_eq!(a.positionals, vec!["pos1"]);
        assert_eq!(a.flag("verbose"), Some("true"));
    }

    #[test]
    fn equals_form() {
        let a = parse("sweep --exp=fig3 --out=/tmp/x");
        assert_eq!(a.flag("exp"), Some("fig3"));
        assert_eq!(a.flag("out"), Some("/tmp/x"));
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse("train --accumulate");
        assert_eq!(a.flag("accumulate"), Some("true"));
    }

    #[test]
    fn negative_numbers_are_values() {
        // "--cl-power -0.5": '-0.5' does not start with '--', so it's a value
        let a = parse("train --cl-power -0.5");
        assert_eq!(a.flag("cl-power"), Some("-0.5"));
    }
}

//! The [`Backend`] abstraction: everything the trainer needs from a compute
//! runtime, with two implementations —
//!
//!   * [`crate::runtime::NativeBackend`] — pure-Rust ports of the L1
//!     reference kernels (`python/compile/kernels/ref.py`); zero native
//!     dependencies, runs anywhere, any subset size;
//!   * [`crate::runtime::Engine`] (behind `--features xla`) — the PJRT/XLA
//!     engine executing the Pallas-backed HLO artifacts.
//!
//! The trainer, harness and benches are generic over `B: Backend`, so every
//! selection policy, figure sweep and perf experiment runs identically on
//! both; CI exercises the native path on bare runners.

use crate::pipeline::Batch;

/// Task type of a model family (mirrors `data::Task` without payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Classification,
    Regression,
    Lm,
}

/// A plain host tensor: row-major f32 data plus its shape.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }
}

/// Batch geometry + task of one model family, backend-agnostic.
#[derive(Clone, Debug)]
pub struct FamilyMeta {
    pub name: String,
    pub task: TaskKind,
    /// full (selection) batch size B
    pub batch: usize,
    /// train-step subset sizes the backend supports; `None` = any size
    /// (the native backend has no compiled-shape constraint)
    pub sizes: Option<Vec<usize>>,
}

/// Smallest size in `sizes` that is ≥ k (fallback: the largest; `k` itself
/// when `sizes` is empty). The single owner of the size-rounding rule —
/// both the manifest view and [`FamilyMeta`] delegate here.
pub fn round_up_size(sizes: &[usize], k: usize) -> usize {
    sizes
        .iter()
        .copied()
        .find(|&s| s >= k)
        .or_else(|| sizes.last().copied())
        .unwrap_or(k)
}

impl FamilyMeta {
    /// Smallest supported train size ≥ k (exact k when unconstrained).
    pub fn round_size(&self, k: usize) -> usize {
        match &self.sizes {
            None => k,
            Some(sizes) => round_up_size(sizes, k),
        }
    }
}

/// Output of a fused forward + AdaSelection-score pass.
#[derive(Clone, Debug)]
pub struct FusedForward {
    pub loss: Vec<f32>,
    pub gnorm: Vec<f32>,
    pub scores: Vec<f32>,
    /// full 7-row α matrix, `Method::ALL` order
    pub alphas: Vec<Vec<f32>>,
}

/// A compute runtime the trainer can drive end to end.
///
/// `State` holds model parameters + optimizer state in whatever format is
/// fastest for the backend (host literals for PJRT, plain tensors natively),
/// so neither path pays conversion costs on the hot loop.
pub trait Backend {
    /// Model parameters + optimizer state, backend-native format.
    type State;

    /// Short identifier used in logs/reports ("native", "xla").
    fn name(&self) -> &'static str;

    /// Batch geometry + task for a model family.
    fn family_meta(&self, family: &str) -> anyhow::Result<FamilyMeta>;

    /// Fresh parameters + zero momentum, deterministic in `seed`.
    fn init_state(&mut self, family: &str, seed: i32) -> anyhow::Result<Self::State>;

    /// Selection forward pass: per-sample (loss, gnorm proxy) over a full
    /// batch (padded rows included; callers slice by `batch.real`).
    fn forward_scores(
        &mut self,
        state: &Self::State,
        batch: &Batch,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)>;

    /// Fused forward + L1 scorer in one call, when the backend supports it
    /// (`Ok(None)` = not available, caller falls back to
    /// [`Backend::forward_scores`] + [`Backend::score`]).
    fn forward_score_fused(
        &mut self,
        state: &Self::State,
        batch: &Batch,
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<Option<FusedForward>>;

    /// One SGD+momentum step on a sub-batch; updates `state` in place and
    /// returns the mean loss over the sub-batch.
    fn train_step(
        &mut self,
        state: &mut Self::State,
        sub: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32>;

    /// Masked eval pass: (loss_sum, correct_sum) over one padded batch.
    fn eval(&mut self, state: &Self::State, batch: &Batch) -> anyhow::Result<(f32, f32)>;

    /// Standalone AdaSelection scorer on already-computed (loss, gnorm):
    /// returns (fused scores, full 7-row α matrix).
    fn score(
        &mut self,
        loss: &[f32],
        gnorm: &[f32],
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)>;

    /// Warm anything expensive (artifact compilation) before the timed
    /// training loop. No-op for backends without a compile step.
    fn preload_family(&mut self, _family: &str, _sizes: &[usize]) -> anyhow::Result<()> {
        Ok(())
    }

    /// Number of f32 parameters in a family (reporting).
    fn param_count(&self, family: &str) -> anyhow::Result<usize>;

    /// Serialize model + optimizer state to plain host tensors for
    /// checkpointing (stream trainer resume). Backends without host-visible
    /// state may leave the default unsupported error.
    fn export_state(&self, _state: &Self::State) -> anyhow::Result<Vec<Tensor>> {
        anyhow::bail!("backend '{}' does not support state export", self.name())
    }

    /// Rebuild a `State` from tensors produced by [`Backend::export_state`].
    fn import_state(&mut self, _family: &str, _tensors: &[Tensor]) -> anyhow::Result<Self::State> {
        anyhow::bail!("backend '{}' does not support state import", self.name())
    }

    /// Backend self-checks run once per training job (e.g. the engine's
    /// frozen method-order validation against the artifact manifest).
    fn validate(&self) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Forward-score a candidate subset (`rows` = batch positions) of `batch`:
/// gather the rows into a dense sub-batch, run [`Backend::forward_scores`]
/// over it, and return per-candidate (loss, gnorm) aligned with `rows`.
/// The phase-1 scoring entry point for candidate-superset policies (OBFTF)
/// — the forward pass covers only the planned candidates, and the backward
/// pass later sees only the finally-selected rows.
pub fn forward_scores_rows<B: Backend>(
    backend: &mut B,
    state: &B::State,
    batch: &Batch,
    rows: &[usize],
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    let sub = batch.gather_rows(rows);
    let (loss, gnorm) = backend.forward_scores(state, &sub)?;
    anyhow::ensure!(
        loss.len() >= rows.len() && gnorm.len() >= rows.len(),
        "forward_scores returned {} rows for a {}-row candidate batch",
        loss.len(),
        rows.len()
    );
    Ok((loss[..rows.len()].to_vec(), gnorm[..rows.len()].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_size_unconstrained_is_identity() {
        let meta = FamilyMeta {
            name: "f".into(),
            task: TaskKind::Regression,
            batch: 100,
            sizes: None,
        };
        assert_eq!(meta.round_size(17), 17);
        assert_eq!(meta.round_size(1), 1);
    }

    #[test]
    fn round_size_constrained_rounds_up() {
        let meta = FamilyMeta {
            name: "f".into(),
            task: TaskKind::Classification,
            batch: 128,
            sizes: Some(vec![13, 26, 39, 52, 64, 128]),
        };
        assert_eq!(meta.round_size(13), 13);
        assert_eq!(meta.round_size(14), 26);
        assert_eq!(meta.round_size(999), 128);
    }

    #[test]
    fn tensor_zeros_shape_product() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.elems(), 12);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }
}

//! Typed view of `artifacts/manifest.json` — the contract between the
//! python build side (aot.py) and this runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

pub use super::backend::TaskKind;

/// Element dtype of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unknown dtype '{other}'"),
        }
    }
}

/// One positional input/output of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled HLO module on disk.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// A model family: spec + artifact names.
#[derive(Clone, Debug)]
pub struct FamilyInfo {
    pub name: String,
    pub task: TaskKind,
    pub batch: usize,
    pub train_sizes: Vec<usize>,
    /// ordered parameter list (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub init: String,
    pub fwd: String,
    /// fused forward+scorer artifact (optional; newer manifests)
    pub fwd_score: Option<String>,
    pub eval: String,
    /// subset size K -> train artifact name
    pub train: BTreeMap<usize, String>,
}

impl FamilyInfo {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// The train artifact for subset size k (exact match required — the
    /// caller rounds k to a compiled size via [`FamilyInfo::round_size`]).
    pub fn train_artifact(&self, k: usize) -> anyhow::Result<&str> {
        self.train
            .get(&k)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("no train artifact for k={k} in {}", self.name))
    }

    /// Smallest compiled subset size ≥ k (fallback: the largest).
    pub fn round_size(&self, k: usize) -> usize {
        super::backend::round_up_size(&self.train_sizes, k)
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub method_order: Vec<String>,
    pub momentum: f64,
    pub gamma_grid: Vec<f64>,
    pub families: BTreeMap<String, FamilyInfo>,
    /// batch size -> score artifact name
    pub score: BTreeMap<usize, String>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        anyhow::ensure!(
            j.at(&["version"])?.as_usize()? == 1,
            "unsupported manifest version"
        );
        let method_order: Vec<String> = j
            .at(&["method_order"])?
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(String::from))
            .collect::<anyhow::Result<_>>()?;
        let momentum = j.at(&["momentum"])?.as_f64()?;
        let gamma_grid: Vec<f64> = j
            .at(&["gamma_grid"])?
            .as_arr()?
            .iter()
            .map(|v| v.as_f64())
            .collect::<anyhow::Result<_>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.at(&["artifacts"])?.as_obj()? {
            let parse_io = |key: &str| -> anyhow::Result<Vec<IoSpec>> {
                a.at(&[key])?
                    .as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io.at(&["name"])?.as_str()?.to_string(),
                            shape: io.at(&["shape"])?.as_usize_vec()?,
                            dtype: Dtype::parse(io.at(&["dtype"])?.as_str()?)?,
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(a.at(&["file"])?.as_str()?),
                    inputs: parse_io("inputs")?,
                    outputs: parse_io("outputs")?,
                },
            );
        }

        let mut families = BTreeMap::new();
        for (name, fj) in j.at(&["families"])?.as_obj()? {
            let task = match fj.at(&["task"])?.as_str()? {
                "classification" => TaskKind::Classification,
                "regression" => TaskKind::Regression,
                "lm" => TaskKind::Lm,
                other => anyhow::bail!("unknown task '{other}'"),
            };
            let params = fj
                .at(&["params"])?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok((
                        p.at(&["name"])?.as_str()?.to_string(),
                        p.at(&["shape"])?.as_usize_vec()?,
                    ))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let mut train = BTreeMap::new();
            for (k, v) in fj.at(&["artifacts", "train"])?.as_obj()? {
                train.insert(k.parse::<usize>()?, v.as_str()?.to_string());
            }
            let fam = FamilyInfo {
                name: name.clone(),
                task,
                batch: fj.at(&["batch"])?.as_usize()?,
                train_sizes: fj.at(&["train_sizes"])?.as_usize_vec()?,
                params,
                init: fj.at(&["artifacts", "init"])?.as_str()?.to_string(),
                fwd: fj.at(&["artifacts", "fwd"])?.as_str()?.to_string(),
                fwd_score: fj
                    .at(&["artifacts"])?
                    .get("fwd_score")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?,
                eval: fj.at(&["artifacts", "eval"])?.as_str()?.to_string(),
                train,
            };
            // referential integrity
            for a in [&fam.init, &fam.fwd, &fam.eval] {
                anyhow::ensure!(artifacts.contains_key(a), "{name}: missing artifact {a}");
            }
            if let Some(a) = &fam.fwd_score {
                anyhow::ensure!(artifacts.contains_key(a), "{name}: missing artifact {a}");
            }
            for a in fam.train.values() {
                anyhow::ensure!(artifacts.contains_key(a), "{name}: missing artifact {a}");
            }
            families.insert(name.clone(), fam);
        }

        let mut score = BTreeMap::new();
        for (bs, v) in j.at(&["score"])?.as_obj()? {
            let name = v.as_str()?.to_string();
            anyhow::ensure!(artifacts.contains_key(&name), "missing score artifact {name}");
            score.insert(bs.parse::<usize>()?, name);
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            method_order,
            momentum,
            gamma_grid,
            families,
            score,
            artifacts,
        })
    }

    pub fn family(&self, name: &str) -> anyhow::Result<&FamilyInfo> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model family '{name}'"))
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))
    }

    pub fn score_artifact(&self, batch: usize) -> anyhow::Result<&ArtifactInfo> {
        let name = self
            .score
            .get(&batch)
            .ok_or_else(|| anyhow::anyhow!("no score artifact for batch {batch}"))?;
        self.artifact(name)
    }
}

/// Default artifacts directory: `$ADASELECTION_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("ADASELECTION_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    fn load() -> Option<Manifest> {
        let dir = manifest_dir();
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = load() else { return };
        assert_eq!(m.method_order.len(), 7);
        assert_eq!(m.method_order[1], "big_loss");
        assert!((m.momentum - 0.9).abs() < 1e-9);
        assert!(m.families.contains_key("resnet_c10"));
        let fam = m.family("resnet_c10").unwrap();
        assert_eq!(fam.batch, 128);
        assert_eq!(fam.task, TaskKind::Classification);
        assert!(fam.n_params() > 10);
        assert!(fam.train.contains_key(&128));
    }

    #[test]
    fn round_size_picks_next_compiled() {
        let Some(m) = load() else { return };
        let fam = m.family("resnet_c10").unwrap();
        // γ grid for B=128: 13,26,39,52,64,128
        assert_eq!(fam.round_size(13), 13);
        assert_eq!(fam.round_size(14), 26);
        assert_eq!(fam.round_size(1), 13);
        assert_eq!(fam.round_size(999), 128);
    }

    #[test]
    fn io_specs_match_family_params() {
        let Some(m) = load() else { return };
        for fam in m.families.values() {
            let fwd = m.artifact(&fam.fwd).unwrap();
            assert_eq!(fwd.inputs.len(), fam.n_params() + 2, "{}", fam.name);
            for ((_, shape), io) in fam.params.iter().zip(fwd.inputs.iter()) {
                assert_eq!(&io.shape, shape);
                assert_eq!(io.dtype, Dtype::F32);
            }
            assert_eq!(fwd.outputs.len(), 2);
            assert_eq!(fwd.outputs[0].shape, vec![fam.batch]);
        }
    }

    #[test]
    fn missing_artifact_reference_fails() {
        let dir = std::env::temp_dir().join("ada_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"method_order":["uniform"],"momentum":0.9,
                "gamma_grid":[0.1],
                "families":{"f":{"task":"regression","batch":4,"train_sizes":[2],
                  "params":[],
                  "artifacts":{"init":"nope","fwd":"nope","eval":"nope","train":{"2":"nope"}}}},
                "score":{},"artifacts":{}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}

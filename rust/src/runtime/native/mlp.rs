//! Pure-Rust MLP family: the L1 reference kernels of
//! `python/compile/kernels/ref.py` (per-sample squared-error / softmax
//! cross-entropy losses with their grad-norm proxies) plus the L2 train
//! step of `python/compile/model.py` (mean-loss backprop, global-norm
//! gradient clipping, SGD+momentum) — no JAX, no XLA, no artifacts.
//!
//! Serves the paper's regression tasks exactly (`mlp_simple`, `mlp_bike`)
//! and the image-classification datasets through an MLP surrogate head on
//! the flattened synthetic images (the selection layer under test is
//! model-agnostic; the mini-ResNet itself stays on the XLA backend).

use crate::runtime::backend::Tensor;
use crate::util::rng::Pcg64;

use super::{GRAD_CLIP, MOMENTUM};

const EPS: f32 = 1e-9;

/// `a[m,k] · b[k,n]` into a fresh `[m,n]` buffer.
pub(super) fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `aᵀ[k,m] · g[m,n]` into `[k,n]` (weight gradients).
pub(super) fn matmul_at_b(a: &[f32], g: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(g.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let grow = &g[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[p * n..(p + 1) * n];
            for (o, &gv) in orow.iter_mut().zip(grow.iter()) {
                *o += av * gv;
            }
        }
    }
    out
}

/// `g[m,n] · bᵀ[n,k]` into `[m,k]` (input gradients; `b` is `[k,n]`).
pub(super) fn matmul_a_bt(g: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(g.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * k];
    for i in 0..m {
        let grow = &g[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (p, ov) in orow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            let mut acc = 0.0f32;
            for (&gv, &bv) in grow.iter().zip(brow.iter()) {
                acc += gv * bv;
            }
            *ov = acc;
        }
    }
    out
}

/// In-place row-wise log-softmax over `[m, n]`; returns nothing, `logits`
/// becomes log-probabilities.
pub(super) fn log_softmax_rows(logits: &mut [f32], m: usize, n: usize) {
    for i in 0..m {
        let row = &mut logits[i * n..(i + 1) * n];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v -= max;
            sum += v.exp();
        }
        let lse = sum.ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// Global-norm gradient clipping (model.py GRAD_CLIP) + momentum update.
/// `grads` layout matches `params`/`mom`.
pub(super) fn clip_momentum_step(
    params: &mut [Tensor],
    mom: &mut [Tensor],
    grads: &[Vec<f32>],
    lr: f32,
) {
    let sq: f32 = grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&g| g * g)
        .sum::<f32>()
        + 1e-12;
    let gnorm = sq.sqrt();
    let scale = (GRAD_CLIP / gnorm).min(1.0);
    for ((p, m), g) in params.iter_mut().zip(mom.iter_mut()).zip(grads.iter()) {
        for ((pv, mv), &gv) in p.data.iter_mut().zip(m.data.iter_mut()).zip(g.iter()) {
            *mv = MOMENTUM * *mv + gv * scale;
            *pv -= lr * *mv;
        }
    }
}

/// An MLP `in_dim -> hidden... -> out_dim` with ReLU activations, mirroring
/// `python/compile/models/mlp.py` (out_dim 1 = regression head, out_dim C =
/// classification logits).
#[derive(Clone, Debug)]
pub struct MlpModel {
    pub in_dim: usize,
    pub hidden: Vec<usize>,
    pub out_dim: usize,
}

impl MlpModel {
    fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.in_dim];
        d.extend_from_slice(&self.hidden);
        d.push(self.out_dim);
        d
    }

    /// Ordered parameter shapes: (w0, b0, w1, b1, ...).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let dims = self.dims();
        let mut shapes = Vec::new();
        for win in dims.windows(2) {
            shapes.push(vec![win[0], win[1]]);
            shapes.push(vec![win[1]]);
        }
        shapes
    }

    /// Kaiming-normal weights, zero biases (deterministic in `rng`).
    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        self.param_shapes()
            .into_iter()
            .map(|shape| {
                if shape.len() == 2 {
                    let std = (2.0 / shape[0] as f64).sqrt();
                    Tensor {
                        data: (0..shape[0] * shape[1])
                            .map(|_| rng.normal_ms(0.0, std) as f32)
                            .collect(),
                        shape,
                    }
                } else {
                    Tensor::zeros(&shape)
                }
            })
            .collect()
    }

    /// Hidden stack: returns (last hidden activations `[b, h_last]`,
    /// per-sample fnorm = ‖last hidden‖₂). `b` rows of `x`.
    fn hidden_forward(&self, params: &[Tensor], x: &[f32], b: usize) -> (Vec<f32>, Vec<f32>) {
        let dims = self.dims();
        let mut h = x.to_vec();
        let mut width = self.in_dim;
        for (l, win) in dims.windows(2).take(dims.len() - 2).enumerate() {
            let (w, bias) = (&params[2 * l], &params[2 * l + 1]);
            let mut z = matmul(&h, &w.data, b, win[0], win[1]);
            for row in z.chunks_mut(win[1]) {
                for (v, &bv) in row.iter_mut().zip(bias.data.iter()) {
                    *v = (*v + bv).max(0.0);
                }
            }
            h = z;
            width = win[1];
        }
        let fnorm: Vec<f32> = h
            .chunks(width)
            .map(|row| (row.iter().map(|&v| v * v).sum::<f32>() + EPS).sqrt())
            .collect();
        (h, fnorm)
    }

    /// Head outputs `[b, out_dim]` (logits or 1-wide predictions).
    fn head(&self, params: &[Tensor], h: &[f32], b: usize) -> Vec<f32> {
        let dims = self.dims();
        let k = dims[dims.len() - 2];
        let w = &params[params.len() - 2];
        let bias = &params[params.len() - 1];
        let mut out = matmul(h, &w.data, b, k, self.out_dim);
        for row in out.chunks_mut(self.out_dim) {
            for (v, &bv) in row.iter_mut().zip(bias.data.iter()) {
                *v += bv;
            }
        }
        out
    }

    /// Per-sample (loss, gnorm proxy) — `persample_sqerr` / `persample_xent`
    /// from ref.py depending on the head width.
    pub fn forward_scores(
        &self,
        params: &[Tensor],
        x: &[f32],
        y_f32: Option<&[f32]>,
        y_i32: Option<&[i32]>,
        b: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (h, fnorm) = self.hidden_forward(params, x, b);
        let out = self.head(params, &h, b);
        if self.out_dim == 1 {
            let y = y_f32.expect("regression batch missing f32 targets");
            let mut loss = vec![0.0f32; b];
            let mut gnorm = vec![0.0f32; b];
            for i in 0..b {
                let r = out[i] - y[i];
                loss[i] = 0.5 * r * r;
                gnorm[i] = r.abs() * fnorm[i];
            }
            (loss, gnorm)
        } else {
            let y = y_i32.expect("classification batch missing i32 labels");
            let c = self.out_dim;
            let mut logp = out;
            log_softmax_rows(&mut logp, b, c);
            let mut loss = vec![0.0f32; b];
            let mut gnorm = vec![0.0f32; b];
            for i in 0..b {
                let row = &logp[i * c..(i + 1) * c];
                let yi = y[i] as usize;
                loss[i] = -row[yi];
                let mut sq = 0.0f32;
                for (cidx, &lp) in row.iter().enumerate() {
                    let p = lp.exp();
                    let t = if cidx == yi { p - 1.0 } else { p };
                    sq += t * t;
                }
                gnorm[i] = (sq + EPS).sqrt() * fnorm[i];
            }
            (loss, gnorm)
        }
    }

    /// Masked eval: (Σ loss·mask, Σ correct·mask) — correct is 0 for the
    /// regression head, matching the eval artifact. One forward pass: the
    /// argmax of the log-softmax rows equals the argmax of the logits.
    pub fn eval(
        &self,
        params: &[Tensor],
        x: &[f32],
        y_f32: Option<&[f32]>,
        y_i32: Option<&[i32]>,
        mask: &[f32],
        b: usize,
    ) -> (f32, f32) {
        if self.out_dim == 1 {
            let (loss, _) = self.forward_scores(params, x, y_f32, y_i32, b);
            let loss_sum = loss.iter().zip(mask.iter()).map(|(&l, &m)| l * m).sum();
            return (loss_sum, 0.0);
        }
        let y = y_i32.expect("classification batch missing i32 labels");
        let c = self.out_dim;
        let (h, _) = self.hidden_forward(params, x, b);
        let mut logp = self.head(params, &h, b);
        log_softmax_rows(&mut logp, b, c);
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for i in 0..b {
            let row = &logp[i * c..(i + 1) * c];
            loss_sum += -row[y[i] as usize] * mask[i];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(idx, _)| idx)
                .unwrap_or(0);
            if argmax == y[i] as usize {
                correct += mask[i];
            }
        }
        (loss_sum, correct)
    }

    /// One SGD+momentum step on `k` rows; returns the pre-update mean loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &mut [Tensor],
        mom: &mut [Tensor],
        x: &[f32],
        y_f32: Option<&[f32]>,
        y_i32: Option<&[i32]>,
        k: usize,
        lr: f32,
    ) -> f32 {
        let dims = self.dims();
        let n_layers = dims.len() - 1;

        // forward, caching every layer input
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers);
        acts.push(x.to_vec());
        for l in 0..n_layers - 1 {
            let (a, b_) = (dims[l], dims[l + 1]);
            let (w, bias) = (&params[2 * l], &params[2 * l + 1]);
            let mut z = matmul(acts.last().unwrap(), &w.data, k, a, b_);
            for row in z.chunks_mut(b_) {
                for (v, &bv) in row.iter_mut().zip(bias.data.iter()) {
                    *v = (*v + bv).max(0.0);
                }
            }
            acts.push(z);
        }
        let out = self.head(params, acts.last().unwrap(), k);

        // mean loss + output gradient (d mean-loss / d out)
        let c = self.out_dim;
        let mut dout = vec![0.0f32; k * c];
        let mean_loss;
        if c == 1 {
            let y = y_f32.expect("regression batch missing f32 targets");
            let mut sum = 0.0f32;
            for i in 0..k {
                let r = out[i] - y[i];
                sum += 0.5 * r * r;
                dout[i] = r / k as f32;
            }
            mean_loss = sum / k as f32;
        } else {
            let y = y_i32.expect("classification batch missing i32 labels");
            let mut logp = out;
            log_softmax_rows(&mut logp, k, c);
            let mut sum = 0.0f32;
            for i in 0..k {
                let yi = y[i] as usize;
                let row = &logp[i * c..(i + 1) * c];
                sum += -row[yi];
                for (cidx, &lp) in row.iter().enumerate() {
                    let p = lp.exp();
                    dout[i * c + cidx] =
                        (if cidx == yi { p - 1.0 } else { p }) / k as f32;
                }
            }
            mean_loss = sum / k as f32;
        }

        // backprop through the dense stack
        let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.elems()]).collect();
        let mut g = dout; // [k, dims[l+1]] for layer l, walking backwards
        for l in (0..n_layers).rev() {
            let (a, b_) = (dims[l], dims[l + 1]);
            let inp = &acts[l];
            grads[2 * l] = matmul_at_b(inp, &g, k, a, b_);
            let db = &mut grads[2 * l + 1];
            for row in g.chunks(b_) {
                for (d, &gv) in db.iter_mut().zip(row.iter()) {
                    *d += gv;
                }
            }
            if l > 0 {
                let w = &params[2 * l];
                let mut da = matmul_a_bt(&g, &w.data, k, a, b_);
                // ReLU mask from the cached post-activation input
                for (d, &av) in da.iter_mut().zip(inp.iter()) {
                    if av <= 0.0 {
                        *d = 0.0;
                    }
                }
                g = da;
            }
        }

        clip_momentum_step(params, mom, &grads, lr);
        mean_loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MlpModel {
        MlpModel {
            in_dim: 2,
            hidden: vec![8],
            out_dim: 1,
        }
    }

    #[test]
    fn shapes_match_python_layout() {
        let shapes = model().param_shapes();
        assert_eq!(
            shapes,
            vec![vec![2, 8], vec![8], vec![8, 1], vec![1]]
        );
    }

    #[test]
    fn matmul_small_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let out = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
        let atb = matmul_at_b(&[1.0, 2.0, 3.0, 4.0], &[1.0, 0.0, 0.0, 1.0], 2, 2, 2);
        assert_eq!(atb, vec![1.0, 3.0, 2.0, 4.0]); // aᵀ
        let abt = matmul_a_bt(&[1.0, 0.0, 0.0, 1.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(abt, vec![5.0, 6.0, 7.0, 8.0]); // picks rows of b
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let m = model();
        let mut rng = Pcg64::new(3);
        let params0 = m.init(&mut rng);
        let x = vec![0.3f32, -0.7, 1.2, 0.4, -0.5, 0.9];
        let y = vec![1.0f32, -2.0, 0.5];

        // analytic step with clip disabled by tiny lr trick: recover grads by
        // comparing param deltas after one zero-momentum step
        let mut params = params0.clone();
        let mut mom: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let lr = 1e-3f32;
        let _ = m.train_step(&mut params, &mut mom, &x, Some(&y), None, 3, lr);

        // finite-difference check on one early weight
        let mean_loss = |ps: &[Tensor]| -> f32 {
            let (loss, _) = m.forward_scores(ps, &x, Some(&y), None, 3);
            loss.iter().sum::<f32>() / 3.0
        };
        let eps = 1e-3f32;
        let mut pp = params0.clone();
        pp[0].data[0] += eps;
        let mut pm = params0.clone();
        pm[0].data[0] -= eps;
        let fd = (mean_loss(&pp) - mean_loss(&pm)) / (2.0 * eps);
        // delta = -lr * grad (momentum starts at zero, clip scale ≈ 1 here)
        let analytic = (params0[0].data[0] - params[0].data[0]) / lr;
        assert!(
            (fd - analytic).abs() < 2e-2 * (1.0 + fd.abs()),
            "finite-diff {fd} vs analytic {analytic}"
        );
    }

    #[test]
    fn regression_training_reduces_loss() {
        let m = model();
        let mut rng = Pcg64::new(11);
        let mut params = m.init(&mut rng);
        let mut mom: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        // y = x0 - x1 on a fixed grid
        let n = 32;
        let x: Vec<f32> = (0..n)
            .flat_map(|i| {
                let a = (i as f32 / n as f32) * 2.0 - 1.0;
                [a, -a * 0.5]
            })
            .collect();
        let y: Vec<f32> = x.chunks(2).map(|p| p[0] - p[1]).collect();
        let first = m.train_step(&mut params, &mut mom, &x, Some(&y), None, n, 0.05);
        let mut last = first;
        for _ in 0..200 {
            last = m.train_step(&mut params, &mut mom, &x, Some(&y), None, n, 0.05);
        }
        assert!(last < 0.2 * first, "loss {first} -> {last}");
    }

    #[test]
    fn classification_forward_and_train_sane() {
        let m = MlpModel {
            in_dim: 3,
            hidden: vec![16],
            out_dim: 4,
        };
        let mut rng = Pcg64::new(5);
        let mut params = m.init(&mut rng);
        let mut mom: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        // 4 clusters on coordinate axes
        let n = 64;
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut r2 = Pcg64::new(9);
        for i in 0..n {
            let cls = i % 4;
            let mut row = [0.0f32; 3];
            for v in row.iter_mut() {
                *v = r2.normal_ms(0.0, 0.1) as f32;
            }
            if cls < 3 {
                row[cls] += 2.0;
            } else {
                row[0] -= 2.0;
            }
            x.extend_from_slice(&row);
            y.push(cls as i32);
        }
        let (loss, gnorm) = m.forward_scores(&params, &x, None, Some(&y), n);
        assert!(loss.iter().all(|l| l.is_finite() && *l >= 0.0));
        assert!(gnorm.iter().all(|g| g.is_finite() && *g >= 0.0));
        // untrained xent ≈ ln(4)
        let mean: f32 = loss.iter().sum::<f32>() / n as f32;
        assert!((mean - 4.0f32.ln()).abs() < 1.0, "untrained loss {mean}");

        let first = m.train_step(&mut params, &mut mom, &x, None, Some(&y), n, 0.1);
        let mut last = first;
        for _ in 0..150 {
            last = m.train_step(&mut params, &mut mom, &x, None, Some(&y), n, 0.1);
        }
        assert!(last < 0.5 * first, "xent {first} -> {last}");
        let mask = vec![1.0f32; n];
        let (_, correct) = m.eval(&params, &x, None, Some(&y), &mask, n);
        assert!(correct / n as f32 > 0.8, "train acc {}", correct / n as f32);
    }
}

//! Pure-Rust LM family: a learned bigram model (token embedding → dense →
//! vocab logits, position-wise) standing in for the Pallas transformer on
//! the native backend. The per-sample loss/gnorm math is the
//! `persample_lm_xent` reference kernel from ref.py: token-level softmax
//! cross-entropy and `‖p − onehot‖₂ · ‖h‖₂`, both averaged over the window.
//!
//! On the order-2 Markov corpus a bigram learner captures most of the
//! structure, which is all the selection layer needs: a loss landscape that
//! moves under training. The full transformer stays on the XLA backend.

use crate::runtime::backend::Tensor;
use crate::util::rng::Pcg64;

use super::mlp::{clip_momentum_step, log_softmax_rows, matmul, matmul_a_bt, matmul_at_b};

const EPS: f32 = 1e-9;

/// Bigram LM: params = [embed `[vocab, d]`, w `[d, vocab]`, b `[vocab]`].
#[derive(Clone, Debug)]
pub struct BigramLm {
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
}

impl BigramLm {
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![
            vec![self.vocab, self.d_model],
            vec![self.d_model, self.vocab],
            vec![self.vocab],
        ]
    }

    pub fn init(&self, rng: &mut Pcg64) -> Vec<Tensor> {
        let emb_std = (1.0 / self.d_model as f64).sqrt();
        let w_std = (2.0 / self.d_model as f64).sqrt();
        vec![
            Tensor {
                shape: vec![self.vocab, self.d_model],
                data: (0..self.vocab * self.d_model)
                    .map(|_| rng.normal_ms(0.0, emb_std) as f32)
                    .collect(),
            },
            Tensor {
                shape: vec![self.d_model, self.vocab],
                data: (0..self.d_model * self.vocab)
                    .map(|_| rng.normal_ms(0.0, w_std) as f32)
                    .collect(),
            },
            Tensor::zeros(&[self.vocab]),
        ]
    }

    /// Gather token embeddings: `[b·t, d]` plus per-token ‖h‖₂.
    fn embed(&self, params: &[Tensor], x: &[i32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        let d = self.d_model;
        let emb = &params[0].data;
        let mut h = Vec::with_capacity(rows * d);
        let mut fnorm = Vec::with_capacity(rows);
        for &tok in x.iter().take(rows) {
            let t = tok as usize;
            let row = &emb[t * d..(t + 1) * d];
            h.extend_from_slice(row);
            fnorm.push((row.iter().map(|&v| v * v).sum::<f32>() + EPS).sqrt());
        }
        (h, fnorm)
    }

    /// Token log-probabilities `[rows, vocab]` for flattened tokens.
    fn token_logp(&self, params: &[Tensor], h: &[f32], rows: usize) -> Vec<f32> {
        let (d, v) = (self.d_model, self.vocab);
        let mut logits = matmul(h, &params[1].data, rows, d, v);
        for row in logits.chunks_mut(v) {
            for (lv, &bv) in row.iter_mut().zip(params[2].data.iter()) {
                *lv += bv;
            }
        }
        log_softmax_rows(&mut logits, rows, v);
        logits
    }

    /// Per-sample (loss, gnorm): `persample_lm_xent` over `[b, seq]` tokens.
    pub fn forward_scores(
        &self,
        params: &[Tensor],
        x: &[i32],
        y: &[i32],
        b: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (t, v) = (self.seq, self.vocab);
        let rows = b * t;
        let (h, fnorm) = self.embed(params, x, rows);
        let logp = self.token_logp(params, &h, rows);
        let mut loss = vec![0.0f32; b];
        let mut gnorm = vec![0.0f32; b];
        for i in 0..b {
            let mut lsum = 0.0f32;
            let mut gsum = 0.0f32;
            for ti in 0..t {
                let r = i * t + ti;
                let row = &logp[r * v..(r + 1) * v];
                let yi = y[r] as usize;
                lsum += -row[yi];
                let mut sq = 0.0f32;
                for (c, &lp) in row.iter().enumerate() {
                    let p = lp.exp();
                    let d = if c == yi { p - 1.0 } else { p };
                    sq += d * d;
                }
                gsum += (sq + EPS).sqrt() * fnorm[r];
            }
            loss[i] = lsum / t as f32;
            gnorm[i] = gsum / t as f32;
        }
        (loss, gnorm)
    }

    /// Masked eval: (Σ sample-loss·mask, Σ token-accuracy·mask).
    pub fn eval(
        &self,
        params: &[Tensor],
        x: &[i32],
        y: &[i32],
        mask: &[f32],
        b: usize,
    ) -> (f32, f32) {
        let (t, v) = (self.seq, self.vocab);
        let rows = b * t;
        let (h, _) = self.embed(params, x, rows);
        let logp = self.token_logp(params, &h, rows);
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for i in 0..b {
            let mut lsum = 0.0f32;
            let mut hits = 0.0f32;
            for ti in 0..t {
                let r = i * t + ti;
                let row = &logp[r * v..(r + 1) * v];
                let yi = y[r] as usize;
                lsum += -row[yi];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(idx, _)| idx)
                    .unwrap_or(0);
                if argmax == yi {
                    hits += 1.0;
                }
            }
            loss_sum += (lsum / t as f32) * mask[i];
            correct += (hits / t as f32) * mask[i];
        }
        (loss_sum, correct)
    }

    /// One SGD+momentum step on `k` sequences; returns pre-update mean loss.
    pub fn train_step(
        &self,
        params: &mut [Tensor],
        mom: &mut [Tensor],
        x: &[i32],
        y: &[i32],
        k: usize,
        lr: f32,
    ) -> f32 {
        let (t, v, d) = (self.seq, self.vocab, self.d_model);
        let rows = k * t;
        let (h, _) = self.embed(params, x, rows);
        let logp = self.token_logp(params, &h, rows);

        // mean loss over every token + dlogits = (p - onehot) / (k·t)
        let scale = 1.0 / rows as f32;
        let mut sum = 0.0f32;
        let mut dlogits = vec![0.0f32; rows * v];
        for r in 0..rows {
            let row = &logp[r * v..(r + 1) * v];
            let yi = y[r] as usize;
            sum += -row[yi];
            let drow = &mut dlogits[r * v..(r + 1) * v];
            for (c, (&lp, dv)) in row.iter().zip(drow.iter_mut()).enumerate() {
                let p = lp.exp();
                *dv = (if c == yi { p - 1.0 } else { p }) * scale;
            }
        }
        let mean_loss = sum * scale;

        // grads: dw = hᵀ·dlogits, db = Σ rows, dembed scatter-add
        let dw = matmul_at_b(&h, &dlogits, rows, d, v);
        let mut db = vec![0.0f32; v];
        for row in dlogits.chunks(v) {
            for (b_, &g) in db.iter_mut().zip(row.iter()) {
                *b_ += g;
            }
        }
        let dh = matmul_a_bt(&dlogits, &params[1].data, rows, d, v);
        let mut demb = vec![0.0f32; self.vocab * d];
        for (r, &tok) in x.iter().take(rows).enumerate() {
            let ti = tok as usize;
            let src = &dh[r * d..(r + 1) * d];
            let dst = &mut demb[ti * d..(ti + 1) * d];
            for (dv, &sv) in dst.iter_mut().zip(src.iter()) {
                *dv += sv;
            }
        }

        clip_momentum_step(params, mom, &[demb, dw, db], lr);
        mean_loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> BigramLm {
        BigramLm {
            vocab: 8,
            seq: 4,
            d_model: 6,
        }
    }

    #[test]
    fn untrained_loss_near_uniform() {
        let m = toy();
        let mut rng = Pcg64::new(1);
        let params = m.init(&mut rng);
        let x: Vec<i32> = (0..8).map(|i| i % 8).collect(); // 2 sequences
        let y: Vec<i32> = (1..9).map(|i| i % 8).collect();
        let (loss, gnorm) = m.forward_scores(&params, &x, &y, 2);
        assert_eq!(loss.len(), 2);
        let uniform = (8.0f32).ln();
        for l in &loss {
            assert!((l - uniform).abs() < 1.0, "loss {l} vs ln(V) {uniform}");
        }
        assert!(gnorm.iter().all(|g| g.is_finite() && *g > 0.0));
    }

    #[test]
    fn bigram_structure_is_learned() {
        // deterministic successor: y = x + 1 mod V — a pure bigram rule
        let m = toy();
        let mut rng = Pcg64::new(2);
        let mut params = m.init(&mut rng);
        let mut mom: Vec<Tensor> =
            params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let k = 4;
        let x: Vec<i32> = (0..k * 4).map(|i| (i % 8) as i32).collect();
        let y: Vec<i32> = x.iter().map(|&t| (t + 1) % 8).collect();
        let first = m.train_step(&mut params, &mut mom, &x, &y, k, 0.5);
        let mut last = first;
        for _ in 0..300 {
            last = m.train_step(&mut params, &mut mom, &x, &y, k, 0.5);
        }
        assert!(last < 0.3 * first, "lm loss {first} -> {last}");
        let mask = vec![1.0f32; k];
        let (_, tok_acc) = m.eval(&params, &x, &y, &mask, k);
        assert!(tok_acc / k as f32 > 0.9, "token acc {}", tok_acc / k as f32);
    }
}

//! The pure-Rust backend: L1 reference kernels + L2 train/eval steps with
//! zero native dependencies — no Python, no XLA shared library, no
//! artifacts directory. Any train-step subset size runs (no compiled-shape
//! grid), which makes ⌈γB⌉ exact instead of rounded.
//!
//! Family table (mirrors `python/compile/model.py::make_families`):
//!
//! | family        | model                         | task           | B   |
//! |---------------|-------------------------------|----------------|-----|
//! | `mlp_simple`  | MLP 1→32→1                    | regression     | 100 |
//! | `mlp_bike`    | MLP 8→64→64→1                 | regression     | 100 |
//! | `resnet_c10`  | MLP 768→128→10 (surrogate)    | classification | 128 |
//! | `resnet_c100` | MLP 768→128→100 (surrogate)   | classification | 128 |
//! | `transformer` | bigram LM V=256 d=32 (surrogate) | lm          | 64  |
//!
//! The two surrogates keep every dataset runnable on bare CPU; the real
//! mini-ResNet / transformer graphs remain on the XLA backend
//! (`--features xla`). The selection layer under test is model-agnostic.

pub mod lm;
pub mod mlp;

use std::collections::BTreeMap;

use crate::pipeline::Batch;
use crate::selection::adaselection::score_full;
use crate::util::rng::Pcg64;

use super::backend::{Backend, FamilyMeta, FusedForward, TaskKind, Tensor};

use self::lm::BigramLm;
use self::mlp::MlpModel;

/// SGD momentum coefficient (model.py MOMENTUM).
pub const MOMENTUM: f32 = 0.9;
/// Global-norm gradient clip (model.py GRAD_CLIP).
pub const GRAD_CLIP: f32 = 5.0;

/// One registered model family.
#[derive(Clone, Debug)]
enum NativeModel {
    Mlp(MlpModel),
    Lm(BigramLm),
}

#[derive(Clone, Debug)]
struct NativeFamily {
    task: TaskKind,
    batch: usize,
    model: NativeModel,
}

/// Model parameters + momentum, plain host tensors.
#[derive(Clone, Debug)]
pub struct NativeState {
    pub family: String,
    pub params: Vec<Tensor>,
    pub mom: Vec<Tensor>,
}

impl NativeState {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

/// The pure-Rust compute backend.
pub struct NativeBackend {
    families: BTreeMap<String, NativeFamily>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        let mlp = |in_dim: usize, hidden: &[usize], out_dim: usize| {
            NativeModel::Mlp(MlpModel {
                in_dim,
                hidden: hidden.to_vec(),
                out_dim,
            })
        };
        let mut families = BTreeMap::new();
        families.insert(
            "mlp_simple".to_string(),
            NativeFamily { task: TaskKind::Regression, batch: 100, model: mlp(1, &[32], 1) },
        );
        families.insert(
            "mlp_bike".to_string(),
            NativeFamily { task: TaskKind::Regression, batch: 100, model: mlp(8, &[64, 64], 1) },
        );
        families.insert(
            "resnet_c10".to_string(),
            NativeFamily {
                task: TaskKind::Classification,
                batch: 128,
                model: mlp(16 * 16 * 3, &[128], 10),
            },
        );
        families.insert(
            "resnet_c100".to_string(),
            NativeFamily {
                task: TaskKind::Classification,
                batch: 128,
                model: mlp(16 * 16 * 3, &[128], 100),
            },
        );
        families.insert(
            "transformer".to_string(),
            NativeFamily {
                task: TaskKind::Lm,
                batch: 64,
                model: NativeModel::Lm(BigramLm { vocab: 256, seq: 32, d_model: 32 }),
            },
        );
        // streaming family: compact classifier for the drift-class source
        // (continuous-training workloads; no XLA-side counterpart needed)
        families.insert(
            "stream_class".to_string(),
            NativeFamily {
                task: TaskKind::Classification,
                batch: 128,
                model: mlp(32, &[64], 10),
            },
        );
        NativeBackend { families }
    }

    fn family(&self, name: &str) -> anyhow::Result<&NativeFamily> {
        self.families
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model family '{name}' (native backend)"))
    }

    fn param_shapes(fam: &NativeFamily) -> Vec<Vec<usize>> {
        match &fam.model {
            NativeModel::Mlp(m) => m.param_shapes(),
            NativeModel::Lm(m) => m.param_shapes(),
        }
    }
}

/// Pull the f32 features out of a batch (MLP families).
fn x_f32(batch: &Batch) -> anyhow::Result<&[f32]> {
    batch
        .x_f32
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("batch has no f32 features for an MLP family"))
}

fn y_pair(batch: &Batch) -> (Option<&[f32]>, Option<&[i32]>) {
    (batch.y_f32.as_deref(), batch.y_i32.as_deref())
}

/// Pull the i32 token windows out of a batch (LM family).
fn xy_i32(batch: &Batch) -> anyhow::Result<(&[i32], &[i32])> {
    match (batch.x_i32.as_deref(), batch.y_i32.as_deref()) {
        (Some(x), Some(y)) => Ok((x, y)),
        _ => Err(anyhow::anyhow!("batch has no i32 token windows for the LM family")),
    }
}

impl Backend for NativeBackend {
    type State = NativeState;

    fn name(&self) -> &'static str {
        "native"
    }

    fn family_meta(&self, family: &str) -> anyhow::Result<FamilyMeta> {
        let fam = self.family(family)?;
        Ok(FamilyMeta {
            name: family.to_string(),
            task: fam.task,
            batch: fam.batch,
            sizes: None, // any subset size trains natively
        })
    }

    fn init_state(&mut self, family: &str, seed: i32) -> anyhow::Result<NativeState> {
        let fam = self.family(family)?;
        // fold the family name into the stream so families differ per seed
        let tag = family
            .bytes()
            .fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
        let mut rng = Pcg64::new((seed as u64) ^ tag);
        let params = match &fam.model {
            NativeModel::Mlp(m) => m.init(&mut rng),
            NativeModel::Lm(m) => m.init(&mut rng),
        };
        let mom = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        Ok(NativeState {
            family: family.to_string(),
            params,
            mom,
        })
    }

    fn forward_scores(
        &mut self,
        state: &NativeState,
        batch: &Batch,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let fam = self.family(&state.family)?;
        let b = batch.len();
        crate::obs::prof::time("forward_scores", || {
            Ok(match &fam.model {
                NativeModel::Mlp(m) => {
                    let (yf, yi) = y_pair(batch);
                    m.forward_scores(&state.params, x_f32(batch)?, yf, yi, b)
                }
                NativeModel::Lm(m) => {
                    let (x, y) = xy_i32(batch)?;
                    m.forward_scores(&state.params, x, y, b)
                }
            })
        })
    }

    fn forward_score_fused(
        &mut self,
        state: &NativeState,
        batch: &Batch,
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<Option<FusedForward>> {
        let (loss, gnorm) = self.forward_scores(state, batch)?;
        // only the scoring half is the fused-scorer kernel — the forward
        // half was already timed under "forward_scores" just above
        let (scores, alphas) = crate::obs::prof::time("fused_scorer", || {
            score_full(&loss, &gnorm, w_full, t, cl_power, cl_on)
        });
        Ok(Some(FusedForward {
            loss,
            gnorm,
            scores,
            alphas,
        }))
    }

    fn train_step(
        &mut self,
        state: &mut NativeState,
        sub: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32> {
        let fam = self.family(&state.family)?;
        let k = sub.len();
        anyhow::ensure!(k > 0, "train_step on an empty sub-batch");
        crate::obs::prof::time("sgd_step", || {
            Ok(match &fam.model {
                NativeModel::Mlp(m) => {
                    let (yf, yi) = y_pair(sub);
                    m.train_step(
                        &mut state.params,
                        &mut state.mom,
                        x_f32(sub)?,
                        yf,
                        yi,
                        k,
                        lr,
                    )
                }
                NativeModel::Lm(m) => {
                    let (x, y) = xy_i32(sub)?;
                    m.train_step(&mut state.params, &mut state.mom, x, y, k, lr)
                }
            })
        })
    }

    fn eval(&mut self, state: &NativeState, batch: &Batch) -> anyhow::Result<(f32, f32)> {
        let fam = self.family(&state.family)?;
        let b = batch.len();
        let mask = batch.mask();
        crate::obs::prof::time("eval", || {
            Ok(match &fam.model {
                NativeModel::Mlp(m) => {
                    let (yf, yi) = y_pair(batch);
                    m.eval(&state.params, x_f32(batch)?, yf, yi, &mask, b)
                }
                NativeModel::Lm(m) => {
                    let (x, y) = xy_i32(batch)?;
                    m.eval(&state.params, x, y, &mask, b)
                }
            })
        })
    }

    fn score(
        &mut self,
        loss: &[f32],
        gnorm: &[f32],
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)> {
        Ok(crate::obs::prof::time("score_full", || {
            score_full(loss, gnorm, w_full, t, cl_power, cl_on)
        }))
    }

    fn param_count(&self, family: &str) -> anyhow::Result<usize> {
        let fam = self.family(family)?;
        Ok(Self::param_shapes(fam)
            .iter()
            .map(|s| s.iter().product::<usize>())
            .sum())
    }

    /// Checkpoint export: parameters followed by momentum buffers.
    fn export_state(&self, state: &NativeState) -> anyhow::Result<Vec<Tensor>> {
        let mut out = state.params.clone();
        out.extend(state.mom.iter().cloned());
        Ok(out)
    }

    fn import_state(&mut self, family: &str, tensors: &[Tensor]) -> anyhow::Result<NativeState> {
        let fam = self.family(family)?;
        let shapes = Self::param_shapes(fam);
        anyhow::ensure!(
            tensors.len() == 2 * shapes.len(),
            "checkpoint for '{family}' has {} tensors, expected {} (params + momentum)",
            tensors.len(),
            2 * shapes.len()
        );
        for (i, t) in tensors.iter().enumerate() {
            let want = &shapes[i % shapes.len()];
            anyhow::ensure!(
                &t.shape == want && t.data.len() == want.iter().product::<usize>(),
                "checkpoint tensor {i} shape {:?} != family shape {want:?}",
                t.shape
            );
        }
        Ok(NativeState {
            family: family.to_string(),
            params: tensors[..shapes.len()].to_vec(),
            mom: tensors[shapes.len()..].to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::pipeline::gather;

    #[test]
    fn all_native_families_resolve() {
        let nb = NativeBackend::new();
        for (fam, ds) in [
            ("mlp_simple", "simple"),
            ("mlp_bike", "bike"),
            ("resnet_c10", "cifar10"),
            ("resnet_c100", "cifar100"),
            ("transformer", "wikitext"),
        ] {
            let meta = nb.family_meta(fam).unwrap();
            assert_eq!(meta.sizes, None, "{fam}");
            assert!(nb.param_count(fam).unwrap() > 0, "{fam}");
            assert_eq!(data::family_for(ds).unwrap(), fam);
        }
        assert!(nb.family_meta("nope").is_err());
    }

    #[test]
    fn init_is_deterministic_and_distinct_per_family() {
        let mut nb = NativeBackend::new();
        let a = nb.init_state("mlp_simple", 7).unwrap();
        let b = nb.init_state("mlp_simple", 7).unwrap();
        assert_eq!(a.params[0].data, b.params[0].data);
        let c = nb.init_state("mlp_simple", 8).unwrap();
        assert_ne!(a.params[0].data, c.params[0].data);
        assert!(a.mom.iter().all(|t| t.data.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn forward_train_eval_cycle_every_dataset() {
        let mut nb = NativeBackend::new();
        for ds_name in ["simple", "bike", "cifar10", "wikitext"] {
            let fam_name = data::family_for(ds_name).unwrap();
            let meta = nb.family_meta(fam_name).unwrap();
            let split = data::build(ds_name, 3, 0.01).unwrap();
            let mut state = nb.init_state(fam_name, 5).unwrap();
            let idx: Vec<usize> = (0..meta.batch.min(split.train.len())).collect();
            let batch = gather(&split.train, &idx, meta.batch, 0, 0);

            let (loss, gnorm) = nb.forward_scores(&state, &batch).unwrap();
            assert_eq!(loss.len(), meta.batch, "{ds_name}");
            assert!(loss.iter().all(|l| l.is_finite() && *l >= 0.0), "{ds_name}");
            assert!(gnorm.iter().all(|g| g.is_finite() && *g >= 0.0), "{ds_name}");

            // any subset size trains (no compiled grid natively)
            let rows: Vec<usize> = (0..17.min(batch.len())).collect();
            let sub = batch.gather_rows(&rows);
            let l0 = nb.train_step(&mut state, &sub, 0.01).unwrap();
            assert!(l0.is_finite(), "{ds_name}");

            let (loss_sum, correct) = nb.eval(&state, &batch).unwrap();
            assert!(loss_sum.is_finite() && loss_sum >= 0.0, "{ds_name}");
            assert!(correct >= 0.0, "{ds_name}");
        }
    }

    #[test]
    fn stream_family_registered() {
        let mut nb = NativeBackend::new();
        let meta = nb.family_meta("stream_class").unwrap();
        assert_eq!(meta.task, TaskKind::Classification);
        assert_eq!(meta.batch, 128);
        assert_eq!(meta.sizes, None);
        // 32->64->10 MLP: (32*64 + 64) + (64*10 + 10)
        assert_eq!(nb.param_count("stream_class").unwrap(), 2112 + 650);
        let state = nb.init_state("stream_class", 3).unwrap();
        assert!(state.n_params() > 0);
    }

    #[test]
    fn export_import_round_trips_state() {
        let mut nb = NativeBackend::new();
        let split = data::build("simple", 2, 0.01).unwrap();
        let mut state = nb.init_state("mlp_simple", 4).unwrap();
        // take a step so momentum is non-zero
        let idx: Vec<usize> = (0..32).collect();
        let batch = gather(&split.train, &idx, 100, 0, 0);
        nb.train_step(&mut state, &batch, 0.01).unwrap();

        let tensors = nb.export_state(&state).unwrap();
        let restored = nb.import_state("mlp_simple", &tensors).unwrap();
        for (a, b) in state.params.iter().zip(restored.params.iter()) {
            assert_eq!(a.data, b.data);
        }
        for (a, b) in state.mom.iter().zip(restored.mom.iter()) {
            assert_eq!(a.data, b.data);
        }
        // forward results agree exactly
        let (la, _) = nb.forward_scores(&state, &batch).unwrap();
        let (lb, _) = nb.forward_scores(&restored, &batch).unwrap();
        assert_eq!(la, lb);
        // wrong family / truncated tensor lists are rejected
        assert!(nb.import_state("transformer", &tensors).is_err());
        assert!(nb.import_state("mlp_simple", &tensors[..1]).is_err());
    }

    #[test]
    fn fused_matches_separate_score() {
        let mut nb = NativeBackend::new();
        let split = data::build("simple", 1, 0.01).unwrap();
        let state = nb.init_state("mlp_simple", 1).unwrap();
        let idx: Vec<usize> = (0..100).collect();
        let batch = gather(&split.train, &idx, 100, 0, 0);
        let w = [0.3f32, 1.2, 0.8, 1.0, 0.5, 0.9, 1.3];
        let fused = nb
            .forward_score_fused(&state, &batch, &w, 7, -0.5, true)
            .unwrap()
            .unwrap();
        let (loss, gnorm) = nb.forward_scores(&state, &batch).unwrap();
        let (scores, alphas) = nb.score(&loss, &gnorm, &w, 7, -0.5, true).unwrap();
        assert_eq!(fused.loss, loss);
        assert_eq!(fused.gnorm, gnorm);
        assert_eq!(fused.scores, scores);
        assert_eq!(fused.alphas, alphas);
        // α rows are simplex vectors
        for row in &fused.alphas {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "alpha row sum {sum}");
        }
    }
}

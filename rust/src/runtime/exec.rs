//! Literal/buffer helpers around the `xla` crate: typed argument packing
//! validated against manifest IO specs, and tuple-output unpacking.

use xla::{ElementType, Literal};

use super::manifest::{ArtifactInfo, Dtype, IoSpec};

/// A host-side argument for an artifact call.
pub enum Arg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
    /// Pre-built literal (e.g. a parameter kept resident across steps).
    Lit(&'a Literal),
}

/// Build a typed literal for `spec` from raw f32 data.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<Literal> {
    let expect: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == expect,
        "f32 literal: {} elems for shape {shape:?} (want {expect})",
        data.len()
    );
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Build a typed literal for `spec` from raw i32 data.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<Literal> {
    let expect: usize = shape.iter().product();
    anyhow::ensure!(
        data.len() == expect,
        "i32 literal: {} elems for shape {shape:?} (want {expect})",
        data.len()
    );
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Pack one argument against its IO spec (shape/dtype validation).
pub fn pack_arg(arg: &Arg, spec: &IoSpec) -> anyhow::Result<Literal> {
    match (arg, spec.dtype) {
        (Arg::F32(data), Dtype::F32) => lit_f32(data, &spec.shape),
        (Arg::I32(data), Dtype::I32) => lit_i32(data, &spec.shape),
        (Arg::ScalarF32(v), Dtype::F32) => {
            anyhow::ensure!(spec.shape.is_empty(), "{}: not a scalar", spec.name);
            Ok(Literal::scalar(*v))
        }
        (Arg::ScalarI32(v), Dtype::I32) => {
            anyhow::ensure!(spec.shape.is_empty(), "{}: not a scalar", spec.name);
            Ok(Literal::scalar(*v))
        }
        (Arg::Lit(l), _) => Ok((*l).clone()),
        (_, want) => anyhow::bail!("{}: dtype mismatch (artifact wants {want:?})", spec.name),
    }
}

/// Pack a full argument list against an artifact's input specs.
pub fn pack_args(args: &[Arg], info: &ArtifactInfo) -> anyhow::Result<Vec<Literal>> {
    anyhow::ensure!(
        args.len() == info.inputs.len(),
        "{}: got {} args, artifact takes {}",
        info.name,
        args.len(),
        info.inputs.len()
    );
    args.iter()
        .zip(info.inputs.iter())
        .map(|(a, s)| pack_arg(a, s).map_err(|e| anyhow::anyhow!("{}: {e}", info.name)))
        .collect()
}

/// Read a literal back as f32s.
pub fn to_f32(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read a scalar f32 result.
pub fn scalar_f32(lit: &Literal) -> anyhow::Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lit_f32_roundtrip() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data.to_vec());
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn lit_i32_roundtrip() {
        let data = [7i32, -8, 9];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data.to_vec());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }

    #[test]
    fn pack_arg_validates_dtype() {
        let spec = IoSpec {
            name: "x".into(),
            shape: vec![2],
            dtype: Dtype::F32,
        };
        assert!(pack_arg(&Arg::F32(&[1.0, 2.0]), &spec).is_ok());
        assert!(pack_arg(&Arg::I32(&[1, 2]), &spec).is_err());
        let scalar = IoSpec {
            name: "lr".into(),
            shape: vec![],
            dtype: Dtype::F32,
        };
        assert!(pack_arg(&Arg::ScalarF32(0.1), &scalar).is_ok());
        assert!(pack_arg(&Arg::ScalarF32(0.1), &spec).is_err());
    }
}

//! PJRT runtime (L3 ↔ artifacts bridge): manifest parsing, artifact
//! compilation + caching, typed execution helpers.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod engine;
pub mod exec;
pub mod manifest;

pub use engine::{Engine, ModelState};
pub use exec::Arg;
pub use manifest::{default_artifacts_dir, Dtype, FamilyInfo, Manifest, TaskKind};

//! The compute runtime layer: the [`Backend`] trait the trainer drives,
//! its two implementations, and the artifact manifest schema.
//!
//!   * [`native::NativeBackend`] (default) — pure-Rust L1 kernels + train
//!     steps; zero native deps, no artifacts, any subset size.
//!   * [`engine::Engine`] (`--features xla`) — PJRT bridge: manifest
//!     parsing, HLO artifact compilation + caching, typed execution.
//!     Pattern adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//!     `HloModuleProto::from_text_file` → `client.compile` → `execute`.

pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod exec;
pub mod manifest;
pub mod merge;
pub mod native;

pub use backend::{forward_scores_rows, Backend, FamilyMeta, FusedForward, TaskKind, Tensor};
pub use merge::average_states;
#[cfg(feature = "xla")]
pub use engine::{Engine, ModelState};
#[cfg(feature = "xla")]
pub use exec::Arg;
pub use manifest::{default_artifacts_dir, Dtype, FamilyInfo, Manifest};
pub use native::{NativeBackend, NativeState};

//! Host-tensor state averaging — the cluster's periodic model merge.
//!
//! Nodes train disjoint stream shards between sync points, then exchange
//! `Backend::export_state` tensors and replace their state with the
//! weighted mean (federated-averaging style). Averaging momentum buffers
//! together with parameters is deliberate: both live in the exported
//! tensor list, and averaged momentum keeps post-merge updates smooth.

use crate::runtime::Tensor;

/// Weighted elementwise mean of several exported state-tensor lists.
/// Every list must have the same arity and shapes; weights must be
/// non-negative with a positive, finite total. The summation order is
/// fixed by the input order, so the result is bit-deterministic.
pub fn average_states(states: &[Vec<Tensor>], weights: &[f64]) -> anyhow::Result<Vec<Tensor>> {
    anyhow::ensure!(!states.is_empty(), "average_states: no states");
    anyhow::ensure!(
        states.len() == weights.len(),
        "average_states: {} states vs {} weights",
        states.len(),
        weights.len()
    );
    let total: f64 = weights.iter().sum();
    anyhow::ensure!(
        total > 0.0 && total.is_finite() && weights.iter().all(|&w| w >= 0.0),
        "average_states: degenerate weights {weights:?}"
    );
    let arity = states[0].len();
    for (i, s) in states.iter().enumerate() {
        anyhow::ensure!(
            s.len() == arity,
            "average_states: state {i} has {} tensors, expected {arity}",
            s.len()
        );
        for (k, t) in s.iter().enumerate() {
            anyhow::ensure!(
                t.shape == states[0][k].shape,
                "average_states: tensor {k} shape {:?} != {:?} (state {i})",
                t.shape,
                states[0][k].shape
            );
        }
    }

    let mut out: Vec<Tensor> = states[0]
        .iter()
        .map(|t| Tensor::zeros(&t.shape))
        .collect();
    for (s, &w) in states.iter().zip(weights.iter()) {
        let frac = (w / total) as f32;
        for (acc, t) in out.iter_mut().zip(s.iter()) {
            for (a, &v) in acc.data.iter_mut().zip(t.data.iter()) {
                *a += frac * v;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], fill: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![fill; shape.iter().product()],
        }
    }

    #[test]
    fn equal_weights_are_the_mean() {
        let a = vec![t(&[2, 2], 1.0), t(&[3], 4.0)];
        let b = vec![t(&[2, 2], 3.0), t(&[3], 0.0)];
        let m = average_states(&[a, b], &[1.0, 1.0]).unwrap();
        assert!(m[0].data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert!(m[1].data.iter().all(|&v| (v - 2.0).abs() < 1e-6));
        assert_eq!(m[0].shape, vec![2, 2]);
    }

    #[test]
    fn weights_bias_the_average() {
        let a = vec![t(&[4], 0.0)];
        let b = vec![t(&[4], 1.0)];
        let m = average_states(&[a, b], &[1.0, 3.0]).unwrap();
        assert!(m[0].data.iter().all(|&v| (v - 0.75).abs() < 1e-6), "{:?}", m[0].data);
    }

    #[test]
    fn single_state_is_identity() {
        let a = vec![t(&[2], 7.5)];
        let m = average_states(std::slice::from_ref(&a), &[2.0]).unwrap();
        assert_eq!(m[0].data, a[0].data);
    }

    #[test]
    fn mismatches_are_rejected() {
        let a = vec![t(&[2], 1.0)];
        let b = vec![t(&[3], 1.0)];
        assert!(average_states(&[a.clone(), b], &[1.0, 1.0]).is_err());
        let c = vec![t(&[2], 1.0), t(&[2], 1.0)];
        assert!(average_states(&[a.clone(), c], &[1.0, 1.0]).is_err());
        assert!(average_states(&[a.clone()], &[0.0]).is_err());
        assert!(average_states(&[a.clone(), a.clone()], &[1.0]).is_err());
        assert!(average_states(&[a], &[-1.0]).is_err());
        assert!(average_states(&[], &[]).is_err());
    }
}

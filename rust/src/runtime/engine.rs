//! The PJRT execution engine: loads HLO-text artifacts, compiles them once
//! (cached), and exposes typed entry points for the trainer hot path.
//!
//! Design notes:
//!   * HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//!     jax≥0.5 serialized protos; the text parser reassigns ids).
//!   * Parameters/momentum live as host `Literal`s inside [`ModelState`] and
//!     are passed by reference each step (no per-step deep copies); data
//!     batches are packed fresh per call (they change every step).

use std::collections::HashMap;
use std::path::Path;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::pipeline::Batch;
use crate::util::timer::PhaseTimer;

use super::backend::{Backend, FamilyMeta, FusedForward};
use super::exec::{pack_arg, scalar_f32, to_f32, Arg};
use super::manifest::{Dtype, FamilyInfo, Manifest};

/// Model parameters + optimizer state, device-format host literals.
pub struct ModelState {
    pub family: String,
    pub params: Vec<Literal>,
    pub mom: Vec<Literal>,
}

impl ModelState {
    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

/// The runtime engine (single-threaded owner of the PJRT client).
pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    /// compile/load accounting, folded into run reports
    pub timer: PhaseTimer,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        log::info!(
            "PJRT client up: platform={} devices={} ({} artifacts)",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            timer: PhaseTimer::default(),
        })
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn load(&mut self, name: &str) -> anyhow::Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let info = self.manifest.artifact(name)?;
            let t0 = std::time::Instant::now();
            let proto = HloModuleProto::from_text_file(
                info.file
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path {:?}", info.file))?,
            )?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.timer.add("compile", t0.elapsed());
            log::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute artifact `name` with typed args; returns the output tuple.
    pub fn run(&mut self, name: &str, args: &[Arg]) -> anyhow::Result<Vec<Literal>> {
        let info = self.manifest.artifact(name)?.clone();
        anyhow::ensure!(
            args.len() == info.inputs.len(),
            "{name}: got {} args, artifact takes {}",
            args.len(),
            info.inputs.len()
        );
        // pack non-literal args; reference resident literals directly
        let mut temps: Vec<(usize, Literal)> = Vec::new();
        for (i, (a, s)) in args.iter().zip(info.inputs.iter()).enumerate() {
            if !matches!(a, Arg::Lit(_)) {
                temps.push((i, pack_arg(a, s).map_err(|e| anyhow::anyhow!("{name}: {e}"))?));
            }
        }
        let mut ptrs: Vec<&Literal> = Vec::with_capacity(args.len());
        let mut ti = 0;
        for (i, a) in args.iter().enumerate() {
            match a {
                Arg::Lit(l) => ptrs.push(l),
                _ => {
                    debug_assert_eq!(temps[ti].0, i);
                    ptrs.push(&temps[ti].1);
                    ti += 1;
                }
            }
        }
        let exe = self.load(name)?;
        let result = exe.execute::<&Literal>(&ptrs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    // ---- typed trainer entry points ---------------------------------------

    /// Run the init artifact: fresh parameters + zero momentum.
    pub fn init_state(&mut self, family: &str, seed: i32) -> anyhow::Result<ModelState> {
        let fam = self.manifest.family(family)?.clone();
        let outs = self.run(&fam.init, &[Arg::ScalarI32(seed)])?;
        let n = fam.n_params();
        anyhow::ensure!(outs.len() == 2 * n, "init returned {} outputs", outs.len());
        let mut outs = outs;
        let mom = outs.split_off(n);
        Ok(ModelState {
            family: family.to_string(),
            params: outs,
            mom,
        })
    }

    fn push_xy<'a>(args: &mut Vec<Arg<'a>>, fam: &FamilyInfo, batch: &'a Batch) {
        let _ = fam;
        if let Some(x) = &batch.x_f32 {
            args.push(Arg::F32(x));
        } else {
            args.push(Arg::I32(batch.x_i32.as_ref().expect("batch missing x")));
        }
        if let Some(y) = &batch.y_f32 {
            args.push(Arg::F32(y));
        } else {
            args.push(Arg::I32(batch.y_i32.as_ref().expect("batch missing y")));
        }
    }

    /// Selection forward pass: per-sample (loss, gnorm) over the full batch.
    pub fn forward(
        &mut self,
        state: &ModelState,
        batch: &Batch,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let fam = self.manifest.family(&state.family)?.clone();
        anyhow::ensure!(
            batch.len() == fam.batch,
            "forward: batch {} != artifact batch {}",
            batch.len(),
            fam.batch
        );
        let mut args: Vec<Arg> = state.params.iter().map(Arg::Lit).collect();
        Self::push_xy(&mut args, &fam, batch);
        let outs = self.run(&fam.fwd.clone(), &args)?;
        Ok((to_f32(&outs[0])?, to_f32(&outs[1])?))
    }

    /// Fused selection pass (perf path): forward + L1 scorer in ONE module.
    /// Returns (loss, gnorm, scores, α[7][B]); `None` if the manifest has
    /// no fused artifact for this family (older artifacts trees).
    #[allow(clippy::type_complexity)]
    pub fn forward_score(
        &mut self,
        state: &ModelState,
        batch: &Batch,
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<Vec<f32>>)>> {
        let fam = self.manifest.family(&state.family)?.clone();
        let Some(name) = fam.fwd_score.clone() else {
            return Ok(None);
        };
        anyhow::ensure!(
            batch.len() == fam.batch,
            "forward_score: batch {} != artifact batch {}",
            batch.len(),
            fam.batch
        );
        let knobs = [t as f32, cl_power, if cl_on { 1.0 } else { 0.0 }];
        let mut args: Vec<Arg> = state.params.iter().map(Arg::Lit).collect();
        Self::push_xy(&mut args, &fam, batch);
        args.push(Arg::F32(&w_full[..]));
        args.push(Arg::F32(&knobs));
        let outs = self.run(&name, &args)?;
        let b = batch.len();
        let loss = to_f32(&outs[0])?;
        let gnorm = to_f32(&outs[1])?;
        let s = to_f32(&outs[2])?;
        let flat = to_f32(&outs[3])?;
        anyhow::ensure!(flat.len() == 7 * b, "fused alpha shape mismatch");
        let alphas = flat.chunks(b).map(|c| c.to_vec()).collect();
        Ok(Some((loss, gnorm, s, alphas)))
    }

    /// One SGD+momentum step on a sub-batch whose size matches a compiled
    /// train artifact; updates `state` in place and returns the mean loss.
    pub fn train_step(
        &mut self,
        state: &mut ModelState,
        sub: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32> {
        let fam = self.manifest.family(&state.family)?.clone();
        let name = fam.train_artifact(sub.len())?.to_string();
        let mut args: Vec<Arg> = state.params.iter().map(Arg::Lit).collect();
        args.extend(state.mom.iter().map(Arg::Lit));
        Self::push_xy(&mut args, &fam, sub);
        args.push(Arg::ScalarF32(lr));
        let mut outs = self.run(&name, &args)?;
        let n = fam.n_params();
        anyhow::ensure!(outs.len() == 2 * n + 1, "train returned {} outputs", outs.len());
        let loss = scalar_f32(&outs[2 * n])?;
        let mom = outs.drain(n..2 * n).collect::<Vec<_>>();
        outs.truncate(n);
        state.params = outs;
        state.mom = mom;
        Ok(loss)
    }

    /// Masked eval pass: (loss_sum, correct_sum) over one padded batch.
    pub fn evaluate(
        &mut self,
        state: &ModelState,
        batch: &Batch,
    ) -> anyhow::Result<(f32, f32)> {
        let fam = self.manifest.family(&state.family)?.clone();
        let mask = batch.mask();
        let mut args: Vec<Arg> = state.params.iter().map(Arg::Lit).collect();
        Self::push_xy(&mut args, &fam, batch);
        args.push(Arg::F32(&mask));
        let outs = self.run(&fam.eval.clone(), &args)?;
        Ok((scalar_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }

    /// Fused AdaSelection scoring on the L1 kernel: returns (s, α[7][B]).
    #[allow(clippy::too_many_arguments)]
    pub fn score(
        &mut self,
        loss: &[f32],
        gnorm: &[f32],
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)> {
        let b = loss.len();
        let name = self.manifest.score_artifact(b)?.name.clone();
        let knobs = [t as f32, cl_power, if cl_on { 1.0 } else { 0.0 }];
        let outs = self.run(
            &name,
            &[
                Arg::F32(loss),
                Arg::F32(gnorm),
                Arg::F32(&w_full[..]),
                Arg::F32(&knobs),
            ],
        )?;
        let s = to_f32(&outs[0])?;
        let flat = to_f32(&outs[1])?;
        anyhow::ensure!(flat.len() == 7 * b, "alpha shape mismatch");
        let alphas = flat.chunks(b).map(|c| c.to_vec()).collect();
        Ok((s, alphas))
    }

    /// Pre-compile everything a run will need (keeps compile time out of
    /// the timed training loop).
    pub fn preload_family(&mut self, family: &str, sizes: &[usize]) -> anyhow::Result<()> {
        let fam = self.manifest.family(family)?.clone();
        self.load(&fam.init)?;
        self.load(&fam.fwd)?;
        self.load(&fam.eval)?;
        for &k in sizes {
            let name = fam.train_artifact(k)?.to_string();
            self.load(&name)?;
        }
        if let Ok(info) = self.manifest.score_artifact(fam.batch) {
            let name = info.name.clone();
            self.load(&name)?;
        }
        Ok(())
    }

    /// Number of f32 parameters in a family (reporting).
    pub fn param_count(&self, family: &str) -> anyhow::Result<usize> {
        Ok(self
            .manifest
            .family(family)?
            .params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum())
    }

    /// Validate the frozen method order against the selection module.
    pub fn check_method_order(&self) -> anyhow::Result<()> {
        let want: Vec<&str> = crate::selection::Method::ALL
            .iter()
            .map(|m| m.name())
            .collect();
        let got: Vec<&str> = self.manifest.method_order.iter().map(|s| s.as_str()).collect();
        anyhow::ensure!(
            got == want,
            "manifest method order {got:?} != rust order {want:?}"
        );
        Ok(())
    }

    /// Expose dtype of an artifact input (diagnostics).
    pub fn input_dtype(&self, artifact: &str, idx: usize) -> anyhow::Result<Dtype> {
        Ok(self.manifest.artifact(artifact)?.inputs[idx].dtype)
    }
}

/// The PJRT engine as a [`Backend`]: thin delegation onto the typed entry
/// points above. `State` stays in device-literal format so the hot loop
/// passes parameters by reference with no per-step conversion.
impl Backend for Engine {
    type State = ModelState;

    fn name(&self) -> &'static str {
        "xla"
    }

    fn family_meta(&self, family: &str) -> anyhow::Result<FamilyMeta> {
        let fam = self.manifest.family(family)?;
        Ok(FamilyMeta {
            name: fam.name.clone(),
            task: fam.task,
            batch: fam.batch,
            sizes: Some(fam.train_sizes.clone()),
        })
    }

    fn init_state(&mut self, family: &str, seed: i32) -> anyhow::Result<ModelState> {
        Engine::init_state(self, family, seed)
    }

    fn forward_scores(
        &mut self,
        state: &ModelState,
        batch: &Batch,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        self.forward(state, batch)
    }

    fn forward_score_fused(
        &mut self,
        state: &ModelState,
        batch: &Batch,
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<Option<FusedForward>> {
        Ok(self
            .forward_score(state, batch, w_full, t, cl_power, cl_on)?
            .map(|(loss, gnorm, scores, alphas)| FusedForward {
                loss,
                gnorm,
                scores,
                alphas,
            }))
    }

    fn train_step(
        &mut self,
        state: &mut ModelState,
        sub: &Batch,
        lr: f32,
    ) -> anyhow::Result<f32> {
        Engine::train_step(self, state, sub, lr)
    }

    fn eval(&mut self, state: &ModelState, batch: &Batch) -> anyhow::Result<(f32, f32)> {
        self.evaluate(state, batch)
    }

    fn score(
        &mut self,
        loss: &[f32],
        gnorm: &[f32],
        w_full: &[f32; 7],
        t: usize,
        cl_power: f32,
        cl_on: bool,
    ) -> anyhow::Result<(Vec<f32>, Vec<Vec<f32>>)> {
        Engine::score(self, loss, gnorm, w_full, t, cl_power, cl_on)
    }

    fn preload_family(&mut self, family: &str, sizes: &[usize]) -> anyhow::Result<()> {
        Engine::preload_family(self, family, sizes)
    }

    fn param_count(&self, family: &str) -> anyhow::Result<usize> {
        Engine::param_count(self, family)
    }

    fn validate(&self) -> anyhow::Result<()> {
        self.check_method_order()
    }
}

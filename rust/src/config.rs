//! Typed run configuration: JSON config files + CLI overrides + validation.
//!
//! One [`RunConfig`] fully determines a training run (dataset, model family
//! via the dataset, selection policy, sampling rate, schedule, pipeline
//! knobs, seeds) — the harness sweeps are lists of `RunConfig`s, and every
//! report embeds the originating config for provenance.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::util::json::Json;

/// Configuration of a single training run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// compute backend: native (pure Rust, default) | xla (PJRT artifacts,
    /// needs `--features xla`)
    pub backend: String,
    /// dataset name: cifar10|cifar100|svhn|simple|bike|wikitext
    pub dataset: String,
    /// selector spec: benchmark | <method> | adaselection[:m1+m2...]
    pub selector: String,
    /// sampling rate γ ∈ (0, 1]
    pub gamma: f64,
    /// eq. 3 β ∈ [-1, 1]
    pub beta: f32,
    /// curriculum reward on/off + exponent (eq. 4)
    pub cl_on: bool,
    pub cl_power: f32,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
    /// scales the paper's dataset sizes down to CPU budgets
    pub data_scale: f64,
    /// pipeline workers / prefetch capacity
    pub workers: usize,
    pub capacity: usize,
    /// Alg-2 lines 8–11: accumulate selections until |C| = B (true) or
    /// update immediately on each sub-batch (false, default)
    pub accumulate: bool,
    /// score α on the L1 Pallas kernel (true) or the host oracle (false)
    pub kernel_scorer: bool,
    /// weight-update rule: eq3[:beta] | exp3[:eta] | softmax[:tau]
    pub rule: String,
    /// stale-loss cache window in epochs (0 = always run the selection
    /// forward pass; paper §5 future-work approximation)
    pub stale_refresh: u32,
    /// AdaSelection-signal early stopping (paper §5 future-work)
    pub early_stop: bool,
    pub patience: usize,
    pub artifacts_dir: PathBuf,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            backend: "native".into(),
            dataset: "cifar10".into(),
            selector: "adaselection".into(),
            gamma: 0.2,
            beta: 0.5,
            cl_on: true,
            cl_power: -0.5,
            epochs: 3,
            lr: 0.01,
            seed: 42,
            data_scale: 0.02,
            workers: 2,
            capacity: 8,
            accumulate: false,
            // CPU default: host-oracle scoring. The L1 kernel path
            // (kernel_scorer=true) is numerically equivalent (tested) but
            // interpret-mode pallas inside XLA costs ~14ms/batch on CPU;
            // on real TPU the fused kernel path is the fast one
            // (EXPERIMENTS.md §Perf).
            kernel_scorer: false,
            rule: "eq3".into(),
            stale_refresh: 0,
            early_stop: false,
            patience: 3,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }
}

impl RunConfig {
    /// Sanity-check ranges before a run starts.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.backend == "native" || self.backend == "xla",
            "unknown backend '{}' (expected native|xla)",
            self.backend
        );
        anyhow::ensure!(
            self.gamma > 0.0 && self.gamma <= 1.0,
            "gamma {} outside (0, 1]",
            self.gamma
        );
        anyhow::ensure!(
            (-1.0..=1.0).contains(&self.beta),
            "beta {} outside [-1, 1] (paper range)",
            self.beta
        );
        anyhow::ensure!(self.epochs > 0, "epochs must be > 0");
        anyhow::ensure!(self.lr > 0.0, "lr must be > 0");
        anyhow::ensure!(
            self.data_scale > 0.0 && self.data_scale <= 1.0,
            "data_scale {} outside (0, 1]",
            self.data_scale
        );
        crate::data::family_for(&self.dataset)?;
        crate::selection::bandit::UpdateRule::parse(&self.rule)?;
        crate::selection::build_selector(
            &self.selector,
            self.seed,
            self.beta,
            self.cl_on,
            self.cl_power,
        )?;
        Ok(())
    }

    /// Apply `--key value` overrides (CLI surface).
    pub fn apply_override(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "backend" => self.backend = value.into(),
            "dataset" => self.dataset = value.into(),
            "selector" | "method" => self.selector = value.into(),
            "gamma" => self.gamma = value.parse()?,
            "beta" => self.beta = value.parse()?,
            "cl" => self.cl_on = parse_bool(value)?,
            "cl-power" => self.cl_power = value.parse()?,
            "epochs" => self.epochs = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "data-scale" => self.data_scale = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "capacity" => self.capacity = value.parse()?,
            "accumulate" => self.accumulate = parse_bool(value)?,
            "kernel-scorer" => self.kernel_scorer = parse_bool(value)?,
            "rule" => self.rule = value.into(),
            "stale-refresh" => self.stale_refresh = value.parse()?,
            "early-stop" => self.early_stop = parse_bool(value)?,
            "patience" => self.patience = value.parse()?,
            "artifacts" => self.artifacts_dir = PathBuf::from(value),
            other => anyhow::bail!("unknown config key '--{other}'"),
        }
        Ok(())
    }

    /// Load a JSON config file, then validate.
    pub fn from_json(j: &Json) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        for (k, v) in j.as_obj()? {
            let val = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => anyhow::bail!("config key {k}: unsupported value {other:?}"),
            };
            cfg.apply_override(k, &val)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    /// Serialize for provenance in reports.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("backend".into(), Json::Str(self.backend.clone()));
        m.insert("dataset".into(), Json::Str(self.dataset.clone()));
        m.insert("selector".into(), Json::Str(self.selector.clone()));
        m.insert("gamma".into(), Json::Num(self.gamma));
        m.insert("beta".into(), Json::Num(self.beta as f64));
        m.insert("cl".into(), Json::Bool(self.cl_on));
        m.insert("cl-power".into(), Json::Num(self.cl_power as f64));
        m.insert("epochs".into(), Json::Num(self.epochs as f64));
        m.insert("lr".into(), Json::Num(self.lr as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("data-scale".into(), Json::Num(self.data_scale));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("capacity".into(), Json::Num(self.capacity as f64));
        m.insert("accumulate".into(), Json::Bool(self.accumulate));
        m.insert("kernel-scorer".into(), Json::Bool(self.kernel_scorer));
        m.insert("rule".into(), Json::Str(self.rule.clone()));
        m.insert("stale-refresh".into(), Json::Num(self.stale_refresh as f64));
        m.insert("early-stop".into(), Json::Bool(self.early_stop));
        m.insert("patience".into(), Json::Num(self.patience as f64));
        Json::Obj(m)
    }
}

/// Configuration of a streaming continuous-training run (the `stream`
/// subcommand): unbounded epochless source, bounded instance store,
/// checkpoint/resume. See `stream::trainer`.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// compute backend: native (default) | xla
    pub backend: String,
    /// stream name: drift-class|drift-reg|drift-lm
    pub dataset: String,
    /// selector spec (same grammar as [`RunConfig::selector`])
    pub selector: String,
    /// OBFTF candidate multiplier: forward-score up to `obftf_k·⌈γB⌉`
    /// rows, backward only on the top ⌈γB⌉ (paper's forward-cheap mode)
    pub obftf_k: usize,
    /// sampling rate γ ∈ (0, 1]
    pub gamma: f64,
    pub beta: f32,
    pub cl_on: bool,
    pub cl_power: f32,
    pub lr: f32,
    pub seed: u64,
    /// stop after this many ticks (micro-batches); the stream itself is
    /// unbounded
    pub max_ticks: usize,
    /// pipeline workers / prefetch capacity (loader unbounded mode)
    pub workers: usize,
    pub capacity: usize,
    /// instance-store hard capacity (records) and shard count
    pub store_capacity: usize,
    pub store_shards: usize,
    /// ticks per concept-drift cycle (0 = stationary)
    pub drift_period: u64,
    /// arrival-burst modulation period in ticks (0 = constant full chunks)
    pub burst_period: u64,
    /// fraction of B arriving at the deepest lull, in (0, 1]
    pub burst_min: f64,
    /// rolling-window size (ticks) for prequential loss/accuracy
    pub window: usize,
    /// prequential-eval cadence in ticks (0 = no eval passes)
    pub eval_every: usize,
    /// weight-update rule: eq3[:beta] | exp3[:eta] | softmax[:tau]
    pub rule: String,
    /// drift detection on the per-tick mean loss, boosting γ and the
    /// method-weight learning rate while drift is fresh:
    /// off | page-hinkley | adwin (legacy booleans map to
    /// off/page-hinkley)
    pub drift_detect: String,
    /// top up lull ticks with high-loss instance-store rows so the
    /// training budget ⌈γB⌉ stays filled during arrival dips
    pub replay: bool,
    /// checkpoint file (written every `checkpoint_every` ticks + at the
    /// end; also the file `resume` reads)
    pub checkpoint: Option<PathBuf>,
    pub checkpoint_every: usize,
    /// continue from `checkpoint` instead of starting fresh
    pub resume: bool,
    /// structured trace journal path (schema-v1 JSONL, one event per
    /// processed tick; see `obs::trace`). Off the digest path: tracing
    /// on/off never changes selection.
    pub trace: Option<PathBuf>,
    /// serve Prometheus `/metrics` + JSON `/status` + `/profile` on this
    /// address (e.g. `127.0.0.1:9464`; port 0 picks an ephemeral port)
    pub status_addr: Option<String>,
    /// fleet health rule engine (see `obs::health`): off (default) |
    /// warn (evaluate + journal alerts) | strict (warn + exit nonzero if
    /// any alert is still firing when the run ends; CI gate)
    pub health: String,
    pub artifacts_dir: PathBuf,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            backend: "native".into(),
            dataset: "drift-class".into(),
            selector: "adaselection".into(),
            obftf_k: 10,
            gamma: 0.5,
            beta: 0.5,
            cl_on: true,
            cl_power: -0.5,
            lr: 0.05,
            seed: 42,
            max_ticks: 500,
            workers: 2,
            capacity: 8,
            store_capacity: 65_536,
            store_shards: 16,
            drift_period: 256,
            burst_period: 64,
            burst_min: 0.25,
            window: 50,
            eval_every: 1,
            rule: "eq3".into(),
            drift_detect: "off".into(),
            replay: false,
            checkpoint: None,
            checkpoint_every: 0,
            resume: false,
            trace: None,
            status_addr: None,
            health: "off".into(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }
}

impl StreamConfig {
    /// Sanity-check ranges before a run starts.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.backend == "native" || self.backend == "xla",
            "unknown backend '{}' (expected native|xla)",
            self.backend
        );
        anyhow::ensure!(
            self.gamma > 0.0 && self.gamma <= 1.0,
            "gamma {} outside (0, 1]",
            self.gamma
        );
        anyhow::ensure!(
            (-1.0..=1.0).contains(&self.beta),
            "beta {} outside [-1, 1] (paper range)",
            self.beta
        );
        anyhow::ensure!(self.lr > 0.0, "lr must be > 0");
        anyhow::ensure!(self.max_ticks > 0, "max-ticks must be > 0");
        anyhow::ensure!(self.store_capacity > 0, "store-capacity must be > 0");
        anyhow::ensure!(self.store_shards > 0, "store-shards must be > 0");
        anyhow::ensure!(
            self.burst_min > 0.0 && self.burst_min <= 1.0,
            "burst-min {} outside (0, 1]",
            self.burst_min
        );
        anyhow::ensure!(self.window > 0, "window must be > 0");
        anyhow::ensure!(
            !self.resume || self.checkpoint.is_some(),
            "--resume requires --checkpoint FILE"
        );
        anyhow::ensure!(self.obftf_k >= 1, "obftf-k must be >= 1");
        crate::obs::health::HealthMode::parse(&self.health)?;
        crate::stream::source::family_for(&self.dataset)?;
        crate::stream::tick::DriftKind::parse(&self.drift_detect)?;
        crate::selection::bandit::UpdateRule::parse(&self.rule)?;
        crate::selection::build_policy_full(
            &self.selector,
            self.seed,
            self.beta,
            self.cl_on,
            self.cl_power,
            self.obftf_k,
        )?;
        Ok(())
    }

    /// Apply `--key value` overrides (CLI surface).
    pub fn apply_override(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "backend" => self.backend = value.into(),
            "dataset" => self.dataset = value.into(),
            // `--method` is the reader-friendly alias the paper tables use
            "selector" | "method" => self.selector = value.into(),
            "obftf-k" => self.obftf_k = value.parse()?,
            "gamma" => self.gamma = value.parse()?,
            "beta" => self.beta = value.parse()?,
            "cl" => self.cl_on = parse_bool(value)?,
            "cl-power" => self.cl_power = value.parse()?,
            "lr" => self.lr = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "max-ticks" => self.max_ticks = value.parse()?,
            "workers" => self.workers = value.parse()?,
            "capacity" => self.capacity = value.parse()?,
            "store-capacity" => self.store_capacity = value.parse()?,
            "store-shards" => self.store_shards = value.parse()?,
            "drift-period" => self.drift_period = value.parse()?,
            "burst-period" => self.burst_period = value.parse()?,
            "burst-min" => self.burst_min = value.parse()?,
            "window" => self.window = value.parse()?,
            "eval-every" => self.eval_every = value.parse()?,
            "rule" => self.rule = value.into(),
            // legacy boolean values picked the only detector there was;
            // keep them working (`--drift-detect on` in older scripts)
            "drift-detect" => {
                self.drift_detect = match value {
                    "true" | "1" | "yes" | "on" => "page-hinkley".to_string(),
                    "false" | "0" | "no" | "off" => "off".to_string(),
                    other => other.to_string(),
                }
            }
            "replay" => self.replay = parse_bool(value)?,
            "checkpoint" => self.checkpoint = Some(PathBuf::from(value)),
            "checkpoint-every" => self.checkpoint_every = value.parse()?,
            "resume" => self.resume = parse_bool(value)?,
            "trace" => self.trace = Some(PathBuf::from(value)),
            "status-addr" => self.status_addr = Some(value.into()),
            "health" => self.health = value.into(),
            "artifacts" => self.artifacts_dir = PathBuf::from(value),
            other => anyhow::bail!("unknown stream config key '--{other}'"),
        }
        Ok(())
    }

    /// Load a JSON config file, then validate.
    pub fn from_json(j: &Json) -> anyhow::Result<StreamConfig> {
        let mut cfg = StreamConfig::default();
        for (k, v) in j.as_obj()? {
            let val = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => anyhow::bail!("stream config key {k}: unsupported value {other:?}"),
            };
            cfg.apply_override(k, &val)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<StreamConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    /// The subset of the config that determines the *identity* of a run's
    /// traffic and selection sequence — what must match between the run
    /// that wrote a checkpoint and the run resuming it. Deliberately
    /// excludes budget/operational knobs (`max_ticks`, `lr`, workers,
    /// capacities, eval cadence) that an operator legitimately changes
    /// when extending a run.
    pub fn identity_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("dataset".into(), Json::Str(self.dataset.clone()));
        m.insert("selector".into(), Json::Str(self.selector.clone()));
        // the candidate multiplier changes which rows get scored, hence
        // the selection sequence
        m.insert("obftf-k".into(), Json::Num(self.obftf_k as f64));
        m.insert("gamma".into(), Json::Num(self.gamma));
        m.insert("beta".into(), Json::Num(self.beta as f64));
        m.insert("cl".into(), Json::Bool(self.cl_on));
        m.insert("cl-power".into(), Json::Num(self.cl_power as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("drift-period".into(), Json::Num(self.drift_period as f64));
        m.insert("burst-period".into(), Json::Num(self.burst_period as f64));
        m.insert("burst-min".into(), Json::Num(self.burst_min));
        m.insert("rule".into(), Json::Str(self.rule.clone()));
        // both alter the selection/training sequence, so they are part of
        // the run identity a resume must match
        m.insert("drift-detect".into(), Json::Str(self.drift_detect.clone()));
        m.insert("replay".into(), Json::Bool(self.replay));
        Json::Obj(m)
    }

    /// Serialize for provenance in reports.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("backend".into(), Json::Str(self.backend.clone()));
        m.insert("dataset".into(), Json::Str(self.dataset.clone()));
        m.insert("selector".into(), Json::Str(self.selector.clone()));
        m.insert("obftf-k".into(), Json::Num(self.obftf_k as f64));
        m.insert("gamma".into(), Json::Num(self.gamma));
        m.insert("beta".into(), Json::Num(self.beta as f64));
        m.insert("cl".into(), Json::Bool(self.cl_on));
        m.insert("cl-power".into(), Json::Num(self.cl_power as f64));
        m.insert("lr".into(), Json::Num(self.lr as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("max-ticks".into(), Json::Num(self.max_ticks as f64));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("capacity".into(), Json::Num(self.capacity as f64));
        m.insert("store-capacity".into(), Json::Num(self.store_capacity as f64));
        m.insert("store-shards".into(), Json::Num(self.store_shards as f64));
        m.insert("drift-period".into(), Json::Num(self.drift_period as f64));
        m.insert("burst-period".into(), Json::Num(self.burst_period as f64));
        m.insert("burst-min".into(), Json::Num(self.burst_min));
        m.insert("window".into(), Json::Num(self.window as f64));
        m.insert("eval-every".into(), Json::Num(self.eval_every as f64));
        m.insert("rule".into(), Json::Str(self.rule.clone()));
        m.insert("drift-detect".into(), Json::Str(self.drift_detect.clone()));
        m.insert("replay".into(), Json::Bool(self.replay));
        if let Some(p) = &self.checkpoint {
            m.insert("checkpoint".into(), Json::Str(p.display().to_string()));
        }
        m.insert(
            "checkpoint-every".into(),
            Json::Num(self.checkpoint_every as f64),
        );
        m.insert("resume".into(), Json::Bool(self.resume));
        // operational telemetry knobs: serialized for provenance (and so
        // process workers inherit them via the Assign config payload) but
        // deliberately NOT part of identity_json — telemetry must never
        // gate a resume
        if let Some(p) = &self.trace {
            m.insert("trace".into(), Json::Str(p.display().to_string()));
        }
        if let Some(a) = &self.status_addr {
            m.insert("status-addr".into(), Json::Str(a.clone()));
        }
        if self.health != "off" {
            m.insert("health".into(), Json::Str(self.health.clone()));
        }
        Json::Obj(m)
    }
}

/// Configuration of a multi-node cluster run (the `cluster` subcommand):
/// N in-process worker nodes sharding one stream through a consistent-hash
/// ring, with periodic store gossip and model/policy merge, plus an
/// optional deterministic kill/join churn schedule. All stream-level knobs
/// ride in `stream`; unknown `--key` overrides fall through to it.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub stream: StreamConfig,
    /// worker nodes at start
    pub nodes: usize,
    /// virtual nodes per worker on the hash ring
    pub vnodes: usize,
    /// how workers run: threads (in-process nodes on scoped threads) |
    /// processes (one OS process per node, coordinated over the
    /// `cluster::wire` control plane). `--workers threads|processes` on
    /// the CLI; a numeric `--workers N` still sets the pipeline worker
    /// count.
    pub worker_mode: String,
    /// node-to-node transport: loopback (in-process mailboxes) | tcp
    /// (127.0.0.1 sockets speaking the `cluster::wire` frame format).
    /// Process workers always talk wire frames over their coordinator
    /// sockets; this knob only selects the thread-mode transport.
    pub transport: String,
    /// store-gossip payload: full (whole snapshots every round) | delta
    /// (only entries touched since the last sync, with a periodic
    /// full-snapshot fallback and full snapshots on join)
    pub gossip: String,
    /// ticks between store-gossip rounds (0 = never)
    pub gossip_every: usize,
    /// in delta mode, every K-th gossip round still ships full snapshots
    /// so evicting or late-joining peers reconverge (K ≥ 1)
    pub full_gossip_every: usize,
    /// ticks between model/policy merges (0 = never)
    pub merge_every: usize,
    /// tick at which `kill_node` is removed (0 = no kill)
    pub kill_at: usize,
    pub kill_node: usize,
    /// tick at which a fresh node joins the ring (0 = no join)
    pub join_at: usize,
    /// crash injection (process workers only): SIGKILL `chaos_kill_node`
    /// while the segment containing this tick runs (0 = off). Unlike
    /// `kill_at` this is *not* in the precompiled ring schedule — the
    /// coordinator must detect the death and convert it to churn.
    pub chaos_kill_at: usize,
    pub chaos_kill_node: usize,
    /// straggler injection (process workers only): `chaos_straggler_node`
    /// sleeps this many milliseconds at every barrier segment, inflating
    /// its ready lag without touching training state (0 = off). This is
    /// how the health e2e makes `straggler_ready_lag` fire on demand.
    pub chaos_straggler_ms: usize,
    pub chaos_straggler_node: usize,
    /// control-plane listen address for process workers (e.g.
    /// `0.0.0.0:7400`); None binds an ephemeral loopback port. A fixed
    /// address lets `adaselection worker --coordinator HOST:PORT` register
    /// from any machine (process workers only).
    pub listen: Option<String>,
    /// spawn the worker processes locally (default). With `--spawn off`
    /// the coordinator spawns nothing and waits for `nodes` external
    /// workers to register on `listen` instead.
    pub spawn: bool,
    /// elastic scale-out: admit a registered standby worker when the
    /// cluster-wide arrival rate (samples per tick, measured between
    /// barriers) rises above this watermark (0 = off; process workers
    /// only)
    pub elastic_admit_above: f64,
    /// elastic scale-in: shed the worst straggler when the arrival rate
    /// falls below this watermark (0 = off; process workers only)
    pub elastic_shed_below: f64,
    /// never shed below this many alive workers
    pub elastic_min_nodes: usize,
    /// never admit above this many alive workers (0 = unlimited)
    pub elastic_max_nodes: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            stream: StreamConfig::default(),
            nodes: 4,
            vnodes: 128,
            worker_mode: "threads".into(),
            transport: "loopback".into(),
            gossip: "full".into(),
            gossip_every: 16,
            full_gossip_every: 8,
            merge_every: 16,
            kill_at: 0,
            kill_node: 0,
            join_at: 0,
            chaos_kill_at: 0,
            chaos_kill_node: 0,
            chaos_straggler_ms: 0,
            chaos_straggler_node: 0,
            listen: None,
            spawn: true,
            elastic_admit_above: 0.0,
            elastic_shed_below: 0.0,
            elastic_min_nodes: 1,
            elastic_max_nodes: 0,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        self.stream.validate()?;
        anyhow::ensure!(self.nodes >= 1, "cluster needs at least 1 node");
        anyhow::ensure!(
            (1..=1024).contains(&self.vnodes),
            "vnodes {} outside 1..=1024",
            self.vnodes
        );
        anyhow::ensure!(
            self.worker_mode == "threads" || self.worker_mode == "processes",
            "unknown worker mode '{}' (expected threads|processes)",
            self.worker_mode
        );
        anyhow::ensure!(
            self.transport == "loopback" || self.transport == "tcp",
            "unknown transport '{}' (expected loopback|tcp)",
            self.transport
        );
        anyhow::ensure!(
            self.gossip == "full" || self.gossip == "delta",
            "unknown gossip mode '{}' (expected full|delta)",
            self.gossip
        );
        anyhow::ensure!(
            self.full_gossip_every >= 1,
            "full-gossip-every must be >= 1 (got {})",
            self.full_gossip_every
        );
        if self.worker_mode == "processes" {
            anyhow::ensure!(
                self.stream.backend == "native",
                "process workers run the native backend only (got '{}')",
                self.stream.backend
            );
            anyhow::ensure!(
                !self.stream.dataset.starts_with("tcp:"),
                "process workers cannot share a tcp: stream feed (each \
                 worker process would consume the socket independently); \
                 capture it to a file: log first"
            );
            anyhow::ensure!(
                self.chaos_kill_at < self.stream.max_ticks,
                "chaos-kill-at {} beyond max-ticks {}",
                self.chaos_kill_at,
                self.stream.max_ticks
            );
            if self.chaos_kill_at > 0 {
                anyhow::ensure!(
                    self.chaos_kill_node < self.nodes,
                    "chaos-kill-node {} out of range 0..{}",
                    self.chaos_kill_node,
                    self.nodes
                );
                anyhow::ensure!(self.nodes > 1, "chaos-killing the only worker");
                anyhow::ensure!(
                    self.kill_at == 0 || self.kill_node != self.chaos_kill_node,
                    "chaos-kill-node and kill-node target the same worker"
                );
            }
            if self.chaos_straggler_ms > 0 {
                anyhow::ensure!(
                    self.chaos_straggler_node < self.nodes,
                    "chaos-straggler-node {} out of range 0..{}",
                    self.chaos_straggler_node,
                    self.nodes
                );
            }
        } else {
            anyhow::ensure!(
                self.chaos_kill_at == 0,
                "chaos-kill-at requires --workers processes"
            );
            anyhow::ensure!(
                self.chaos_straggler_ms == 0,
                "chaos-straggler-ms requires --workers processes"
            );
            anyhow::ensure!(
                self.listen.is_none(),
                "--listen requires --workers processes"
            );
            anyhow::ensure!(self.spawn, "--spawn off requires --workers processes");
            anyhow::ensure!(
                self.elastic_admit_above == 0.0 && self.elastic_shed_below == 0.0,
                "elastic watermarks require --workers processes"
            );
        }
        anyhow::ensure!(
            self.spawn || self.listen.is_some(),
            "--spawn off needs --listen ADDR so external workers can register"
        );
        anyhow::ensure!(
            self.elastic_admit_above >= 0.0 && self.elastic_shed_below >= 0.0,
            "elastic watermarks must be >= 0"
        );
        anyhow::ensure!(
            self.elastic_min_nodes >= 1,
            "elastic-min-nodes must be >= 1"
        );
        anyhow::ensure!(
            self.elastic_max_nodes == 0 || self.elastic_max_nodes >= self.nodes,
            "elastic-max-nodes {} below the starting node count {}",
            self.elastic_max_nodes,
            self.nodes
        );
        if self.transport == "tcp" || self.worker_mode == "processes" {
            // the store's hard bound after rounding is ≤ max(capacity,
            // 2·shards); a full-snapshot gossip of that many entries must
            // fit in one wire frame, or the run would die at the first
            // full gossip barrier instead of failing here up front
            let worst = self.stream.store_capacity.max(2 * self.stream.store_shards);
            let cap = crate::cluster::wire::max_gossip_entries();
            anyhow::ensure!(
                worst <= cap,
                "store-capacity {worst} exceeds the {cap} entries a wire gossip frame can carry"
            );
        }
        anyhow::ensure!(
            self.kill_at < self.stream.max_ticks,
            "kill-at {} beyond max-ticks {}",
            self.kill_at,
            self.stream.max_ticks
        );
        anyhow::ensure!(
            self.join_at < self.stream.max_ticks,
            "join-at {} beyond max-ticks {}",
            self.join_at,
            self.stream.max_ticks
        );
        if self.kill_at > 0 {
            anyhow::ensure!(
                self.kill_node < self.nodes,
                "kill-node {} out of range 0..{}",
                self.kill_node,
                self.nodes
            );
            anyhow::ensure!(
                self.nodes > 1 || self.join_at > 0,
                "killing the only node would leave the ring empty"
            );
            if self.nodes == 1 {
                // the coordinator processes a kill before a join at the
                // same barrier, so the join must happen strictly earlier
                anyhow::ensure!(
                    self.join_at < self.kill_at,
                    "single-node cluster: the join must happen before the kill"
                );
            }
        }
        anyhow::ensure!(
            self.stream.checkpoint.is_none() && !self.stream.resume,
            "cluster runs do not support checkpoints yet"
        );
        Ok(())
    }

    /// Apply `--key value` overrides; non-cluster keys fall through to the
    /// embedded [`StreamConfig`].
    pub fn apply_override(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "nodes" => self.nodes = value.parse()?,
            "vnodes" => self.vnodes = value.parse()?,
            // `--workers` is overloaded on purpose: a mode name selects the
            // worker runtime, a number keeps meaning pipeline workers
            "workers" if value == "threads" || value == "processes" => {
                self.worker_mode = value.into()
            }
            "worker-mode" => self.worker_mode = value.into(),
            "transport" => self.transport = value.into(),
            "gossip" => self.gossip = value.into(),
            "gossip-every" => self.gossip_every = value.parse()?,
            "full-gossip-every" => self.full_gossip_every = value.parse()?,
            "merge-every" => self.merge_every = value.parse()?,
            "kill-at" => self.kill_at = value.parse()?,
            "kill-node" => self.kill_node = value.parse()?,
            "join-at" => self.join_at = value.parse()?,
            "chaos-kill-at" => self.chaos_kill_at = value.parse()?,
            "chaos-kill-node" => self.chaos_kill_node = value.parse()?,
            "chaos-straggler-ms" => self.chaos_straggler_ms = value.parse()?,
            "chaos-straggler-node" => self.chaos_straggler_node = value.parse()?,
            "listen" => self.listen = Some(value.into()),
            "spawn" => self.spawn = parse_bool(value)?,
            "elastic-admit-above" => self.elastic_admit_above = value.parse()?,
            "elastic-shed-below" => self.elastic_shed_below = value.parse()?,
            "elastic-min-nodes" => self.elastic_min_nodes = value.parse()?,
            "elastic-max-nodes" => self.elastic_max_nodes = value.parse()?,
            other => return self.stream.apply_override(other, value),
        }
        Ok(())
    }

    /// Load a JSON config file, then validate.
    pub fn from_json(j: &Json) -> anyhow::Result<ClusterConfig> {
        let mut cfg = ClusterConfig::default();
        for (k, v) in j.as_obj()? {
            let val = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => anyhow::bail!("cluster config key {k}: unsupported value {other:?}"),
            };
            cfg.apply_override(k, &val)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<ClusterConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }

    /// Serialize for provenance in reports.
    pub fn to_json(&self) -> Json {
        let mut m = match self.stream.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("StreamConfig::to_json returns an object"),
        };
        m.insert("nodes".into(), Json::Num(self.nodes as f64));
        m.insert("vnodes".into(), Json::Num(self.vnodes as f64));
        m.insert("worker-mode".into(), Json::Str(self.worker_mode.clone()));
        m.insert("transport".into(), Json::Str(self.transport.clone()));
        m.insert("gossip".into(), Json::Str(self.gossip.clone()));
        m.insert("gossip-every".into(), Json::Num(self.gossip_every as f64));
        m.insert(
            "full-gossip-every".into(),
            Json::Num(self.full_gossip_every as f64),
        );
        m.insert("merge-every".into(), Json::Num(self.merge_every as f64));
        m.insert("kill-at".into(), Json::Num(self.kill_at as f64));
        m.insert("kill-node".into(), Json::Num(self.kill_node as f64));
        m.insert("join-at".into(), Json::Num(self.join_at as f64));
        m.insert("chaos-kill-at".into(), Json::Num(self.chaos_kill_at as f64));
        m.insert(
            "chaos-kill-node".into(),
            Json::Num(self.chaos_kill_node as f64),
        );
        m.insert(
            "chaos-straggler-ms".into(),
            Json::Num(self.chaos_straggler_ms as f64),
        );
        m.insert(
            "chaos-straggler-node".into(),
            Json::Num(self.chaos_straggler_node as f64),
        );
        if let Some(a) = &self.listen {
            m.insert("listen".into(), Json::Str(a.clone()));
        }
        m.insert("spawn".into(), Json::Bool(self.spawn));
        m.insert(
            "elastic-admit-above".into(),
            Json::Num(self.elastic_admit_above),
        );
        m.insert(
            "elastic-shed-below".into(),
            Json::Num(self.elastic_shed_below),
        );
        m.insert(
            "elastic-min-nodes".into(),
            Json::Num(self.elastic_min_nodes as f64),
        );
        m.insert(
            "elastic-max-nodes".into(),
            Json::Num(self.elastic_max_nodes as f64),
        );
        Json::Obj(m)
    }
}

fn parse_bool(s: &str) -> anyhow::Result<bool> {
    match s {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => anyhow::bail!("expected bool, got '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply_and_validate() {
        let mut cfg = RunConfig::default();
        cfg.apply_override("dataset", "bike").unwrap();
        cfg.apply_override("gamma", "0.4").unwrap();
        cfg.apply_override("selector", "big_loss").unwrap();
        cfg.apply_override("cl", "off").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.dataset, "bike");
        assert!((cfg.gamma - 0.4).abs() < 1e-12);
        assert!(!cfg.cl_on);
    }

    #[test]
    fn bad_values_rejected() {
        let mut cfg = RunConfig::default();
        assert!(cfg.apply_override("gamma", "abc").is_err());
        assert!(cfg.apply_override("nope", "1").is_err());
        cfg.gamma = 0.0;
        assert!(cfg.validate().is_err());
        cfg.gamma = 0.2;
        cfg.beta = 2.0;
        assert!(cfg.validate().is_err());
        cfg.beta = 0.5;
        cfg.dataset = "mnist".into();
        assert!(cfg.validate().is_err());
        cfg.dataset = "cifar10".into();
        cfg.selector = "bogus".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_round_trip() {
        let mut cfg = RunConfig::default();
        cfg.dataset = "svhn".into();
        cfg.gamma = 0.3;
        cfg.accumulate = true;
        let j = cfg.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back.dataset, "svhn");
        assert!((back.gamma - 0.3).abs() < 1e-12);
        assert!(back.accumulate);
    }

    #[test]
    fn backend_selection_validates() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.backend, "native");
        cfg.apply_override("backend", "xla").unwrap();
        cfg.validate().unwrap();
        cfg.backend = "cuda".into();
        assert!(cfg.validate().is_err());
        let j = cfg.to_json();
        assert!(j.to_string().contains("backend"));
    }

    #[test]
    fn from_json_rejects_unknown_keys() {
        let j = Json::parse(r#"{"datasett": "cifar10"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn stream_default_validates() {
        StreamConfig::default().validate().unwrap();
    }

    #[test]
    fn stream_overrides_apply_and_validate() {
        let mut cfg = StreamConfig::default();
        cfg.apply_override("dataset", "drift-lm").unwrap();
        cfg.apply_override("gamma", "0.25").unwrap();
        cfg.apply_override("max-ticks", "200").unwrap();
        cfg.apply_override("store-capacity", "4096").unwrap();
        cfg.apply_override("burst-period", "0").unwrap();
        cfg.apply_override("checkpoint", "/tmp/ck.json").unwrap();
        cfg.apply_override("resume", "on").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.dataset, "drift-lm");
        assert_eq!(cfg.max_ticks, 200);
        assert!(cfg.resume);
    }

    #[test]
    fn stream_bad_values_rejected() {
        let mut cfg = StreamConfig::default();
        assert!(cfg.apply_override("nope", "1").is_err());
        cfg.dataset = "cifar10".into(); // batch dataset, not a stream
        assert!(cfg.validate().is_err());
        cfg.dataset = "drift-class".into();
        cfg.gamma = 1.5;
        assert!(cfg.validate().is_err());
        cfg.gamma = 0.5;
        cfg.max_ticks = 0;
        assert!(cfg.validate().is_err());
        cfg.max_ticks = 10;
        cfg.resume = true; // resume without a checkpoint path
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn stream_json_round_trip() {
        let mut cfg = StreamConfig::default();
        cfg.dataset = "drift-reg".into();
        cfg.gamma = 0.3;
        cfg.burst_min = 0.5;
        cfg.drift_detect = "adwin".into();
        cfg.replay = true;
        let back = StreamConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.dataset, "drift-reg");
        assert!((back.gamma - 0.3).abs() < 1e-12);
        assert!((back.burst_min - 0.5).abs() < 1e-12);
        assert_eq!(back.drift_detect, "adwin");
        assert!(back.replay);
    }

    #[test]
    fn drift_detect_selector_parses_and_keeps_legacy_booleans() {
        let mut cfg = StreamConfig::default();
        assert_eq!(cfg.drift_detect, "off");
        cfg.apply_override("drift-detect", "on").unwrap();
        assert_eq!(cfg.drift_detect, "page-hinkley");
        cfg.apply_override("drift-detect", "false").unwrap();
        assert_eq!(cfg.drift_detect, "off");
        cfg.apply_override("drift-detect", "adwin").unwrap();
        cfg.validate().unwrap();
        cfg.apply_override("drift-detect", "kswin").unwrap();
        assert!(cfg.validate().is_err(), "unknown detector accepted");
    }

    #[test]
    fn health_knob_parses_validates_and_round_trips() {
        let mut cfg = StreamConfig::default();
        assert_eq!(cfg.health, "off");
        cfg.validate().unwrap();
        cfg.apply_override("health", "warn").unwrap();
        cfg.validate().unwrap();
        cfg.apply_override("health", "strict").unwrap();
        cfg.validate().unwrap();
        let back = StreamConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.health, "strict");
        cfg.health = "paranoid".into();
        assert!(cfg.validate().is_err(), "unknown health mode accepted");
        // telemetry must never gate a resume
        let mut warn = StreamConfig::default();
        warn.health = "warn".into();
        assert_eq!(StreamConfig::default().identity_json(), warn.identity_json());
        // the knob falls through the cluster override surface too
        let mut cc = ClusterConfig::default();
        cc.apply_override("health", "warn").unwrap();
        assert_eq!(cc.stream.health, "warn");
        cc.validate().unwrap();
    }

    #[test]
    fn method_alias_and_obftf_k_apply_and_validate() {
        let mut cfg = StreamConfig::default();
        cfg.apply_override("method", "obftf").unwrap();
        assert_eq!(cfg.selector, "obftf");
        cfg.apply_override("obftf-k", "4").unwrap();
        assert_eq!(cfg.obftf_k, 4);
        cfg.validate().unwrap();
        cfg.apply_override("method", "selective-backprop").unwrap();
        cfg.validate().unwrap();
        cfg.apply_override("method", "adaselection:big_loss+obftf").unwrap();
        cfg.validate().unwrap();
        cfg.obftf_k = 0;
        assert!(cfg.validate().is_err(), "obftf-k 0 accepted");
        cfg.obftf_k = 10;
        cfg.selector = "bogus".into();
        let e = cfg.validate().unwrap_err().to_string();
        assert!(e.contains("obftf"), "error must list valid ids: {e}");

        // the multiplier is part of the resume identity
        let base = StreamConfig::default();
        let mut k4 = base.clone();
        k4.obftf_k = 4;
        assert_ne!(base.identity_json(), k4.identity_json());

        // the batch config accepts the alias too
        let mut rc = RunConfig::default();
        rc.apply_override("method", "big_loss").unwrap();
        assert_eq!(rc.selector, "big_loss");
        rc.validate().unwrap();
    }

    #[test]
    fn drift_and_replay_are_part_of_run_identity() {
        let base = StreamConfig::default();
        let mut d = base.clone();
        d.drift_detect = "page-hinkley".into();
        let mut a = base.clone();
        a.drift_detect = "adwin".into();
        let mut r = base.clone();
        r.replay = true;
        assert_ne!(base.identity_json(), d.identity_json());
        assert_ne!(base.identity_json(), a.identity_json());
        assert_ne!(d.identity_json(), a.identity_json());
        assert_ne!(base.identity_json(), r.identity_json());
    }

    #[test]
    fn cluster_default_validates() {
        ClusterConfig::default().validate().unwrap();
    }

    #[test]
    fn cluster_overrides_split_between_layers() {
        let mut cfg = ClusterConfig::default();
        cfg.apply_override("nodes", "2").unwrap();
        cfg.apply_override("gossip-every", "8").unwrap();
        cfg.apply_override("transport", "tcp").unwrap();
        cfg.apply_override("gossip", "delta").unwrap();
        cfg.apply_override("kill-at", "40").unwrap();
        cfg.apply_override("kill-node", "1").unwrap();
        cfg.apply_override("join-at", "60").unwrap();
        // stream-level keys fall through
        cfg.apply_override("gamma", "0.25").unwrap();
        cfg.apply_override("max-ticks", "100").unwrap();
        cfg.apply_override("replay", "on").unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.nodes, 2);
        assert_eq!(cfg.gossip_every, 8);
        assert_eq!(cfg.transport, "tcp");
        assert_eq!(cfg.gossip, "delta");
        assert!((cfg.stream.gamma - 0.25).abs() < 1e-12);
        assert!(cfg.stream.replay);
        assert!(cfg.apply_override("bogus-key", "1").is_err());
    }

    #[test]
    fn workers_flag_splits_mode_from_pipeline_count() {
        let mut cfg = ClusterConfig::default();
        assert_eq!(cfg.worker_mode, "threads");
        // numeric: pipeline workers, mode untouched
        cfg.apply_override("workers", "3").unwrap();
        assert_eq!(cfg.stream.workers, 3);
        assert_eq!(cfg.worker_mode, "threads");
        // mode name: worker runtime, pipeline count untouched
        cfg.apply_override("workers", "processes").unwrap();
        assert_eq!(cfg.worker_mode, "processes");
        assert_eq!(cfg.stream.workers, 3);
        cfg.validate().unwrap();
        cfg.apply_override("worker-mode", "threads").unwrap();
        assert_eq!(cfg.worker_mode, "threads");
        cfg.worker_mode = "fibers".into();
        assert!(cfg.validate().is_err(), "unknown worker mode accepted");
    }

    #[test]
    fn full_gossip_every_is_validated_and_round_trips() {
        let mut cfg = ClusterConfig::default();
        assert_eq!(cfg.full_gossip_every, 8);
        cfg.apply_override("full-gossip-every", "3").unwrap();
        cfg.validate().unwrap();
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.full_gossip_every, 3);
        cfg.full_gossip_every = 0;
        assert!(cfg.validate().is_err(), "full-gossip-every 0 accepted");
    }

    #[test]
    fn chaos_kill_requires_process_workers() {
        let mut cfg = ClusterConfig::default();
        cfg.chaos_kill_at = 40;
        cfg.chaos_kill_node = 1;
        assert!(cfg.validate().is_err(), "chaos kill in thread mode accepted");
        cfg.worker_mode = "processes".into();
        cfg.validate().unwrap();
        cfg.chaos_kill_node = cfg.nodes; // out of range
        assert!(cfg.validate().is_err());
        cfg.chaos_kill_node = 1;
        cfg.kill_at = 80;
        cfg.kill_node = 1; // same victim twice
        assert!(cfg.validate().is_err());
        cfg.kill_node = 2;
        cfg.validate().unwrap();
        // a tcp: feed cannot be re-consumed by N worker processes
        cfg.stream.dataset = "tcp:127.0.0.1:9999".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cluster_bad_values_rejected() {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = 0;
        assert!(cfg.validate().is_err());
        cfg.nodes = 4;
        cfg.vnodes = 0;
        assert!(cfg.validate().is_err());
        cfg.vnodes = 128;
        cfg.transport = "udp".into();
        assert!(cfg.validate().is_err(), "unknown transport accepted");
        cfg.transport = "tcp".into();
        cfg.gossip = "snapshot".into();
        assert!(cfg.validate().is_err(), "unknown gossip mode accepted");
        cfg.gossip = "delta".into();
        cfg.validate().unwrap();
        cfg.transport = "loopback".into();
        cfg.gossip = "full".into();
        cfg.kill_at = cfg.stream.max_ticks; // beyond the run
        assert!(cfg.validate().is_err());
        cfg.kill_at = 10;
        cfg.kill_node = 4; // out of range
        assert!(cfg.validate().is_err());
        cfg.kill_node = 0;
        cfg.nodes = 1; // killing the only node
        assert!(cfg.validate().is_err());
        cfg.nodes = 4;
        cfg.stream.checkpoint = Some(PathBuf::from("/tmp/ck.json"));
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tcp_transport_caps_store_capacity() {
        let mut cfg = ClusterConfig::default();
        cfg.transport = "tcp".into();
        cfg.stream.store_capacity = 3_000_000; // gossip frame > MAX_PAYLOAD
        assert!(cfg.validate().is_err(), "oversized tcp gossip frame accepted");
        cfg.transport = "loopback".into(); // loopback never frames
        cfg.validate().unwrap();
        cfg.transport = "tcp".into();
        cfg.stream.store_capacity = 65_536;
        cfg.validate().unwrap();
    }

    #[test]
    fn listen_spawn_and_elastic_knobs_gate_on_process_workers() {
        let mut cfg = ClusterConfig::default();
        cfg.apply_override("listen", "127.0.0.1:7400").unwrap();
        assert!(cfg.validate().is_err(), "--listen in thread mode accepted");
        cfg.apply_override("workers", "processes").unwrap();
        cfg.validate().unwrap();

        cfg.apply_override("spawn", "off").unwrap();
        cfg.validate().unwrap();
        cfg.listen = None;
        assert!(cfg.validate().is_err(), "--spawn off without --listen accepted");
        cfg.listen = Some("127.0.0.1:7400".into());

        cfg.apply_override("elastic-admit-above", "64").unwrap();
        cfg.apply_override("elastic-shed-below", "8").unwrap();
        cfg.apply_override("elastic-min-nodes", "2").unwrap();
        cfg.apply_override("elastic-max-nodes", "6").unwrap();
        cfg.validate().unwrap();
        cfg.elastic_max_nodes = 2; // below the starting count of 4
        assert!(cfg.validate().is_err(), "elastic-max-nodes < nodes accepted");
        cfg.elastic_max_nodes = 0;
        cfg.elastic_min_nodes = 0;
        assert!(cfg.validate().is_err(), "elastic-min-nodes 0 accepted");
        cfg.elastic_min_nodes = 1;

        cfg.worker_mode = "threads".into();
        cfg.listen = None;
        assert!(cfg.validate().is_err(), "elastic in thread mode accepted");

        // the new keys survive a JSON round trip
        cfg.worker_mode = "processes".into();
        cfg.listen = Some("0.0.0.0:7401".into());
        cfg.spawn = false;
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.listen.as_deref(), Some("0.0.0.0:7401"));
        assert!(!back.spawn);
        assert!((back.elastic_admit_above - 64.0).abs() < 1e-12);
        assert!((back.elastic_shed_below - 8.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_json_round_trip() {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = 2;
        cfg.merge_every = 4;
        cfg.transport = "tcp".into();
        cfg.gossip = "delta".into();
        cfg.stream.gamma = 0.4;
        let back = ClusterConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.nodes, 2);
        assert_eq!(back.merge_every, 4);
        assert_eq!(back.transport, "tcp");
        assert_eq!(back.gossip, "delta");
        assert!((back.stream.gamma - 0.4).abs() < 1e-12);
    }
}

//! Experiment harness: one entry per paper figure/table (DESIGN.md §6),
//! a sweep driver that runs the underlying training jobs, and report
//! writers that emit the same rows/series the paper plots.

pub mod experiments;
pub mod report;

pub use experiments::{registry, run_experiment, run_experiment_with, SweepOptions};

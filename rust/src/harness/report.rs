//! Report writers: per-run CSV dumps, figure series, table printers.

use std::path::Path;

use crate::metrics::csv::{fmt_f, CsvTable};
use crate::metrics::ranking::{aggregate_dataset, MethodAggregate};
use crate::metrics::RunResult;

/// All raw runs, one row each (the provenance file every experiment emits).
pub fn runs_table(runs: &[RunResult]) -> CsvTable {
    let mut t = CsvTable::new(vec![
        "dataset", "selector", "gamma", "beta", "seed", "epochs", "iterations",
        "test_acc", "test_loss", "train_time_s",
    ]);
    for r in runs {
        t.push(vec![
            r.dataset.clone(),
            r.selector.clone(),
            format!("{:.2}", r.gamma),
            format!("{:.2}", r.beta),
            r.seed.to_string(),
            r.epochs.len().to_string(),
            r.iterations.to_string(),
            fmt_f(r.final_test_acc() as f64),
            fmt_f(r.final_test_loss() as f64),
            format!("{:.3}", r.train_time_s()),
        ]);
    }
    t
}

/// Figure-style series: metric vs γ, one column per selector.
pub fn figure_series(runs: &[RunResult], value: impl Fn(&RunResult) -> f64) -> CsvTable {
    let mut gammas: Vec<String> = Vec::new();
    let mut selectors: Vec<String> = Vec::new();
    for r in runs {
        let g = format!("{:.2}", r.gamma);
        if !gammas.contains(&g) {
            gammas.push(g);
        }
        if !selectors.contains(&r.selector) {
            selectors.push(r.selector.clone());
        }
    }
    gammas.sort();
    let mut header = vec!["gamma".to_string()];
    header.extend(selectors.iter().cloned());
    let mut t = CsvTable::new(header);
    for g in &gammas {
        let mut row = vec![g.clone()];
        for s in &selectors {
            let v = runs
                .iter()
                .find(|r| format!("{:.2}", r.gamma) == *g && &r.selector == s)
                .map(&value);
            row.push(v.map(fmt_f).unwrap_or_default());
        }
        t.push(row);
    }
    t
}

/// AdaSelection weight-evolution trace (Fig 8): iteration, w per candidate.
pub fn weight_trace_table(run: &RunResult) -> CsvTable {
    let mut header = vec!["iteration".to_string()];
    header.extend(run.weight_names.iter().cloned());
    let mut t = CsvTable::new(header);
    for (i, w) in run.weight_trace.iter().enumerate() {
        let mut row = vec![i.to_string()];
        row.extend(w.iter().map(|&x| format!("{x:.5}")));
        t.push(row);
    }
    t
}

/// Table-3/4 style table for one dataset.
pub fn aggregate_table(dataset: &str, aggs: &[MethodAggregate]) -> CsvTable {
    let mut t = CsvTable::new(vec!["dataset", "selector", "avg_rank", "avg_metric", "metric"]);
    for a in aggs {
        t.push(vec![
            dataset.to_string(),
            a.selector.clone(),
            format!("{:.2}", a.avg_rank),
            fmt_f(a.avg_metric),
            if a.higher_is_better { "accuracy" } else { "loss" }.to_string(),
        ]);
    }
    t
}

/// Print a CSV table as an aligned text table to stdout.
pub fn print_table(title: &str, t: &CsvTable) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = t.header.iter().map(|h| h.len()).collect();
    for row in &t.rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let line = |cells: &[String]| {
        let cols: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
            .collect();
        println!("  {}", cols.join("  "));
    };
    line(&t.header);
    for row in &t.rows {
        line(row);
    }
}

/// Save + print one dataset aggregate; returns the aggregates.
pub fn emit_dataset_aggregate(
    out_dir: &Path,
    dataset: &str,
    runs: &[RunResult],
) -> anyhow::Result<Vec<MethodAggregate>> {
    let mut aggs = aggregate_dataset(runs);
    crate::metrics::ranking::collapse_ada_best(&mut aggs);
    let t = aggregate_table(dataset, &aggs);
    t.save(&out_dir.join(format!("aggregate_{dataset}.csv")))?;
    print_table(&format!("{dataset}: avg rank / avg metric across γ"), &t);
    Ok(aggs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochStats;
    use crate::util::timer::PhaseTimer;

    fn run(sel: &str, gamma: f64, acc: f32, time: f64) -> RunResult {
        RunResult {
            dataset: "d".into(),
            selector: sel.into(),
            gamma,
            beta: 0.5,
            seed: 1,
            epochs: vec![EpochStats {
                epoch: 0,
                train_loss: 1.0,
                test_loss: 0.3,
                test_acc: acc,
                train_time_s: time,
            }],
            weight_trace: vec![vec![1.0, 1.0]],
            weight_names: vec!["big_loss".into(), "uniform".into()],
            phases: PhaseTimer::default(),
            iterations: 5,
        }
    }

    #[test]
    fn figure_series_pivots() {
        let runs = vec![
            run("a", 0.1, 0.5, 1.0),
            run("b", 0.1, 0.6, 1.0),
            run("a", 0.2, 0.7, 1.0),
        ];
        let t = figure_series(&runs, |r| r.final_test_acc() as f64);
        assert_eq!(t.header, vec!["gamma", "a", "b"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][1], "0.5000");
        assert_eq!(t.rows[1][2], ""); // b missing at γ=0.2
    }

    #[test]
    fn weight_trace_shapes() {
        let t = weight_trace_table(&run("ada", 0.2, 0.5, 1.0));
        assert_eq!(t.header, vec!["iteration", "big_loss", "uniform"]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn runs_table_has_row_per_run() {
        let t = runs_table(&[run("a", 0.1, 0.5, 2.0), run("b", 0.2, 0.6, 3.0)]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[1][2], "0.20");
    }
}

//! The experiment registry: one runnable entry per paper figure/table.
//!
//! Every experiment is a sweep of [`crate::train::run`] jobs followed by a
//! report emission matching what the paper plots. Sizes default to CPU-scale
//! (override with `--epochs/--data-scale`; `--quick` shrinks further for
//! smoke runs and benches).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::config::RunConfig;
use crate::harness::report;
use crate::metrics::RunResult;
use crate::runtime::{Backend, NativeBackend};
use crate::train;

/// A registry entry.
pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub description: &'static str,
}

/// Every table and figure in the paper's evaluation section.
#[rustfmt::skip]
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "fig1", paper_ref: "Figure 1", description: "SVHN test accuracy vs sampling rate (9 selectors)" },
        Experiment { id: "fig2", paper_ref: "Figure 2", description: "CIFAR10 test accuracy vs sampling rate" },
        Experiment { id: "fig3", paper_ref: "Figure 3", description: "CIFAR10 training time vs sampling rate" },
        Experiment { id: "fig4", paper_ref: "Figure 4", description: "CIFAR100 test accuracy vs sampling rate" },
        Experiment { id: "fig5", paper_ref: "Figure 5", description: "Simple-regression test loss vs sampling rate" },
        Experiment { id: "fig6", paper_ref: "Figure 6", description: "Bike-regression test loss vs sampling rate" },
        Experiment { id: "fig7", paper_ref: "Figure 7", description: "β ablation on SVHN/CIFAR10/CIFAR100 (γ=0.2)" },
        Experiment { id: "fig8", paper_ref: "Figure 8", description: "AdaSelection weight evolution per dataset (γ=0.2)" },
        Experiment { id: "fig9", paper_ref: "Figure 9", description: "Transformer (wikitext) test loss vs sampling rate" },
        Experiment { id: "table3", paper_ref: "Table 3", description: "average ranking across γ, all datasets × methods" },
        Experiment { id: "table4", paper_ref: "Table 4", description: "average metric across γ, all datasets × methods" },
        Experiment { id: "ablate-cl", paper_ref: "§3.2 (extension)", description: "curriculum-reward on/off ablation" },
        Experiment { id: "ablate-accumulate", paper_ref: "Alg 1/2 (extension)", description: "accumulate-until-full-batch vs immediate update" },
        Experiment { id: "ablate-stale", paper_ref: "§5 (future work)", description: "stale-loss forward approximation: refresh window sweep" },
        Experiment { id: "ablate-rule", paper_ref: "§3.2 (bandit view)", description: "weight-update rule: eq3 vs exp3 vs softmax" },
        Experiment { id: "tables-from-aggregates", paper_ref: "Tables 3/4", description: "assemble tables 3+4 from aggregate_*.csv already in --out (no re-training)" },
        Experiment { id: "stream-cmp", paper_ref: "§1/§5 (streaming)", description: "continuous-training stream: AdaSelection vs uniform vs benchmark vs forward-cheap (obftf, selective-backprop) rolling loss at equal tick budget (γ=0.5, drift-class)" },
        Experiment { id: "cluster-cmp", paper_ref: "§1 (scale-out)", description: "multi-node sharded streaming: 1 vs 2 vs 4 nodes at equal total tick budget — rolling loss parity + aggregate samples/sec (native only)" },
    ]
}

/// Sweep-level options from the CLI.
#[derive(Clone, Debug)]
pub struct SweepOptions {
    /// compute backend for every job: native | xla
    pub backend: String,
    pub out_dir: PathBuf,
    pub epochs: usize,
    pub data_scale: f64,
    pub lr: f32,
    pub seed: u64,
    pub quick: bool,
    pub artifacts_dir: PathBuf,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            backend: "native".to_string(),
            out_dir: PathBuf::from("results"),
            epochs: 8,
            data_scale: 0.02,
            lr: 0.05,
            seed: 42,
            quick: false,
            artifacts_dir: crate::runtime::default_artifacts_dir(),
        }
    }
}

impl SweepOptions {
    fn effective(&self) -> (usize, f64) {
        if self.quick {
            (1, (self.data_scale * 0.3).max(0.002))
        } else {
            (self.epochs, self.data_scale)
        }
    }

    fn base_config(&self, dataset: &str, selector: &str, gamma: f64) -> RunConfig {
        let (epochs, data_scale) = self.effective();
        let mut cfg = RunConfig::default();
        cfg.backend = self.backend.clone();
        cfg.dataset = dataset.into();
        cfg.selector = selector.into();
        cfg.gamma = gamma;
        cfg.epochs = epochs;
        cfg.data_scale = data_scale;
        cfg.lr = self.lr;
        cfg.seed = self.seed;
        cfg.artifacts_dir = self.artifacts_dir.clone();
        cfg
    }
}

/// γ grid of the paper.
pub const GAMMAS: [f64; 5] = [0.1, 0.2, 0.3, 0.4, 0.5];

/// The baseline columns of Tables 3/4 (paper order, AdaSelection aside).
pub fn standard_selectors(dataset: &str) -> Vec<&'static str> {
    let mut v = vec![
        "benchmark",
        "uniform",
        "big_loss",
        "small_loss",
        "adaboost",
        "grad_norm",
        "coreset1",
        "coreset2",
    ];
    if dataset == "wikitext" {
        // paper footnote 4: gradient norm is unavailable for the NLP task
        v.retain(|s| *s != "grad_norm");
    }
    v
}

/// AdaSelection variants, mirroring the Table-3 caption ("best ranking over
/// several choices … single choice, no CL setting, three candidates, two
/// candidates"). (label, selector spec, cl_on).
pub fn adaselection_variants() -> Vec<(&'static str, &'static str, bool)> {
    vec![
        ("ada3+cl", "adaselection:big_loss+small_loss+uniform", true),
        ("ada3", "adaselection:big_loss+small_loss+uniform", false),
        ("ada2", "adaselection:big_loss+small_loss", false),
        ("ada4", "adaselection:big_loss+small_loss+uniform+coreset1", false),
    ]
}

/// Run a full dataset sweep: all selectors × γ grid.
pub fn dataset_sweep<B: Backend>(
    engine: &mut B,
    dataset: &str,
    opts: &SweepOptions,
) -> anyhow::Result<Vec<RunResult>> {
    let mut runs = Vec::new();
    let gammas: &[f64] = if opts.quick { &[0.1, 0.3] } else { &GAMMAS };
    for selector in standard_selectors(dataset) {
        for &gamma in gammas {
            let cfg = opts.base_config(dataset, selector, gamma);
            log::info!("sweep job: {dataset} {selector} γ={gamma}");
            let r = train::run_with(engine, cfg)?;
            runs.push(r);
            if selector == "benchmark" {
                break; // benchmark is γ-independent; reuse the single run
            }
        }
    }
    // AdaSelection variants (Table-3 caption methodology)
    let variants = adaselection_variants();
    let variants: &[(&str, &str, bool)] =
        if opts.quick { &variants[..1] } else { &variants[..] };
    for (label, spec, cl_on) in variants {
        for &gamma in gammas {
            let mut cfg = opts.base_config(dataset, spec, gamma);
            cfg.cl_on = *cl_on;
            log::info!("sweep job: {dataset} {label} γ={gamma}");
            let mut r = train::run_with(engine, cfg)?;
            r.selector = label.to_string();
            runs.push(r);
        }
    }
    // replicate the benchmark row across the γ grid for ranking parity
    if let Some(bench) = runs.iter().find(|r| r.selector == "benchmark").cloned() {
        let mut extra = Vec::new();
        for &gamma in gammas {
            if (bench.gamma - gamma).abs() > 1e-9 {
                let mut b = bench.clone();
                b.gamma = gamma;
                extra.push(b);
            }
        }
        runs.extend(extra);
    }
    Ok(runs)
}

/// Accuracy/loss-vs-γ figure for one dataset (figs 1, 2, 4, 5, 6, 9).
fn figure_metric_vs_gamma<B: Backend>(
    engine: &mut B,
    id: &str,
    dataset: &str,
    opts: &SweepOptions,
) -> anyhow::Result<()> {
    let runs = dataset_sweep(engine, dataset, opts)?;
    emit_figure(id, dataset, &runs, opts)
}

fn emit_figure(
    id: &str,
    dataset: &str,
    runs: &[RunResult],
    opts: &SweepOptions,
) -> anyhow::Result<()> {
    let accuracy = runs
        .first()
        .map(|r| r.headline_metric().1)
        .unwrap_or(false);
    let metric = report::figure_series(runs, |r| r.headline_metric().0);
    metric.save(&opts.out_dir.join(format!("{id}_{dataset}_metric.csv")))?;
    report::print_table(
        &format!(
            "{id}: {dataset} {} vs sampling rate",
            if accuracy { "test accuracy" } else { "test loss" }
        ),
        &metric,
    );
    let time = report::figure_series(runs, |r| r.train_time_s());
    time.save(&opts.out_dir.join(format!("{id}_{dataset}_time.csv")))?;
    report::runs_table(runs).save(&opts.out_dir.join(format!("{id}_{dataset}_runs.csv")))?;
    crate::metrics::persist::save_runs(
        &opts.out_dir.join(format!("{id}_{dataset}_runs.json")),
        runs,
    )?;
    report::emit_dataset_aggregate(&opts.out_dir, dataset, runs)?;
    Ok(())
}

/// Fig 3: the training-time comparison (same sweep as fig2, time series).
fn fig3<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let runs = dataset_sweep(engine, "cifar10", opts)?;
    let time = report::figure_series(&runs, |r| r.train_time_s());
    time.save(&opts.out_dir.join("fig3_cifar10_time.csv"))?;
    report::print_table("fig3: CIFAR10 training time (s) vs sampling rate", &time);
    // headline check: every subsampling method at γ≤0.5 must beat benchmark
    if let Some(bench) = runs.iter().find(|r| r.selector == "benchmark") {
        let bench_t = bench.train_time_s();
        let mut t = crate::metrics::csv::CsvTable::new(vec!["selector", "gamma", "time_saving_%"]);
        for r in runs.iter().filter(|r| r.selector != "benchmark") {
            t.push(vec![
                r.selector.clone(),
                format!("{:.2}", r.gamma),
                format!("{:.1}", 100.0 * (1.0 - r.train_time_s() / bench_t)),
            ]);
        }
        t.save(&opts.out_dir.join("fig3_time_saving.csv"))?;
        report::print_table("fig3: wall-clock saving vs benchmark", &t);
    }
    report::runs_table(&runs).save(&opts.out_dir.join("fig3_cifar10_runs.csv"))?;
    Ok(())
}

/// Fig 7: β sensitivity of AdaSelection at γ = 0.2.
fn fig7<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let betas = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
    let datasets: &[&str] = if opts.quick {
        &["svhn"]
    } else {
        &["svhn", "cifar10", "cifar100"]
    };
    let mut table = crate::metrics::csv::CsvTable::new(vec!["dataset", "beta", "test_acc"]);
    for ds in datasets {
        for &beta in &betas {
            let mut cfg =
                opts.base_config(ds, "adaselection:big_loss+small_loss+uniform", 0.2);
            cfg.beta = beta;
            let r = train::run_with(engine, cfg)?;
            table.push(vec![
                ds.to_string(),
                format!("{beta:.1}"),
                format!("{:.4}", r.final_test_acc()),
            ]);
        }
    }
    table.save(&opts.out_dir.join("fig7_beta_ablation.csv"))?;
    report::print_table("fig7: β ablation (γ=0.2)", &table);
    Ok(())
}

/// Fig 8: weight evolution traces at γ = 0.2.
fn fig8<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let datasets: &[&str] = if opts.quick {
        &["simple"]
    } else {
        &["svhn", "cifar10", "cifar100", "simple", "bike"]
    };
    for ds in datasets {
        let cfg = opts.base_config(ds, "adaselection:big_loss+small_loss+uniform", 0.2);
        let r = train::run_with(engine, cfg)?;
        let t = report::weight_trace_table(&r);
        t.save(&opts.out_dir.join(format!("fig8_weights_{ds}.csv")))?;
        if let Some(last) = r.weight_trace.last() {
            println!(
                "fig8 {ds}: final weights {:?} over {} iterations",
                last.iter().map(|w| format!("{w:.3}")).collect::<Vec<_>>(),
                r.weight_trace.len()
            );
        }
    }
    Ok(())
}

/// Tables 3 & 4 over every dataset.
fn tables<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let datasets: &[&str] = if opts.quick {
        &["simple", "bike"]
    } else {
        &["cifar10", "cifar100", "svhn", "simple", "bike", "wikitext"]
    };
    let mut rank_table =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "selector", "avg_rank"]);
    let mut metric_table =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "selector", "avg_metric", "metric"]);
    let mut cache: BTreeMap<String, Vec<RunResult>> = BTreeMap::new();
    for ds in datasets {
        let runs = dataset_sweep(engine, ds, opts)?;
        let aggs = report::emit_dataset_aggregate(&opts.out_dir, ds, &runs)?;
        for a in &aggs {
            rank_table.push(vec![
                ds.to_string(),
                a.selector.clone(),
                format!("{:.2}", a.avg_rank),
            ]);
            metric_table.push(vec![
                ds.to_string(),
                a.selector.clone(),
                format!("{:.4}", a.avg_metric),
                if a.higher_is_better { "accuracy" } else { "loss" }.to_string(),
            ]);
        }
        cache.insert(ds.to_string(), runs);
    }
    rank_table.save(&opts.out_dir.join("table3_avg_rank.csv"))?;
    metric_table.save(&opts.out_dir.join("table4_avg_metric.csv"))?;
    report::print_table("table3: average ranking across γ", &rank_table);
    report::print_table("table4: average metric across γ", &metric_table);
    Ok(())
}

/// Extension ablation: CL reward on vs off (same pool, γ grid).
fn ablate_cl<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let mut t = crate::metrics::csv::CsvTable::new(vec!["dataset", "cl", "gamma", "metric"]);
    let gammas: &[f64] = if opts.quick { &[0.2] } else { &[0.1, 0.2, 0.3] };
    for ds in ["cifar10", "simple"] {
        for &gamma in gammas {
            for cl in [true, false] {
                let mut cfg =
                    opts.base_config(ds, "adaselection:big_loss+small_loss+uniform", gamma);
                cfg.cl_on = cl;
                let r = train::run_with(engine, cfg)?;
                t.push(vec![
                    ds.to_string(),
                    cl.to_string(),
                    format!("{gamma:.1}"),
                    format!("{:.4}", r.headline_metric().0),
                ]);
            }
        }
    }
    t.save(&opts.out_dir.join("ablate_cl.csv"))?;
    report::print_table("ablation: curriculum reward", &t);
    Ok(())
}

/// Extension ablation: Alg-2 accumulate mode vs immediate updates.
fn ablate_accumulate<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let mut t =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "mode", "gamma", "metric", "time_s"]);
    let gammas: &[f64] = if opts.quick { &[0.2] } else { &[0.2, 0.4] };
    for ds in ["cifar10", "simple"] {
        for &gamma in gammas {
            for acc in [false, true] {
                let mut cfg = opts.base_config(ds, "big_loss", gamma);
                cfg.accumulate = acc;
                let r = train::run_with(engine, cfg)?;
                t.push(vec![
                    ds.to_string(),
                    if acc { "accumulate" } else { "immediate" }.to_string(),
                    format!("{gamma:.1}"),
                    format!("{:.4}", r.headline_metric().0),
                    format!("{:.2}", r.train_time_s()),
                ]);
            }
        }
    }
    t.save(&opts.out_dir.join("ablate_accumulate.csv"))?;
    report::print_table("ablation: accumulate vs immediate", &t);
    Ok(())
}

/// Extension ablation (paper §5): stale-loss forward approximation.
fn ablate_stale<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let mut t = crate::metrics::csv::CsvTable::new(vec![
        "dataset", "refresh", "metric", "time_s", "fwd_batches",
    ]);
    let windows: &[u32] = if opts.quick { &[0, 2] } else { &[0, 1, 2, 4] };
    for ds in ["cifar10", "simple"] {
        for &refresh in windows {
            let mut cfg = opts.base_config(ds, "adaselection:big_loss+small_loss+uniform", 0.2);
            cfg.stale_refresh = refresh;
            let r = train::run_with(engine, cfg)?;
            t.push(vec![
                ds.to_string(),
                refresh.to_string(),
                format!("{:.4}", r.headline_metric().0),
                format!("{:.2}", r.train_time_s()),
                r.phases.count("forward").to_string(),
            ]);
        }
    }
    t.save(&opts.out_dir.join("ablate_stale.csv"))?;
    report::print_table("ablation: stale-loss forward approximation", &t);
    Ok(())
}

/// Extension ablation (§3.2 bandit framing): weight-update rules.
fn ablate_rule<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    let mut t =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "rule", "gamma", "metric"]);
    let gammas: &[f64] = if opts.quick { &[0.2] } else { &[0.1, 0.2, 0.3] };
    for ds in ["svhn", "simple"] {
        for &gamma in gammas {
            for rule in ["eq3:0.5", "exp3:0.2", "softmax:0.25"] {
                let mut cfg =
                    opts.base_config(ds, "adaselection:big_loss+small_loss+uniform", gamma);
                cfg.rule = rule.into();
                let r = train::run_with(engine, cfg)?;
                t.push(vec![
                    ds.to_string(),
                    rule.to_string(),
                    format!("{gamma:.1}"),
                    format!("{:.4}", r.headline_metric().0),
                ]);
            }
        }
    }
    t.save(&opts.out_dir.join("ablate_rule.csv"))?;
    report::print_table("ablation: bandit weight-update rules", &t);
    Ok(())
}

/// Per-phase seconds columns shared by the stream/cluster comparison
/// summaries. The values are the run's `util::timer` profile — the same
/// accounting the telemetry registry publishes as
/// `adaselection_phase_seconds` — so the CSVs and `/metrics` can never
/// disagree, and neither experiment keeps its own stopwatch plumbing.
const CMP_PHASES: &[&str] = &["data", "forward", "select", "store", "replay", "update", "eval"];

fn phase_headers() -> Vec<String> {
    CMP_PHASES.iter().map(|p| format!("{p}_s")).collect()
}

fn phase_cells(phases: &crate::util::timer::PhaseTimer) -> Vec<String> {
    CMP_PHASES
        .iter()
        .map(|p| format!("{:.3}", phases.total_secs(p)))
        .collect()
}

/// Streaming extension: AdaSelection vs uniform vs full-batch benchmark on
/// the drift-classification stream at an equal train-tick budget. Emits the
/// per-tick rolling-loss trace and a summary row per selector.
fn stream_cmp<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    use crate::config::StreamConfig;
    use crate::stream::StreamTrainer;

    if engine.family_meta("stream_class").is_err() {
        log::warn!("backend lacks the stream_class family; skipping stream-cmp");
        return Ok(());
    }
    let ticks = if opts.quick { 120 } else { 600 };
    let mut trace = crate::metrics::csv::CsvTable::new(vec![
        "selector", "tick", "rolling_loss", "rolling_acc",
    ]);
    let mut summary_cols: Vec<String> = [
        "selector",
        "final_rolling_loss",
        "final_rolling_acc",
        "samples_per_sec",
        "samples_trained",
        "samples_forward",
        "store_live",
        "store_evictions",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    summary_cols.extend(phase_headers());
    let mut summary = crate::metrics::csv::CsvTable::new(summary_cols);
    for selector in [
        "adaselection",
        "uniform",
        "benchmark",
        "obftf",
        "selective-backprop",
    ] {
        let mut cfg = StreamConfig::default();
        cfg.dataset = "drift-class".into();
        cfg.selector = selector.into();
        cfg.gamma = 0.5;
        cfg.lr = opts.lr;
        cfg.seed = opts.seed;
        cfg.max_ticks = ticks;
        cfg.window = 40;
        log::info!("stream-cmp job: {selector} over {ticks} ticks");
        let r = StreamTrainer::new(&mut *engine, cfg)?.run()?;
        for p in &r.rolling {
            trace.push(vec![
                selector.to_string(),
                p.tick.to_string(),
                format!("{:.6}", p.loss),
                format!("{:.6}", p.acc),
            ]);
        }
        let mut row = vec![
            selector.to_string(),
            format!("{:.6}", r.final_rolling_loss),
            format!("{:.6}", r.final_rolling_acc),
            format!("{:.1}", r.samples_per_sec),
            r.samples_trained.to_string(),
            r.samples_forward.to_string(),
            r.store_len.to_string(),
            r.store_counters.evictions.to_string(),
        ];
        row.extend(phase_cells(&r.phases));
        summary.push(row);
    }
    trace.save(&opts.out_dir.join("stream_cmp_trace.csv"))?;
    summary.save(&opts.out_dir.join("stream_cmp_summary.csv"))?;
    report::print_table(
        "stream-cmp: rolling prequential loss at equal tick budget (drift-class, γ=0.5)",
        &summary,
    );
    Ok(())
}

/// Scale-out extension: the same drifting stream at an equal total tick
/// budget through 1-, 2- and 4-node clusters, plus a 4-node delta-gossip
/// job and a 4-node *process-worker* job (one OS process per node).
/// Emits rolling-loss parity vs the single node, the aggregate-
/// throughput scaling curve, and gossip/merge bandwidth per job.
fn cluster_cmp<B: Backend>(engine: &mut B, opts: &SweepOptions) -> anyhow::Result<()> {
    use crate::config::ClusterConfig;

    if engine.name() != "native" {
        log::warn!("cluster-cmp runs on the native backend only; skipping");
        return Ok(());
    }
    let ticks = if opts.quick { 80 } else { 400 };
    let mut summary_cols: Vec<String> = [
        "nodes",
        "final_rolling_loss",
        "loss_vs_1node_%",
        "samples_per_sec",
        "speedup_vs_1node",
        "samples_seen",
        "samples_trained",
        "merges",
        "gossip_rounds",
        "gossip",
        "gossip_bytes",
        "merge_bytes",
        "workers",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    // phase columns sum over thread-mode nodes; process workers time their
    // phases in their own address space, so the processes row reads 0
    summary_cols.extend(phase_headers());
    let mut summary = crate::metrics::csv::CsvTable::new(summary_cols);
    let mut trace = crate::metrics::csv::CsvTable::new(vec![
        "nodes", "gossip", "workers", "tick", "rolling_loss", "rolling_acc",
    ]);
    // (nodes, gossip mode, worker mode); the process job only runs in the
    // full sweep — spawning worker processes needs the real binary, which
    // quick-mode test harnesses may not be
    let jobs: &[(usize, &str, &str)] = if opts.quick {
        &[(1, "full", "threads"), (2, "full", "threads")]
    } else {
        &[
            (1, "full", "threads"),
            (2, "full", "threads"),
            (4, "full", "threads"),
            (4, "delta", "threads"),
            (4, "full", "processes"),
        ]
    };
    let mut base: Option<(f32, f64)> = None; // (loss, samples/s) at 1 node
    for &(nodes, gossip, workers) in jobs {
        let mut cfg = ClusterConfig::default();
        cfg.nodes = nodes;
        cfg.gossip = gossip.into();
        cfg.worker_mode = workers.into();
        cfg.gossip_every = 8;
        cfg.merge_every = 8;
        cfg.stream.dataset = "drift-class".into();
        cfg.stream.gamma = 0.5;
        cfg.stream.lr = opts.lr;
        cfg.stream.seed = opts.seed;
        cfg.stream.max_ticks = ticks;
        cfg.stream.window = 40;
        cfg.stream.workers = 1;
        log::info!(
            "cluster-cmp job: {nodes} node(s), {gossip} gossip, {workers} workers, {ticks} ticks"
        );
        let r = crate::cluster::run(&cfg)?;
        if base.is_none() {
            base = Some((r.final_rolling_loss, r.samples_per_sec));
        }
        let (base_loss, base_sps) = base.expect("set on first iteration");
        for p in &r.rolling {
            trace.push(vec![
                nodes.to_string(),
                gossip.to_string(),
                workers.to_string(),
                p.tick.to_string(),
                format!("{:.6}", p.loss),
                format!("{:.6}", p.acc),
            ]);
        }
        let mut row = vec![
            nodes.to_string(),
            format!("{:.6}", r.final_rolling_loss),
            format!("{:+.1}", 100.0 * (r.final_rolling_loss - base_loss) / base_loss),
            format!("{:.1}", r.samples_per_sec),
            format!("{:.2}", r.samples_per_sec / base_sps.max(1e-9)),
            r.samples_seen.to_string(),
            r.samples_trained.to_string(),
            r.merges.to_string(),
            r.gossip_rounds.to_string(),
            gossip.to_string(),
            r.gossip_bytes.to_string(),
            r.merge_bytes.to_string(),
            workers.to_string(),
        ];
        row.extend(phase_cells(&r.phases));
        summary.push(row);
    }
    summary.save(&opts.out_dir.join("cluster_cmp_summary.csv"))?;
    trace.save(&opts.out_dir.join("cluster_cmp_trace.csv"))?;
    report::print_table(
        "cluster-cmp: node-count scaling at equal total tick budget (drift-class, γ=0.5)",
        &summary,
    );
    Ok(())
}

/// Assemble Tables 3/4 from `aggregate_{dataset}.csv` files already in the
/// output directory (produced by the per-figure sweeps) without re-running
/// any training.
fn tables_from_aggregates(opts: &SweepOptions) -> anyhow::Result<()> {
    let mut rank_table =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "selector", "avg_rank"]);
    let mut metric_table =
        crate::metrics::csv::CsvTable::new(vec!["dataset", "selector", "avg_metric", "metric"]);
    let mut found = 0;
    for ds in crate::data::ALL_DATASETS {
        let path = opts.out_dir.join(format!("aggregate_{ds}.csv"));
        let Ok(text) = std::fs::read_to_string(&path) else {
            log::warn!("missing {path:?} — run the {ds} figure sweep first");
            continue;
        };
        found += 1;
        for line in text.lines().skip(1) {
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 5 {
                continue;
            }
            rank_table.push(vec![cols[0].to_string(), cols[1].to_string(), cols[2].to_string()]);
            metric_table.push(vec![
                cols[0].to_string(),
                cols[1].to_string(),
                cols[3].to_string(),
                cols[4].to_string(),
            ]);
        }
    }
    anyhow::ensure!(found > 0, "no aggregate_*.csv files in {:?}", opts.out_dir);
    rank_table.save(&opts.out_dir.join("table3_avg_rank.csv"))?;
    metric_table.save(&opts.out_dir.join("table4_avg_metric.csv"))?;
    report::print_table("table3: average ranking across γ (from saved sweeps)", &rank_table);
    report::print_table("table4: average metric across γ (from saved sweeps)", &metric_table);
    Ok(())
}

/// Entry point used by the CLI `sweep` command: builds the backend named
/// by `opts.backend` and runs the experiment on it.
pub fn run_experiment(id: &str, opts: &SweepOptions) -> anyhow::Result<()> {
    match opts.backend.as_str() {
        "native" => {
            let mut backend = NativeBackend::new();
            run_experiment_with(&mut backend, id, opts)
        }
        "xla" => run_experiment_xla(id, opts),
        other => anyhow::bail!("unknown backend '{other}' (expected native|xla)"),
    }
}

#[cfg(feature = "xla")]
fn run_experiment_xla(id: &str, opts: &SweepOptions) -> anyhow::Result<()> {
    let mut engine = crate::runtime::Engine::new(&opts.artifacts_dir)?;
    run_experiment_with(&mut engine, id, opts)
}

#[cfg(not(feature = "xla"))]
fn run_experiment_xla(_id: &str, _opts: &SweepOptions) -> anyhow::Result<()> {
    anyhow::bail!("backend 'xla' requires building with `--features xla`")
}

/// Same, on a shared backend (compiled executables reused across sweeps
/// on XLA).
pub fn run_experiment_with<B: Backend>(
    engine: &mut B,
    id: &str,
    opts: &SweepOptions,
) -> anyhow::Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    match id {
        "fig1" => figure_metric_vs_gamma(engine, "fig1", "svhn", opts),
        "fig2" => figure_metric_vs_gamma(engine, "fig2", "cifar10", opts),
        "fig3" => fig3(engine, opts),
        "fig4" => figure_metric_vs_gamma(engine, "fig4", "cifar100", opts),
        "fig5" => figure_metric_vs_gamma(engine, "fig5", "simple", opts),
        "fig6" => figure_metric_vs_gamma(engine, "fig6", "bike", opts),
        "fig7" => fig7(engine, opts),
        "fig8" => fig8(engine, opts),
        "fig9" => figure_metric_vs_gamma(engine, "fig9", "wikitext", opts),
        "table3" | "table4" => tables(engine, opts),
        "ablate-cl" => ablate_cl(engine, opts),
        "ablate-accumulate" => ablate_accumulate(engine, opts),
        "ablate-stale" => ablate_stale(engine, opts),
        "ablate-rule" => ablate_rule(engine, opts),
        "tables-from-aggregates" => tables_from_aggregates(opts),
        "stream-cmp" => stream_cmp(engine, opts),
        "cluster-cmp" => cluster_cmp(engine, opts),
        "all" => {
            for e in registry() {
                // table4 shares tables() with table3; tables-from-aggregates
                // is redundant right after a fresh tables() run
                if e.id == "table4" || e.id == "tables-from-aggregates" {
                    continue;
                }
                run_experiment_with(engine, e.id, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (see `adaselection list-experiments`)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id).collect();
        for want in [
            "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
            "table3", "table4",
        ] {
            assert!(ids.contains(&want), "{want} missing from registry");
        }
    }

    #[test]
    fn wikitext_drops_grad_norm() {
        assert!(!standard_selectors("wikitext").contains(&"grad_norm"));
        assert!(standard_selectors("cifar10").contains(&"grad_norm"));
    }

    #[test]
    fn quick_mode_shrinks() {
        let opts = SweepOptions {
            quick: true,
            ..SweepOptions::default()
        };
        let (epochs, scale) = opts.effective();
        assert_eq!(epochs, 1);
        assert!(scale < opts.data_scale);
    }

    #[test]
    fn unknown_experiment_errors() {
        let opts = SweepOptions::default();
        assert!(run_experiment("fig99", &opts).is_err());
    }
}

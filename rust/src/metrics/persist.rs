//! Run persistence: full `RunResult` ↔ JSON for provenance and offline
//! re-aggregation (`table3`/`table4` can be recomputed from saved runs
//! without re-training).

use std::path::Path;

use crate::metrics::{EpochStats, RunResult};
use crate::util::json::Json;
use crate::util::timer::PhaseTimer;

/// Serialize a run (weights trace included).
pub fn run_to_json(r: &RunResult) -> Json {
    let epochs = Json::Arr(
        r.epochs
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("epoch", Json::from(e.epoch)),
                    ("train_loss", Json::from(e.train_loss as f64)),
                    ("test_loss", Json::from(e.test_loss as f64)),
                    (
                        "test_acc",
                        if e.test_acc.is_nan() {
                            Json::Null
                        } else {
                            Json::from(e.test_acc as f64)
                        },
                    ),
                    ("train_time_s", Json::from(e.train_time_s)),
                ])
            })
            .collect(),
    );
    let trace = Json::Arr(
        r.weight_trace
            .iter()
            .map(|w| Json::arr_f64(&w.iter().map(|&x| x as f64).collect::<Vec<_>>()))
            .collect(),
    );
    Json::obj(vec![
        ("dataset", Json::from(r.dataset.as_str())),
        ("selector", Json::from(r.selector.as_str())),
        ("gamma", Json::from(r.gamma)),
        ("beta", Json::from(r.beta as f64)),
        ("seed", Json::from(r.seed as f64)),
        ("iterations", Json::from(r.iterations)),
        ("epochs", epochs),
        ("weight_names", Json::arr_str(&r.weight_names)),
        ("weight_trace", trace),
    ])
}

/// Parse a run back (phase timers are not persisted — they are process-local).
pub fn run_from_json(j: &Json) -> anyhow::Result<RunResult> {
    let epochs = j
        .at(&["epochs"])?
        .as_arr()?
        .iter()
        .map(|e| {
            Ok(EpochStats {
                epoch: e.at(&["epoch"])?.as_usize()?,
                train_loss: e.at(&["train_loss"])?.as_f64()? as f32,
                test_loss: e.at(&["test_loss"])?.as_f64()? as f32,
                test_acc: match e.at(&["test_acc"])? {
                    Json::Null => f32::NAN,
                    v => v.as_f64()? as f32,
                },
                train_time_s: e.at(&["train_time_s"])?.as_f64()?,
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let weight_trace = j
        .at(&["weight_trace"])?
        .as_arr()?
        .iter()
        .map(|w| {
            Ok(w.as_arr()?
                .iter()
                .map(|x| Ok(x.as_f64()? as f32))
                .collect::<anyhow::Result<Vec<f32>>>()?)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(RunResult {
        dataset: j.at(&["dataset"])?.as_str()?.to_string(),
        selector: j.at(&["selector"])?.as_str()?.to_string(),
        gamma: j.at(&["gamma"])?.as_f64()?,
        beta: j.at(&["beta"])?.as_f64()? as f32,
        seed: j.at(&["seed"])?.as_f64()? as u64,
        iterations: j.at(&["iterations"])?.as_usize()?,
        epochs,
        weight_names: j
            .at(&["weight_names"])?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<anyhow::Result<Vec<_>>>()?,
        weight_trace,
        phases: PhaseTimer::default(),
    })
}

/// Save a batch of runs as a JSON array.
pub fn save_runs(path: &Path, runs: &[RunResult]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let arr = Json::Arr(runs.iter().map(run_to_json).collect());
    std::fs::write(path, arr.to_string())?;
    Ok(())
}

/// Load runs saved by [`save_runs`].
pub fn load_runs(path: &Path) -> anyhow::Result<Vec<RunResult>> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
    j.as_arr()?.iter().map(run_from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunResult {
        RunResult {
            dataset: "svhn".into(),
            selector: "big_loss".into(),
            gamma: 0.3,
            beta: -0.5,
            seed: 11,
            iterations: 42,
            epochs: vec![
                EpochStats {
                    epoch: 0,
                    train_loss: 2.0,
                    test_loss: 1.5,
                    test_acc: 0.6,
                    train_time_s: 3.25,
                },
                EpochStats {
                    epoch: 1,
                    train_loss: 1.0,
                    test_loss: 1.2,
                    test_acc: f32::NAN,
                    train_time_s: 6.5,
                },
            ],
            weight_names: vec!["big_loss".into(), "uniform".into()],
            weight_trace: vec![vec![1.0, 1.0], vec![1.5, 0.5]],
            phases: PhaseTimer::default(),
        }
    }

    #[test]
    fn round_trip() {
        let r = sample();
        let back = run_from_json(&run_to_json(&r)).unwrap();
        assert_eq!(back.dataset, r.dataset);
        assert_eq!(back.selector, r.selector);
        assert_eq!(back.iterations, 42);
        assert_eq!(back.epochs.len(), 2);
        assert!((back.epochs[0].test_acc - 0.6).abs() < 1e-6);
        assert!(back.epochs[1].test_acc.is_nan());
        assert_eq!(back.weight_trace, r.weight_trace);
    }

    #[test]
    fn save_load_file() {
        let path = std::env::temp_dir().join("ada_persist_test/runs.json");
        let runs = vec![sample(), sample()];
        save_runs(&path, &runs).unwrap();
        let back = load_runs(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].seed, 11);
    }
}

//! Rolling-window metrics for the streaming trainer.
//!
//! A stream has no held-out test set; quality is tracked *prequentially*
//! (test-then-train): every arriving chunk is evaluated under the current
//! model before any of it is trained on, and the per-tick means feed a
//! fixed-size rolling window. The window mean is the streaming analogue of
//! the batch trainer's per-epoch test loss/accuracy.

use std::collections::VecDeque;

/// Fixed-capacity rolling mean.
#[derive(Clone, Debug)]
pub struct RollingWindow {
    cap: usize,
    buf: VecDeque<f64>,
    sum: f64,
}

impl RollingWindow {
    pub fn new(cap: usize) -> RollingWindow {
        RollingWindow {
            cap: cap.max(1),
            buf: VecDeque::new(),
            sum: 0.0,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.buf.push_back(v);
        self.sum += v;
        if self.buf.len() > self.cap {
            if let Some(x) = self.buf.pop_front() {
                self.sum -= x;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Window has seen at least `cap` observations.
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Mean over the window (NaN while empty).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            f64::NAN
        } else {
            self.sum / self.buf.len() as f64
        }
    }
}

/// One periodic snapshot of the rolling metrics.
#[derive(Clone, Copy, Debug)]
pub struct RollingPoint {
    pub tick: u64,
    /// rolling mean prequential loss
    pub loss: f32,
    /// rolling mean prequential accuracy (NaN for regression)
    pub acc: f32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_window_is_nan() {
        let w = RollingWindow::new(4);
        assert!(w.mean().is_nan());
        assert!(w.is_empty());
        assert!(!w.is_full());
    }

    #[test]
    fn partial_window_averages_what_it_has() {
        let mut w = RollingWindow::new(4);
        w.push(1.0);
        w.push(3.0);
        assert_eq!(w.len(), 2);
        assert!((w.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn full_window_slides() {
        let mut w = RollingWindow::new(3);
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            w.push(v);
        }
        assert!(w.is_full());
        assert_eq!(w.len(), 3);
        assert!((w.mean() - 4.0).abs() < 1e-9); // mean of [3, 4, 5]
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut w = RollingWindow::new(0);
        w.push(7.0);
        w.push(9.0);
        assert_eq!(w.len(), 1);
        assert!((w.mean() - 9.0).abs() < 1e-12);
    }
}

//! Table-3 / Table-4 math: average rank and average metric across sampling
//! rates, per dataset × method — exactly how the paper aggregates.

use std::collections::BTreeMap;

use crate::metrics::RunResult;
use crate::util::stats::ranks;

/// One (dataset, selector) aggregate across the γ grid.
#[derive(Clone, Debug)]
pub struct MethodAggregate {
    pub selector: String,
    /// average rank across sampling rates (1 = best, Table 3)
    pub avg_rank: f64,
    /// average headline metric across sampling rates (Table 4)
    pub avg_metric: f64,
    /// whether the metric is accuracy (higher better) or loss
    pub higher_is_better: bool,
}

/// Aggregate a set of runs (one dataset, methods × γ grid) into Table-3/4
/// rows. Runs are grouped by γ; ranks are computed within each γ (methods
/// compared at the same rate) and then averaged — matching the caption of
/// Table 3 ("average … under sampling rates 0.1…0.5").
pub fn aggregate_dataset(runs: &[RunResult]) -> Vec<MethodAggregate> {
    let mut by_gamma: BTreeMap<String, Vec<&RunResult>> = BTreeMap::new();
    for r in runs {
        by_gamma.entry(format!("{:.4}", r.gamma)).or_default().push(r);
    }
    // stable selector order: first-seen order in the input
    let mut selectors: Vec<String> = Vec::new();
    for r in runs {
        if !selectors.contains(&r.selector) {
            selectors.push(r.selector.clone());
        }
    }
    let higher = runs
        .first()
        .map(|r| r.headline_metric().1)
        .unwrap_or(false);

    let mut rank_sums: BTreeMap<String, (f64, usize)> = BTreeMap::new();
    let mut metric_sums: BTreeMap<String, (f64, usize)> = BTreeMap::new();
    for group in by_gamma.values() {
        // metric per selector present in this γ group
        let present: Vec<&&RunResult> = group.iter().collect();
        let values: Vec<f64> = present.iter().map(|r| r.headline_metric().0).collect();
        let rs = ranks(&values, !higher);
        for (r, rank) in present.iter().zip(rs.iter()) {
            let e = rank_sums.entry(r.selector.clone()).or_insert((0.0, 0));
            e.0 += rank;
            e.1 += 1;
            let m = metric_sums.entry(r.selector.clone()).or_insert((0.0, 0));
            m.0 += r.headline_metric().0;
            m.1 += 1;
        }
    }

    selectors
        .iter()
        .filter_map(|s| {
            let (rs, rn) = rank_sums.get(s)?;
            let (ms, mn) = metric_sums.get(s)?;
            Some(MethodAggregate {
                selector: s.clone(),
                avg_rank: rs / (*rn).max(1) as f64,
                avg_metric: ms / (*mn).max(1) as f64,
                higher_is_better: higher,
            })
        })
        .collect()
}

/// The Table-3 caption methodology: collapse all `ada*` variant rows into
/// one "adaselection(best)" row carrying the best average rank / metric.
pub fn collapse_ada_best(aggs: &mut Vec<MethodAggregate>) {
    let is_variant = |s: &str| {
        matches!(s, "ada2" | "ada3" | "ada3+cl" | "ada4")
            || s.starts_with("adaselection[")
    };
    let ada: Vec<MethodAggregate> = aggs
        .iter()
        .filter(|a| is_variant(&a.selector))
        .cloned()
        .collect();
    if ada.is_empty() {
        return;
    }
    let best = ada
        .iter()
        .min_by(|a, b| a.avg_rank.partial_cmp(&b.avg_rank).unwrap())
        .unwrap()
        .clone();
    aggs.push(MethodAggregate {
        selector: format!("adaselection(best={})", best.selector),
        ..best
    });
}

/// Best non-benchmark selector by average rank (the paper bolds this).
pub fn best_selector(aggs: &[MethodAggregate]) -> Option<&MethodAggregate> {
    aggs.iter()
        .filter(|a| a.selector != "benchmark")
        .min_by(|a, b| a.avg_rank.partial_cmp(&b.avg_rank).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EpochStats;
    use crate::util::timer::PhaseTimer;

    fn run(selector: &str, gamma: f64, acc: f32) -> RunResult {
        RunResult {
            dataset: "d".into(),
            selector: selector.into(),
            gamma,
            beta: 0.5,
            seed: 0,
            epochs: vec![EpochStats {
                epoch: 0,
                train_loss: 1.0,
                test_loss: 1.0 - acc,
                test_acc: acc,
                train_time_s: 1.0,
            }],
            weight_trace: vec![],
            weight_names: vec![],
            phases: PhaseTimer::default(),
            iterations: 1,
        }
    }

    #[test]
    fn ranks_average_across_gammas() {
        // method A beats B at γ=0.1, loses at γ=0.2 ⇒ both avg rank 1.5
        let runs = vec![
            run("A", 0.1, 0.9),
            run("B", 0.1, 0.8),
            run("A", 0.2, 0.7),
            run("B", 0.2, 0.8),
        ];
        let aggs = aggregate_dataset(&runs);
        assert_eq!(aggs.len(), 2);
        assert!((aggs[0].avg_rank - 1.5).abs() < 1e-9);
        assert!((aggs[1].avg_rank - 1.5).abs() < 1e-9);
        assert!((aggs[0].avg_metric - 0.8).abs() < 1e-6);
    }

    #[test]
    fn consistent_winner_gets_rank_one() {
        let runs = vec![
            run("A", 0.1, 0.9),
            run("B", 0.1, 0.5),
            run("A", 0.2, 0.9),
            run("B", 0.2, 0.5),
        ];
        let aggs = aggregate_dataset(&runs);
        let a = aggs.iter().find(|x| x.selector == "A").unwrap();
        assert_eq!(a.avg_rank, 1.0);
    }

    #[test]
    fn loss_metric_ranks_lower_better() {
        let mut r1 = run("A", 0.1, f32::NAN);
        r1.epochs[0].test_loss = 0.2;
        let mut r2 = run("B", 0.1, f32::NAN);
        r2.epochs[0].test_loss = 0.9;
        let aggs = aggregate_dataset(&[r1, r2]);
        let a = aggs.iter().find(|x| x.selector == "A").unwrap();
        assert_eq!(a.avg_rank, 1.0);
        assert!(!a.higher_is_better);
    }

    #[test]
    fn best_selector_skips_benchmark() {
        let runs = vec![
            run("benchmark", 0.1, 0.99),
            run("A", 0.1, 0.9),
            run("B", 0.1, 0.5),
        ];
        let aggs = aggregate_dataset(&runs);
        assert_eq!(best_selector(&aggs).unwrap().selector, "A");
    }
}

//! Run metrics: per-iteration records, epoch summaries, rolling-window
//! prequential metrics for streams, CSV emission, and the paper's Table-3
//! (average rank) / Table-4 (average metric) math.

pub mod csv;
pub mod drift;
pub mod persist;
pub mod ranking;
pub mod rolling;

use crate::util::timer::PhaseTimer;

/// Per-epoch evaluation snapshot.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub test_loss: f32,
    /// classification / LM token accuracy; NaN for regression
    pub test_acc: f32,
    /// cumulative *training* wall-clock (excludes eval), seconds
    pub train_time_s: f64,
}

/// Result of one full training run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub dataset: String,
    pub selector: String,
    pub gamma: f64,
    pub beta: f32,
    pub seed: u64,
    pub epochs: Vec<EpochStats>,
    /// per-iteration AdaSelection weights (empty for other selectors)
    pub weight_trace: Vec<Vec<f32>>,
    pub weight_names: Vec<String>,
    pub phases: PhaseTimer,
    pub iterations: usize,
}

impl RunResult {
    pub fn final_test_loss(&self) -> f32 {
        self.epochs.last().map(|e| e.test_loss).unwrap_or(f32::NAN)
    }

    pub fn final_test_acc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(f32::NAN)
    }

    /// total training time (excludes eval), seconds
    pub fn train_time_s(&self) -> f64 {
        self.epochs.last().map(|e| e.train_time_s).unwrap_or(0.0)
    }

    /// The figure metric: accuracy for classification/LM-acc tasks if
    /// available, else test loss. `(value, higher_is_better)`.
    pub fn headline_metric(&self) -> (f64, bool) {
        let acc = self.final_test_acc();
        if acc.is_nan() {
            (self.final_test_loss() as f64, false)
        } else {
            (acc as f64, true)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(acc: f32, loss: f32) -> RunResult {
        RunResult {
            dataset: "d".into(),
            selector: "s".into(),
            gamma: 0.2,
            beta: 0.5,
            seed: 0,
            epochs: vec![EpochStats {
                epoch: 0,
                train_loss: 1.0,
                test_loss: loss,
                test_acc: acc,
                train_time_s: 2.0,
            }],
            weight_trace: vec![],
            weight_names: vec![],
            phases: PhaseTimer::default(),
            iterations: 10,
        }
    }

    #[test]
    fn headline_prefers_accuracy() {
        let (v, hib) = run(0.8, 0.5).headline_metric();
        assert!((v - 0.8).abs() < 1e-6);
        assert!(hib);
        let (v, hib) = run(f32::NAN, 0.5).headline_metric();
        assert!((v - 0.5).abs() < 1e-6);
        assert!(!hib);
    }

    #[test]
    fn empty_epochs_are_nan() {
        let mut r = run(0.1, 0.1);
        r.epochs.clear();
        assert!(r.final_test_loss().is_nan());
        assert_eq!(r.train_time_s(), 0.0);
    }
}

//! Concept-drift detection on streaming metrics.
//!
//! [`PageHinkley`] is the classic sequential change-point test on a signal's
//! mean: it accumulates the deviation of each observation from the running
//! mean (minus a tolerance `delta`) and fires when the cumulative sum rises
//! more than `lambda` above its historical minimum. Fed with the per-tick
//! prequential loss it detects *loss increases* — concept drift — with a
//! delay of roughly `lambda / step_size` ticks for a step change.
//!
//! The stream trainer uses it to drive γ and the method-weight learning
//! rate (see `stream::tick::DriftGamma`) instead of keeping them fixed.

/// Page–Hinkley test for an upward shift in the mean of a stream.
#[derive(Clone, Debug)]
pub struct PageHinkley {
    /// magnitude tolerance: deviations below `delta` never accumulate
    delta: f64,
    /// detection threshold on `cum - min(cum)`
    lambda: f64,
    n: u64,
    mean: f64,
    cum: f64,
    min_cum: f64,
    /// total detections fired since construction
    detections: u64,
}

impl PageHinkley {
    /// `delta` = per-observation tolerance, `lambda` = detection threshold.
    pub fn new(delta: f64, lambda: f64) -> PageHinkley {
        PageHinkley {
            delta,
            lambda: lambda.max(1e-12),
            n: 0,
            mean: 0.0,
            cum: 0.0,
            min_cum: 0.0,
            detections: 0,
        }
    }

    /// Feed one observation; `true` when a change is detected. Detection
    /// resets the accumulated statistics so the test re-arms on the new
    /// regime.
    pub fn observe(&mut self, x: f64) -> bool {
        if !x.is_finite() {
            return false;
        }
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
        self.cum += x - self.mean - self.delta;
        self.min_cum = self.min_cum.min(self.cum);
        if self.cum - self.min_cum > self.lambda {
            self.detections += 1;
            self.reset();
            return true;
        }
        false
    }

    /// Forget all accumulated statistics (detections counter survives).
    pub fn reset(&mut self) {
        self.n = 0;
        self.mean = 0.0;
        self.cum = 0.0;
        self.min_cum = 0.0;
    }

    pub fn detections(&self) -> u64 {
        self.detections
    }

    /// Raw state as (n, mean, cum, min_cum) — checkpoint support.
    pub fn state(&self) -> (u64, f64, f64, f64) {
        (self.n, self.mean, self.cum, self.min_cum)
    }

    /// Restore state captured by [`PageHinkley::state`].
    pub fn restore(&mut self, n: u64, mean: f64, cum: f64, min_cum: f64, detections: u64) {
        self.n = n;
        self.mean = mean;
        self.cum = cum;
        self.min_cum = min_cum;
        self.detections = detections;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Stationary noise for `quiet` steps, then a step change of `jump`;
    /// returns the index of the first detection (if any).
    fn first_detection(ph: &mut PageHinkley, quiet: usize, total: usize, jump: f64) -> Option<usize> {
        let mut rng = Pcg64::new(11);
        for i in 0..total {
            let base = if i < quiet { 1.0 } else { 1.0 + jump };
            let x = base + 0.05 * (rng.next_f64() - 0.5);
            if ph.observe(x) {
                return Some(i);
            }
        }
        None
    }

    #[test]
    fn detects_step_change_with_bounded_delay() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        let at = first_detection(&mut ph, 200, 300, 1.0).expect("no detection");
        assert!(at >= 200, "false positive at {at}");
        // step of ~1.0 against λ=2.0 accumulates in a handful of ticks
        assert!(at <= 215, "detection too slow: {at}");
        assert_eq!(ph.detections(), 1);
    }

    #[test]
    fn stationary_stream_stays_quiet() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        assert_eq!(first_detection(&mut ph, 500, 500, 0.0), None);
        assert_eq!(ph.detections(), 0);
    }

    #[test]
    fn re_arms_after_detection() {
        let mut ph = PageHinkley::new(0.05, 1.0);
        let mut hits = 0;
        for block in 0..3 {
            for i in 0..100 {
                let level = 1.0 + block as f64; // staircase upward
                let _ = i;
                if ph.observe(level) {
                    hits += 1;
                }
            }
        }
        assert!(hits >= 2, "only {hits} detections on a staircase");
        assert_eq!(ph.detections(), hits);
    }

    #[test]
    fn downward_shift_is_ignored() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        for i in 0..400 {
            let x = if i < 200 { 2.0 } else { 0.5 };
            assert!(!ph.observe(x), "fired on a loss drop at {i}");
        }
    }

    #[test]
    fn state_round_trips() {
        let mut a = PageHinkley::new(0.02, 3.0);
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            a.observe(1.0 + rng.next_f64());
        }
        let (n, mean, cum, min_cum) = a.state();
        let mut b = PageHinkley::new(0.02, 3.0);
        b.restore(n, mean, cum, min_cum, a.detections());
        for _ in 0..50 {
            let x = 1.0 + rng.next_f64();
            assert_eq!(a.observe(x), b.observe(x));
        }
    }

    #[test]
    fn non_finite_observations_are_skipped() {
        let mut ph = PageHinkley::new(0.01, 0.5);
        assert!(!ph.observe(f64::NAN));
        assert!(!ph.observe(f64::INFINITY));
        let (n, ..) = ph.state();
        assert_eq!(n, 0);
    }
}

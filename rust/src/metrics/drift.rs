//! Concept-drift detection on streaming metrics.
//!
//! [`PageHinkley`] is the classic sequential change-point test on a signal's
//! mean: it accumulates the deviation of each observation from the running
//! mean (minus a tolerance `delta`) and fires when the cumulative sum rises
//! more than `lambda` above its historical minimum. Fed with the per-tick
//! prequential loss it detects *loss increases* — concept drift — with a
//! delay of roughly `lambda / step_size` ticks for a step change.
//!
//! [`Adwin`] (ADaptive WINdowing, Bifet & Gavaldà) keeps a bounded window
//! of recent observations and drops its oldest part whenever some split of
//! the window into old/new halves shows a mean difference larger than a
//! Hoeffding bound — no magnitude tuning, the threshold adapts to the
//! window sizes. Like the Page–Hinkley test here, it only fires on *upward*
//! shifts (a loss drop is improvement, not drift).
//!
//! The stream trainer uses either to drive γ and the method-weight
//! learning rate (see `stream::tick::DriftGamma`, `--drift-detect
//! page-hinkley|adwin`) instead of keeping them fixed.

/// Page–Hinkley test for an upward shift in the mean of a stream.
#[derive(Clone, Debug)]
pub struct PageHinkley {
    /// magnitude tolerance: deviations below `delta` never accumulate
    delta: f64,
    /// detection threshold on `cum - min(cum)`
    lambda: f64,
    n: u64,
    mean: f64,
    cum: f64,
    min_cum: f64,
    /// total detections fired since construction
    detections: u64,
}

impl PageHinkley {
    /// `delta` = per-observation tolerance, `lambda` = detection threshold.
    pub fn new(delta: f64, lambda: f64) -> PageHinkley {
        PageHinkley {
            delta,
            lambda: lambda.max(1e-12),
            n: 0,
            mean: 0.0,
            cum: 0.0,
            min_cum: 0.0,
            detections: 0,
        }
    }

    /// Feed one observation; `true` when a change is detected. Detection
    /// resets the accumulated statistics so the test re-arms on the new
    /// regime.
    pub fn observe(&mut self, x: f64) -> bool {
        if !x.is_finite() {
            return false;
        }
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
        self.cum += x - self.mean - self.delta;
        self.min_cum = self.min_cum.min(self.cum);
        if self.cum - self.min_cum > self.lambda {
            self.detections += 1;
            self.reset();
            return true;
        }
        false
    }

    /// Forget all accumulated statistics (detections counter survives).
    pub fn reset(&mut self) {
        self.n = 0;
        self.mean = 0.0;
        self.cum = 0.0;
        self.min_cum = 0.0;
    }

    pub fn detections(&self) -> u64 {
        self.detections
    }

    /// Raw state as (n, mean, cum, min_cum) — checkpoint support.
    pub fn state(&self) -> (u64, f64, f64, f64) {
        (self.n, self.mean, self.cum, self.min_cum)
    }

    /// Restore state captured by [`PageHinkley::state`].
    pub fn restore(&mut self, n: u64, mean: f64, cum: f64, min_cum: f64, detections: u64) {
        self.n = n;
        self.mean = mean;
        self.cum = cum;
        self.min_cum = min_cum;
        self.detections = detections;
    }
}

/// ADWIN: adaptive-window change detection for an upward mean shift.
///
/// The window holds the most recent `max_window` finite observations.
/// After every observation, all old/new splits (each side at least
/// [`Adwin::MIN_SUB`] long) are tested: a split whose new-side mean
/// exceeds the old-side mean by more than the Hoeffding cut
/// `sqrt(ln(4n/δ) / 2m)` (with `m` the harmonic mean of the two sizes)
/// drops the old side. Any drop counts as one detection; the surviving
/// window is already the post-change regime, so the test re-arms
/// naturally.
#[derive(Clone, Debug)]
pub struct Adwin {
    /// Hoeffding-bound confidence (smaller ⇒ fewer false alarms).
    delta: f64,
    /// hard window cap in observations (memory and per-tick cost bound)
    max_window: usize,
    window: std::collections::VecDeque<f64>,
    detections: u64,
}

impl Adwin {
    /// Minimum observations on each side of a candidate cut.
    pub const MIN_SUB: usize = 5;

    /// `delta` = cut confidence, `max_window` = window cap (observations).
    pub fn new(delta: f64, max_window: usize) -> Adwin {
        Adwin {
            delta: delta.clamp(1e-9, 1.0),
            max_window: max_window.max(2 * Self::MIN_SUB),
            window: std::collections::VecDeque::new(),
            detections: 0,
        }
    }

    /// Feed one observation; `true` when the window was cut (drift).
    pub fn observe(&mut self, x: f64) -> bool {
        if !x.is_finite() {
            return false;
        }
        self.window.push_back(x);
        if self.window.len() > self.max_window {
            self.window.pop_front();
        }
        let mut detected = false;
        loop {
            let n = self.window.len();
            if n < 2 * Self::MIN_SUB {
                break;
            }
            let total: f64 = self.window.iter().sum();
            let log_term = (4.0 * n as f64 / self.delta).ln();
            let mut cut_at = None;
            let mut prefix = 0.0;
            for (i, &v) in self.window.iter().enumerate() {
                prefix += v;
                let n0 = i + 1;
                let n1 = n - n0;
                if n1 < Self::MIN_SUB {
                    break;
                }
                if n0 < Self::MIN_SUB {
                    continue;
                }
                let m0 = prefix / n0 as f64;
                let m1 = (total - prefix) / n1 as f64;
                // harmonic mean of the sub-window sizes
                let m = 1.0 / (1.0 / n0 as f64 + 1.0 / n1 as f64);
                let eps = (log_term / (2.0 * m)).sqrt();
                if m1 - m0 > eps {
                    cut_at = Some(n0);
                    break;
                }
            }
            match cut_at {
                Some(k) => {
                    self.window.drain(..k);
                    detected = true;
                }
                None => break,
            }
        }
        if detected {
            self.detections += 1;
        }
        detected
    }

    /// Drop the whole window (detections counter survives).
    pub fn reset(&mut self) {
        self.window.clear();
    }

    pub fn detections(&self) -> u64 {
        self.detections
    }

    /// Window contents, oldest first — checkpoint support.
    pub fn window_values(&self) -> Vec<f64> {
        self.window.iter().copied().collect()
    }

    /// Restore state captured by [`Adwin::window_values`] +
    /// [`Adwin::detections`]. Values beyond the window cap keep only the
    /// most recent `max_window` entries (matching live behaviour).
    pub fn restore(&mut self, values: &[f64], detections: u64) {
        self.window.clear();
        let skip = values.len().saturating_sub(self.max_window);
        self.window.extend(values[skip..].iter().copied());
        self.detections = detections;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Stationary noise for `quiet` steps, then a step change of `jump`;
    /// returns the index of the first detection (if any).
    fn first_detection(ph: &mut PageHinkley, quiet: usize, total: usize, jump: f64) -> Option<usize> {
        let mut rng = Pcg64::new(11);
        for i in 0..total {
            let base = if i < quiet { 1.0 } else { 1.0 + jump };
            let x = base + 0.05 * (rng.next_f64() - 0.5);
            if ph.observe(x) {
                return Some(i);
            }
        }
        None
    }

    #[test]
    fn detects_step_change_with_bounded_delay() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        let at = first_detection(&mut ph, 200, 300, 1.0).expect("no detection");
        assert!(at >= 200, "false positive at {at}");
        // step of ~1.0 against λ=2.0 accumulates in a handful of ticks
        assert!(at <= 215, "detection too slow: {at}");
        assert_eq!(ph.detections(), 1);
    }

    #[test]
    fn stationary_stream_stays_quiet() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        assert_eq!(first_detection(&mut ph, 500, 500, 0.0), None);
        assert_eq!(ph.detections(), 0);
    }

    #[test]
    fn re_arms_after_detection() {
        let mut ph = PageHinkley::new(0.05, 1.0);
        let mut hits = 0;
        for block in 0..3 {
            for i in 0..100 {
                let level = 1.0 + block as f64; // staircase upward
                let _ = i;
                if ph.observe(level) {
                    hits += 1;
                }
            }
        }
        assert!(hits >= 2, "only {hits} detections on a staircase");
        assert_eq!(ph.detections(), hits);
    }

    #[test]
    fn downward_shift_is_ignored() {
        let mut ph = PageHinkley::new(0.05, 2.0);
        for i in 0..400 {
            let x = if i < 200 { 2.0 } else { 0.5 };
            assert!(!ph.observe(x), "fired on a loss drop at {i}");
        }
    }

    #[test]
    fn state_round_trips() {
        let mut a = PageHinkley::new(0.02, 3.0);
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            a.observe(1.0 + rng.next_f64());
        }
        let (n, mean, cum, min_cum) = a.state();
        let mut b = PageHinkley::new(0.02, 3.0);
        b.restore(n, mean, cum, min_cum, a.detections());
        for _ in 0..50 {
            let x = 1.0 + rng.next_f64();
            assert_eq!(a.observe(x), b.observe(x));
        }
    }

    #[test]
    fn non_finite_observations_are_skipped() {
        let mut ph = PageHinkley::new(0.01, 0.5);
        assert!(!ph.observe(f64::NAN));
        assert!(!ph.observe(f64::INFINITY));
        let (n, ..) = ph.state();
        assert_eq!(n, 0);
    }

    // ---- ADWIN (mirrors the Page–Hinkley suite) ----------------------------

    fn adwin() -> Adwin {
        Adwin::new(0.005, 256)
    }

    /// Same harness as [`first_detection`], for ADWIN.
    fn adwin_first_detection(
        a: &mut Adwin,
        quiet: usize,
        total: usize,
        jump: f64,
    ) -> Option<usize> {
        let mut rng = Pcg64::new(11);
        for i in 0..total {
            let base = if i < quiet { 1.0 } else { 1.0 + jump };
            let x = base + 0.05 * (rng.next_f64() - 0.5);
            if a.observe(x) {
                return Some(i);
            }
        }
        None
    }

    #[test]
    fn adwin_detects_step_change_with_bounded_delay() {
        let mut a = adwin();
        let at = adwin_first_detection(&mut a, 200, 300, 1.0).expect("no detection");
        assert!(at >= 200, "false positive at {at}");
        // a unit step against the Hoeffding cut needs only a handful of
        // post-change observations (eps ≈ sqrt(6/k) at small new sides)
        assert!(at <= 215, "detection too slow: {at}");
        assert_eq!(a.detections(), 1);
    }

    #[test]
    fn adwin_stationary_stream_stays_quiet() {
        let mut a = adwin();
        assert_eq!(adwin_first_detection(&mut a, 500, 500, 0.0), None);
        assert_eq!(a.detections(), 0);
    }

    #[test]
    fn adwin_re_arms_after_detection() {
        let mut a = adwin();
        let mut hits = 0;
        for block in 0..3 {
            for _ in 0..100 {
                if a.observe(1.0 + block as f64) {
                    hits += 1;
                }
            }
        }
        assert!(hits >= 2, "only {hits} detections on a staircase");
        assert_eq!(a.detections(), hits);
    }

    #[test]
    fn adwin_downward_shift_is_ignored() {
        let mut a = adwin();
        for i in 0..400 {
            let x = if i < 200 { 2.0 } else { 0.5 };
            assert!(!a.observe(x), "fired on a loss drop at {i}");
        }
    }

    #[test]
    fn adwin_state_round_trips() {
        let mut a = adwin();
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            a.observe(1.0 + rng.next_f64());
        }
        let mut b = adwin();
        b.restore(&a.window_values(), a.detections());
        for _ in 0..60 {
            let x = 1.0 + 2.0 * rng.next_f64();
            assert_eq!(a.observe(x), b.observe(x));
        }
        assert_eq!(a.detections(), b.detections());
        assert_eq!(a.window_values(), b.window_values());
    }

    #[test]
    fn adwin_window_is_bounded_and_nonfinite_skipped() {
        let mut a = Adwin::new(0.01, 16);
        assert!(!a.observe(f64::NAN));
        assert!(!a.observe(f64::INFINITY));
        assert!(a.window_values().is_empty());
        for _ in 0..100 {
            a.observe(1.0);
        }
        assert!(a.window_values().len() <= 16);
        a.reset();
        assert!(a.window_values().is_empty());
    }
}

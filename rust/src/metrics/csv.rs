//! Tiny CSV writer (quoting-aware) used for all report tables and series.

use std::fmt::Write as _;
use std::path::Path;

/// An in-memory CSV table.
#[derive(Clone, Debug, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        CsvTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "csv row arity");
        self.rows.push(row);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for r in &self.rows {
            write_row(&mut out, r);
        }
        out
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, cell) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            let escaped = cell.replace('"', "\"\"");
            let _ = write!(out, "\"{escaped}\"");
        } else {
            out.push_str(cell);
        }
    }
    out.push('\n');
}

/// Format a float for tables: fixed 4 decimals, NaN as empty cell.
pub fn fmt_f(v: f64) -> String {
    if v.is_nan() {
        String::new()
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["1", "plain"]);
        t.push(vec!["2", "has,comma"]);
        t.push(vec!["3", "has\"quote"]);
        let s = t.to_string();
        assert_eq!(
            s,
            "a,b\n1,plain\n2,\"has,comma\"\n3,\"has\"\"quote\"\n"
        );
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["only-one"]);
    }

    #[test]
    fn fmt_f_handles_nan() {
        assert_eq!(fmt_f(1.23456), "1.2346");
        assert_eq!(fmt_f(f64::NAN), "");
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("ada_csv_test/nested");
        let path = dir.join("t.csv");
        let _ = std::fs::remove_file(&path);
        let mut t = CsvTable::new(vec!["x"]);
        t.push(vec!["1"]);
        t.save(&path).unwrap();
        assert!(path.exists());
    }
}

//! Bandit-style weight-update rules (the paper §3.2 frames AdaSelection as
//! an RL/bandit problem; eq. 3 is one instantiation). This module provides
//! the update family as pluggable rules so the choice can be ablated:
//!
//!   * `Eq3`       — the paper's multiplicative volatility rule
//!   * `Exp3`      — adversarial-bandit exponential weights over a
//!                   loss-reduction reward
//!   * `Softmax`   — Boltzmann weighting of the (negated) hypothetical
//!                   selected-loss, temperature τ
//!
//! All rules keep weights positive and normalized to sum = M.

/// Which update rule to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateRule {
    /// eq. 3: w ∝ w · exp(β · |ℓ_t − ℓ_{t-1}| / ℓ_{t-1})
    Eq3 { beta: f32 },
    /// EXP3: w ∝ w · exp(η · reward), reward = normalized loss *reduction*
    Exp3 { eta: f32 },
    /// stateless Boltzmann over −ℓ_t^m / τ
    Softmax { tau: f32 },
}

impl UpdateRule {
    pub fn parse(spec: &str) -> anyhow::Result<UpdateRule> {
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (spec, None),
        };
        let num = |default: f32| -> anyhow::Result<f32> {
            arg.map(|a| a.parse::<f32>().map_err(Into::into))
                .unwrap_or(Ok(default))
        };
        match name {
            "eq3" => Ok(UpdateRule::Eq3 { beta: num(0.5)? }),
            "exp3" => Ok(UpdateRule::Exp3 { eta: num(0.2)? }),
            "softmax" => Ok(UpdateRule::Softmax { tau: num(0.25)? }),
            other => anyhow::bail!("unknown update rule '{other}'"),
        }
    }

    /// Scale the rule's learning parameter by `s` (the drift detector's
    /// method-weight learning-rate boost): β and η multiply, the softmax
    /// temperature divides (smaller τ = sharper = faster adaptation).
    pub fn scaled(self, s: f32) -> UpdateRule {
        if (s - 1.0).abs() < f32::EPSILON {
            return self;
        }
        match self {
            UpdateRule::Eq3 { beta } => UpdateRule::Eq3 { beta: beta * s },
            UpdateRule::Exp3 { eta } => UpdateRule::Exp3 { eta: eta * s },
            UpdateRule::Softmax { tau } => UpdateRule::Softmax { tau: tau / s.max(1e-6) },
        }
    }

    /// Apply one update. `w` is modified in place (positive, sum = len).
    /// `cur` is ℓ_t^m per candidate; `prev` is ℓ_{t-1}^m (None on t=1).
    pub fn update(&self, w: &mut [f32], cur: &[f32], prev: Option<&[f32]>) {
        match *self {
            UpdateRule::Eq3 { beta } => {
                if let Some(prev) = prev {
                    // eq. 3 normalizes by ℓ_{t-1}^m; taken literally that
                    // explodes for methods whose picks converge to ~0 loss
                    // (Small Loss), collapsing the policy onto them. We
                    // normalize by the candidate-mean previous loss instead
                    // — same scale-freeness, bounded dynamics (DESIGN.md §5.2).
                    let scale = prev.iter().sum::<f32>() / prev.len() as f32;
                    let scale = scale.max(1e-9);
                    for ((wi, &lt), &lp) in w.iter_mut().zip(cur).zip(prev) {
                        let rel = (lt - lp).abs() / scale;
                        *wi *= (beta * rel).clamp(-10.0, 10.0).exp();
                    }
                }
            }
            UpdateRule::Exp3 { eta } => {
                if let Some(prev) = prev {
                    // reward = relative loss reduction achieved by the
                    // method's own pick (positive when loss fell)
                    let scale: f32 = cur
                        .iter()
                        .zip(prev)
                        .map(|(&c, &p)| (p - c).abs())
                        .fold(1e-9f32, f32::max);
                    for ((wi, &lt), &lp) in w.iter_mut().zip(cur).zip(prev) {
                        let reward = (lp - lt) / scale; // ∈ [-1, 1]
                        *wi *= (eta * reward).clamp(-10.0, 10.0).exp();
                    }
                }
            }
            UpdateRule::Softmax { tau } => {
                // stateless: weights from current losses only
                let min = cur.iter().cloned().fold(f32::MAX, f32::min);
                for (wi, &lt) in w.iter_mut().zip(cur) {
                    *wi = (-(lt - min) / tau.max(1e-6)).exp();
                }
            }
        }
        normalize(w);
    }
}

/// Normalize to sum = len, guarding degenerate cases.
pub fn normalize(w: &mut [f32]) {
    let m = w.len() as f32;
    let sum: f32 = w.iter().sum();
    if sum > 0.0 && sum.is_finite() {
        for x in w.iter_mut() {
            *x *= m / sum;
        }
    } else {
        for x in w.iter_mut() {
            *x = 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_norm(w: &[f32]) {
        let sum: f32 = w.iter().sum();
        assert!((sum - w.len() as f32).abs() < 1e-4, "{w:?}");
        assert!(w.iter().all(|&x| x > 0.0 && x.is_finite()), "{w:?}");
    }

    #[test]
    fn parse_specs() {
        assert_eq!(UpdateRule::parse("eq3:0.7").unwrap(), UpdateRule::Eq3 { beta: 0.7 });
        assert_eq!(UpdateRule::parse("exp3").unwrap(), UpdateRule::Exp3 { eta: 0.2 });
        assert_eq!(
            UpdateRule::parse("softmax:0.1").unwrap(),
            UpdateRule::Softmax { tau: 0.1 }
        );
        assert!(UpdateRule::parse("ucb").is_err());
        assert!(UpdateRule::parse("eq3:abc").is_err());
    }

    #[test]
    fn eq3_rewards_volatility() {
        let mut w = vec![1.0f32, 1.0];
        UpdateRule::Eq3 { beta: 1.0 }.update(
            &mut w,
            &[1.0, 5.0],
            Some(&[1.0, 1.0]), // method 1's pick got much worse -> volatile
        );
        check_norm(&w);
        assert!(w[1] > w[0]);
    }

    #[test]
    fn exp3_rewards_loss_reduction() {
        let mut w = vec![1.0f32, 1.0];
        UpdateRule::Exp3 { eta: 1.0 }.update(
            &mut w,
            &[0.5, 2.0],
            Some(&[1.0, 1.0]), // method 0 reduced its pick's loss
        );
        check_norm(&w);
        assert!(w[0] > w[1]);
    }

    #[test]
    fn softmax_favors_small_current_loss() {
        let mut w = vec![1.0f32, 1.0, 1.0];
        UpdateRule::Softmax { tau: 0.5 }.update(&mut w, &[0.1, 1.0, 2.0], None);
        check_norm(&w);
        assert!(w[0] > w[1] && w[1] > w[2]);
    }

    #[test]
    fn first_iteration_is_noop_for_stateful_rules() {
        for rule in [UpdateRule::Eq3 { beta: 1.0 }, UpdateRule::Exp3 { eta: 1.0 }] {
            let mut w = vec![1.0f32, 1.0];
            rule.update(&mut w, &[3.0, 0.1], None);
            assert_eq!(w, vec![1.0, 1.0], "{rule:?}");
        }
    }

    #[test]
    fn scaled_adjusts_learning_parameters() {
        assert_eq!(
            UpdateRule::Eq3 { beta: 0.5 }.scaled(2.0),
            UpdateRule::Eq3 { beta: 1.0 }
        );
        let UpdateRule::Exp3 { eta } = UpdateRule::Exp3 { eta: 0.2 }.scaled(3.0) else {
            panic!("variant changed");
        };
        assert!((eta - 0.6).abs() < 1e-6);
        let UpdateRule::Softmax { tau } = UpdateRule::Softmax { tau: 0.5 }.scaled(2.0) else {
            panic!("variant changed");
        };
        assert!((tau - 0.25).abs() < 1e-6);
        assert_eq!(UpdateRule::Eq3 { beta: 0.5 }.scaled(1.0), UpdateRule::Eq3 { beta: 0.5 });
    }

    #[test]
    fn normalize_handles_degenerate() {
        let mut w = vec![0.0f32, 0.0];
        normalize(&mut w);
        assert_eq!(w, vec![1.0, 1.0]);
        let mut w = vec![f32::INFINITY, 1.0];
        normalize(&mut w);
        assert_eq!(w, vec![1.0, 1.0]);
    }
}

//! The paper's contribution, as L3 policy code: candidate methods and their
//! α transforms (`method`), the adaptive AdaSelection policy (`adaselection`,
//! eqs. 3–5), and the `Selector` trait + baselines the trainer drives
//! (`policy`).

pub mod adaselection;
pub mod bandit;
pub mod method;
pub mod policy;
pub mod staleness;

pub use adaselection::{merge_snapshots, AdaConfig, AdaSelection, AdaSnapshot, ScoreOutput};
pub use bandit::UpdateRule;
pub use method::Method;
pub use staleness::LossCache;
pub use policy::{
    build_selector, AdaSelectionPolicy, BenchmarkAll, SelectionContext, Selector, SingleMethod,
};

//! The paper's contribution, as L3 policy code: candidate methods and their
//! α transforms (`method`), the adaptive AdaSelection policy (`adaselection`,
//! eqs. 3–5), and the `Selector` trait + baselines the trainer drives
//! (`policy`).

pub mod adaselection;
pub mod bandit;
pub mod method;
pub mod policy;
pub mod staleness;

pub use adaselection::{merge_snapshots, AdaConfig, AdaSelection, AdaSnapshot, ScoreOutput};
pub use bandit::UpdateRule;
pub use method::{lookup, valid_method_ids, Arm, Method, MethodSpec, ScoringCost};
pub use staleness::LossCache;
pub use policy::{
    build_policy, build_policy_full, build_selector, AdaSelectionPolicy, BenchmarkAll,
    LossHistory, ObftfPolicy, Policy, ScoringNeeds, SelectionContext, SelectionPlan,
    SelectiveBackprop, Selector, SingleMethod,
};

//! The AdaSelection policy (paper §3.2): adaptive method weights (eq. 3),
//! curriculum reward (eq. 4), fused sample scores (eq. 5), top-k select.
//!
//! The per-sample α/score math lives in the L1 Pallas kernel at runtime;
//! this module holds the *policy state* — the method weights `w_t^m`, the
//! per-method loss history `ℓ_{t-1}^m`, and the iteration counter — plus a
//! pure-rust scorer (`score_host`) that is the kernel's oracle and fallback.

use crate::selection::bandit::UpdateRule;
use crate::selection::method::{all_alphas, Arm, Method};
use crate::util::stats;
use crate::util::topk::top_k_indices;

/// Configuration for the AdaSelection policy.
#[derive(Clone, Debug)]
pub struct AdaConfig {
    /// candidate arm pool (any registry methods), e.g. [BigLoss, SmallLoss, Uniform]
    pub candidates: Vec<Arm>,
    /// β ∈ [-1, 1] of eq. 3: >0 rewards loss volatility, <0 rewards stability
    pub beta: f32,
    /// enable the curriculum reward of eq. 4
    pub cl_on: bool,
    /// exponent p of eq. 4 (negative ⇒ reward fades with t; DESIGN.md §5.3)
    pub cl_power: f32,
    /// weight-update rule; None = the paper's eq. 3 with `beta`
    /// (the bandit framing of §3.2 — see `selection::bandit`)
    pub rule: Option<UpdateRule>,
    /// candidate multiplier for an `obftf` arm's hypothetical slice
    pub obftf_k: usize,
}

impl Default for AdaConfig {
    fn default() -> Self {
        AdaConfig {
            candidates: vec![
                Arm::Kernel(Method::BigLoss),
                Arm::Kernel(Method::SmallLoss),
                Arm::Kernel(Method::Uniform),
            ],
            beta: 0.5,
            cl_on: true,
            cl_power: -0.5,
            rule: None,
            obftf_k: 10,
        }
    }
}

impl AdaConfig {
    /// The effective update rule (eq. 3 unless overridden).
    pub fn effective_rule(&self) -> UpdateRule {
        self.rule.unwrap_or(UpdateRule::Eq3 { beta: self.beta })
    }
}

/// Mutable policy state across iterations.
#[derive(Clone, Debug)]
pub struct AdaSelection {
    pub cfg: AdaConfig,
    /// w_t^m, one per candidate; kept normalized to sum = |candidates|
    w: Vec<f32>,
    /// ℓ_{t-1}^m per candidate (None before the first iteration)
    prev_loss: Option<Vec<f32>>,
    /// iteration counter t (1-based at first score call)
    t: usize,
    /// transient learning-rate multiplier on the weight-update rule (set
    /// by the stream drift controller; 1.0 = the configured rule verbatim;
    /// deliberately NOT part of snapshots — it is re-derived each tick)
    lr_scale: f32,
}

/// Checkpoint view of the mutable policy state (see
/// [`AdaSelection::snapshot`] / [`AdaSelection::restore`]).
///
/// `ids` is the snapshot-format versioning hook: `Some` carries the stable
/// string id of each weight's arm so restore can re-map by id; `None` marks
/// a legacy (pre-registry) positional snapshot, accepted when the arity
/// matches the restoring policy's pool. Weights are renormalized to
/// sum = M on read either way.
#[derive(Clone, Debug)]
pub struct AdaSnapshot {
    pub w: Vec<f32>,
    pub prev_loss: Option<Vec<f32>>,
    pub t: usize,
    pub ids: Option<Vec<String>>,
}

/// Everything produced for one batch.
#[derive(Clone, Debug)]
pub struct ScoreOutput {
    /// fused s_{i,t}
    pub scores: Vec<f32>,
    /// selected rows (top-k by score, deterministic tie-break)
    pub selected: Vec<usize>,
    /// snapshot of the *post-update* weights, for Fig-8 traces
    pub weights: Vec<f32>,
}

impl AdaSelection {
    pub fn new(cfg: AdaConfig) -> Self {
        assert!(!cfg.candidates.is_empty(), "empty candidate pool");
        let m = cfg.candidates.len();
        AdaSelection {
            cfg,
            w: vec![1.0; m],
            prev_loss: None,
            t: 0,
            lr_scale: 1.0,
        }
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    pub fn iteration(&self) -> usize {
        self.t
    }

    pub fn config(&self) -> &AdaConfig {
        &self.cfg
    }

    /// Override the weight-update rule (bandit ablations).
    pub fn set_rule(&mut self, rule: UpdateRule) {
        self.cfg.rule = Some(rule);
    }

    /// Transient learning-rate multiplier on the update rule (drift boost).
    pub fn set_lr_scale(&mut self, s: f32) {
        self.lr_scale = if s.is_finite() && s > 0.0 { s } else { 1.0 };
    }

    /// Copy out the mutable policy state (checkpoint support).
    pub fn snapshot(&self) -> AdaSnapshot {
        AdaSnapshot {
            w: self.w.clone(),
            prev_loss: self.prev_loss.clone(),
            t: self.t,
            ids: Some(
                self.cfg
                    .candidates
                    .iter()
                    .map(|a| a.id().to_string())
                    .collect(),
            ),
        }
    }

    /// Restore state captured by [`AdaSelection::snapshot`]. Snapshots that
    /// carry arm ids are re-mapped by id (order-independent, every id must
    /// be in this policy's pool and vice versa); legacy positional
    /// snapshots (`ids: None`) must match the pool's arity. Weights are
    /// renormalized to sum = M on read so pre-registry checkpoints written
    /// before normalization was guaranteed still load cleanly.
    pub fn restore(&mut self, snap: AdaSnapshot) -> anyhow::Result<()> {
        let m = self.cfg.candidates.len();
        let (mut w, prev_loss) = match &snap.ids {
            Some(ids) => {
                anyhow::ensure!(
                    ids.len() == snap.w.len(),
                    "snapshot has {} ids but {} weights",
                    ids.len(),
                    snap.w.len()
                );
                anyhow::ensure!(
                    ids.len() == m,
                    "snapshot has {} arms, policy has {} candidates",
                    ids.len(),
                    m
                );
                let mut w = vec![0.0f32; m];
                let mut prev = snap.prev_loss.as_ref().map(|_| vec![0.0f32; m]);
                for (slot, arm) in self.cfg.candidates.iter().enumerate() {
                    let src = ids
                        .iter()
                        .position(|id| id == arm.id())
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "snapshot is missing arm '{}' (has: {})",
                                arm.id(),
                                ids.join(", ")
                            )
                        })?;
                    w[slot] = snap.w[src];
                    if let (Some(p), Some(sp)) = (prev.as_mut(), snap.prev_loss.as_ref()) {
                        anyhow::ensure!(
                            sp.len() == ids.len(),
                            "snapshot prev_loss arity mismatch"
                        );
                        p[slot] = sp[src];
                    }
                }
                (w, prev)
            }
            None => {
                anyhow::ensure!(
                    snap.w.len() == m,
                    "snapshot has {} weights, policy has {} candidates",
                    snap.w.len(),
                    m
                );
                if let Some(prev) = &snap.prev_loss {
                    anyhow::ensure!(
                        prev.len() == m,
                        "snapshot prev_loss arity mismatch"
                    );
                }
                (snap.w, snap.prev_loss)
            }
        };
        crate::selection::bandit::normalize(&mut w);
        self.w = w;
        self.prev_loss = prev_loss;
        self.t = snap.t;
        Ok(())
    }

    /// The full 7-slot weight vector the fused score kernel consumes:
    /// candidate weights at their frozen `Method::index()` positions, zeros
    /// elsewhere. `None` when any arm lives outside the kernel's 7-row α
    /// matrix (obftf / selective-backprop) — callers must fall back to the
    /// host scorer for those pools.
    pub fn kernel_weights(&self) -> Option<[f32; 7]> {
        let mut w = [0.0f32; 7];
        for (a, &wa) in self.cfg.candidates.iter().zip(self.w.iter()) {
            w[a.kernel_index()?] = wa;
        }
        Some(w)
    }

    /// Multiply one arm's weight (drift boost on that arm) and renormalize
    /// the pool back to sum = M.
    pub fn boost_weight(&mut self, arm: usize, factor: f32) {
        if arm >= self.w.len() || !factor.is_finite() || factor <= 0.0 {
            return;
        }
        self.w[arm] *= factor;
        crate::selection::bandit::normalize(&mut self.w);
    }

    /// The per-arm hypothetical top-k mean losses ℓ_t^m observed by the
    /// most recent update (None before the first iteration). This is the
    /// signal the per-method drift detectors watch.
    pub fn last_method_losses(&self) -> Option<&[f32]> {
        self.prev_loss.as_deref()
    }

    /// The curriculum reward r_t (eq. 4), normalized to mean 1.
    pub fn cl_reward(loss: &[f32], t: usize, power: f32) -> Vec<f32> {
        let b = loss.len();
        let tt = (t as f32).max(1.0);
        let denom: f32 = loss.iter().map(|&l| l * l).sum::<f32>() + 1e-9;
        let scale = tt.powf(power);
        let mut r: Vec<f32> = loss.iter().map(|&l| (-scale * l / denom).exp()).collect();
        let sum: f32 = r.iter().sum();
        let norm = b as f32 / sum;
        for v in r.iter_mut() {
            *v *= norm;
        }
        r
    }

    /// One iteration on the host path: compute α on the CPU, fuse with the
    /// current weights + CL reward, select top-k, then update the weights
    /// (eq. 3). This is the oracle for the XLA score artifact; the runtime
    /// path calls [`AdaSelection::select_with_alphas`] with kernel outputs.
    pub fn step_host(&mut self, loss: &[f32], gnorm: &[f32], k: usize) -> ScoreOutput {
        let alphas = self.host_alphas(loss, gnorm);
        self.select_with_alphas(loss, &alphas, k)
    }

    /// Per-candidate α rows on the CPU: kernel arms slice the shared 7-row
    /// matrix; registry-only arms (obftf / selective-backprop) compute
    /// their own α directly.
    pub fn host_alphas(&self, loss: &[f32], gnorm: &[f32]) -> Vec<Vec<f32>> {
        let full = if self.cfg.candidates.iter().any(|a| a.kernel_index().is_some()) {
            Some(all_alphas(loss, gnorm))
        } else {
            None
        };
        self.cfg
            .candidates
            .iter()
            .map(|a| match a.kernel_index() {
                Some(idx) => full.as_ref().expect("kernel arm present")[idx].clone(),
                None => a.alpha(loss, gnorm, self.cfg.obftf_k),
            })
            .collect()
    }

    /// One iteration given per-candidate α rows (from the L1 kernel or from
    /// `step_host`). Also performs the eq. 3 weight update.
    pub fn select_with_alphas(
        &mut self,
        loss: &[f32],
        alphas: &[Vec<f32>],
        k: usize,
    ) -> ScoreOutput {
        assert_eq!(alphas.len(), self.cfg.candidates.len());
        let b = loss.len();

        // eq. 5: s_i = r_t(i) * Σ_m w_m α_im  (computed for t+1, matching
        // the increment inside select_scored)
        let mut scores = vec![0.0f32; b];
        for (wm, am) in self.w.iter().zip(alphas.iter()) {
            for (s, &a) in scores.iter_mut().zip(am.iter()) {
                *s += wm * a;
            }
        }
        if self.cfg.cl_on {
            let r = Self::cl_reward(loss, self.t + 1, self.cfg.cl_power);
            for (s, &ri) in scores.iter_mut().zip(r.iter()) {
                *s *= ri;
            }
        }
        self.select_scored(loss, alphas, scores, k)
    }

    /// One iteration with the fused scores already computed (the runtime
    /// path: the L1 Pallas kernel produced both α and s). Performs top-k
    /// selection and the eq. 3 weight update.
    pub fn select_scored(
        &mut self,
        loss: &[f32],
        alphas: &[Vec<f32>],
        scores: Vec<f32>,
        k: usize,
    ) -> ScoreOutput {
        assert_eq!(alphas.len(), self.cfg.candidates.len());
        self.t += 1;
        let selected = top_k_indices(&scores, k);

        // weight update (eq. 3 by default, pluggable bandit rules otherwise)
        // over ℓ_t^m = mean loss of method m's own hypothetical top-k.
        let cur: Vec<f32> = alphas
            .iter()
            .map(|am| {
                let pick = top_k_indices(am, k);
                let sum: f32 = pick.iter().map(|&i| loss[i]).sum();
                sum / pick.len().max(1) as f32
            })
            .collect();
        self.cfg
            .effective_rule()
            .scaled(self.lr_scale)
            .update(&mut self.w, &cur, self.prev_loss.as_deref());
        self.prev_loss = Some(cur);

        ScoreOutput {
            scores,
            selected,
            weights: self.w.clone(),
        }
    }
}

/// Weighted merge of policy snapshots — the cluster's periodic
/// policy-merge step. Method weights are the weighted mean (renormalized
/// to sum = M), `prev_loss` is the weighted mean when every snapshot has
/// one (else `None`, so the next update is a no-op for the stateful
/// rules), and the iteration counter is the maximum.
pub fn merge_snapshots(snaps: &[AdaSnapshot], weights: &[f64]) -> anyhow::Result<AdaSnapshot> {
    anyhow::ensure!(!snaps.is_empty(), "merge_snapshots: no snapshots");
    anyhow::ensure!(
        snaps.len() == weights.len(),
        "merge_snapshots: {} snapshots vs {} weights",
        snaps.len(),
        weights.len()
    );
    let m = snaps[0].w.len();
    for s in snaps {
        anyhow::ensure!(s.w.len() == m, "merge_snapshots: candidate arity mismatch");
        // positional merge is only sound when every party agrees on which
        // arm sits in which slot; id-carrying snapshots must match exactly
        // (legacy `None` snapshots are trusted positionally, as before)
        if let (Some(a), Some(b)) = (&snaps[0].ids, &s.ids) {
            anyhow::ensure!(a == b, "merge_snapshots: arm id mismatch ({a:?} vs {b:?})");
        }
    }
    let total: f64 = weights.iter().sum();
    anyhow::ensure!(
        total > 0.0 && total.is_finite(),
        "merge_snapshots: degenerate weight total {total}"
    );

    let mut w = vec![0.0f32; m];
    for (s, &ws) in snaps.iter().zip(weights.iter()) {
        for (acc, &v) in w.iter_mut().zip(s.w.iter()) {
            *acc += ((ws / total) * v as f64) as f32;
        }
    }
    crate::selection::bandit::normalize(&mut w);

    let prev_loss = if snaps.iter().all(|s| s.prev_loss.is_some()) {
        let mut p = vec![0.0f32; m];
        for (s, &ws) in snaps.iter().zip(weights.iter()) {
            let sp = s.prev_loss.as_ref().expect("checked above");
            anyhow::ensure!(sp.len() == m, "merge_snapshots: prev_loss arity mismatch");
            for (acc, &v) in p.iter_mut().zip(sp.iter()) {
                *acc += ((ws / total) * v as f64) as f32;
            }
        }
        Some(p)
    } else {
        None
    };

    Ok(AdaSnapshot {
        w,
        prev_loss,
        t: snaps.iter().map(|s| s.t).max().unwrap_or(0),
        ids: snaps.iter().find_map(|s| s.ids.clone()),
    })
}

/// Host-side fused score + full 7-row α matrix (no state/update): mirrors
/// the L1 score kernel exactly. This is the oracle the XLA kernel is tested
/// against AND the scorer the native backend runs in production.
pub fn score_full(
    loss: &[f32],
    gnorm: &[f32],
    w_full: &[f32; 7],
    t: usize,
    cl_power: f32,
    cl_on: bool,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let full = all_alphas(loss, gnorm);
    let b = loss.len();
    let mut scores = vec![0.0f32; b];
    for (wm, am) in w_full.iter().zip(full.iter()) {
        for (s, &a) in scores.iter_mut().zip(am.iter()) {
            *s += wm * a;
        }
    }
    if cl_on {
        let r = AdaSelection::cl_reward(loss, t, cl_power);
        for (s, &ri) in scores.iter_mut().zip(r.iter()) {
            *s *= ri;
        }
    }
    (scores, full)
}

/// Host-side fused score alone (no state/update): see [`score_full`].
pub fn score_host(
    loss: &[f32],
    gnorm: &[f32],
    w_full: &[f32; 7],
    t: usize,
    cl_power: f32,
    cl_on: bool,
) -> Vec<f32> {
    score_full(loss, gnorm, w_full, t, cl_power, cl_on).0
}

/// ℓ_t^m helper exposed for metrics: mean loss over a hypothetical top-k.
pub fn hypothetical_mean_loss(alpha: &[f32], loss: &[f32], k: usize) -> f32 {
    let pick = top_k_indices(alpha, k);
    if pick.is_empty() {
        return stats::mean(loss);
    }
    pick.iter().map(|&i| loss[i]).sum::<f32>() / pick.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn batch(seed: u64, b: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let loss: Vec<f32> = (0..b).map(|_| rng.next_f32() * 3.0 + 0.01).collect();
        let gnorm: Vec<f32> = (0..b).map(|_| rng.next_f32() * 2.0 + 0.01).collect();
        (loss, gnorm)
    }

    #[test]
    fn selects_k_unique_rows() {
        let (l, g) = batch(1, 64);
        let mut ada = AdaSelection::new(AdaConfig::default());
        let out = ada.step_host(&l, &g, 13);
        assert_eq!(out.selected.len(), 13);
        let mut sorted = out.selected.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 13);
    }

    #[test]
    fn weights_stay_positive_and_normalized() {
        let mut ada = AdaSelection::new(AdaConfig {
            beta: 1.0,
            ..AdaConfig::default()
        });
        for s in 0..50 {
            let (l, g) = batch(s, 64);
            ada.step_host(&l, &g, 13);
            let sum: f32 = ada.weights().iter().sum();
            assert!((sum - ada.weights().len() as f32).abs() < 1e-3);
            assert!(ada.weights().iter().all(|&w| w > 0.0));
        }
    }

    #[test]
    fn beta_zero_keeps_weights_uniform() {
        let mut ada = AdaSelection::new(AdaConfig {
            beta: 0.0,
            ..AdaConfig::default()
        });
        for s in 0..10 {
            let (l, g) = batch(s, 32);
            ada.step_host(&l, &g, 8);
        }
        for &w in ada.weights() {
            assert!((w - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn single_candidate_reduces_to_that_method() {
        // with only BigLoss in the pool and CL off, selection = top-k loss
        let (l, g) = batch(2, 32);
        let mut ada = AdaSelection::new(AdaConfig {
            candidates: vec![Arm::Kernel(Method::BigLoss)],
            beta: 0.5,
            cl_on: false,
            cl_power: -0.5,
            rule: None,
            obftf_k: 10,
        });
        let out = ada.step_host(&l, &g, 5);
        let want = crate::util::topk::top_k_indices(&l, 5);
        assert_eq!(out.selected, want);
    }

    #[test]
    fn cl_shifts_early_selection_toward_small_loss() {
        let (l, g) = batch(3, 64);
        let cfg_on = AdaConfig {
            candidates: vec![Arm::Kernel(Method::Uniform)],
            beta: 0.0,
            cl_on: true,
            cl_power: 0.9, // strongly CL-weighted early
            rule: None,
            obftf_k: 10,
        };
        let mut ada = AdaSelection::new(cfg_on);
        let out = ada.step_host(&l, &g, 8);
        let mean_sel: f32 =
            out.selected.iter().map(|&i| l[i]).sum::<f32>() / 8.0;
        let mean_all = stats::mean(&l);
        assert!(
            mean_sel < mean_all,
            "CL must prefer small losses early: {mean_sel} vs {mean_all}"
        );
    }

    #[test]
    fn volatile_method_gains_weight_with_positive_beta() {
        // candidate 0 sees stable losses, candidate 1 volatile ones: with
        // β > 0 the volatile candidate's weight must grow.
        let mut ada = AdaSelection::new(AdaConfig {
            candidates: vec![
                Arm::Kernel(Method::SmallLoss),
                Arm::Kernel(Method::BigLoss),
            ],
            beta: 1.0,
            cl_on: false,
            cl_power: -0.5,
            rule: None,
            obftf_k: 10,
        });
        let mut rng = Pcg64::new(9);
        for t in 0..30 {
            // small losses constant; big losses oscillate wildly
            let osc = if t % 2 == 0 { 5.0 } else { 1.0 };
            let loss: Vec<f32> = (0..32)
                .map(|i| if i < 16 { 0.1 } else { osc + rng.next_f32() * 0.1 })
                .collect();
            let gnorm = vec![1.0; 32];
            ada.step_host(&loss, &gnorm, 8);
        }
        let w = ada.weights();
        assert!(
            w[1] > w[0],
            "big_loss (volatile ℓ^m) should out-weigh small_loss: {w:?}"
        );
    }

    #[test]
    fn score_host_matches_step_host_scores() {
        let (l, g) = batch(5, 48);
        let mut ada = AdaSelection::new(AdaConfig {
            candidates: Method::ALL.iter().map(|&m| Arm::Kernel(m)).collect(),
            beta: 0.5,
            cl_on: true,
            cl_power: -0.5,
            rule: None,
            obftf_k: 10,
        });
        let out = ada.step_host(&l, &g, 10);
        let w = [1.0f32; 7]; // first iteration: weights all 1
        let s = score_host(&l, &g, &w, 1, -0.5, true);
        for (a, b) in out.scores.iter().zip(s.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn snapshot_restore_resumes_identically() {
        let mut a = AdaSelection::new(AdaConfig::default());
        for s in 0..20 {
            let (l, g) = batch(s, 48);
            a.step_host(&l, &g, 10);
        }
        let snap = a.snapshot();
        let mut b = AdaSelection::new(AdaConfig::default());
        b.restore(snap).unwrap();
        assert_eq!(a.weights(), b.weights());
        assert_eq!(a.iteration(), b.iteration());
        for s in 20..40 {
            let (l, g) = batch(s, 48);
            let oa = a.step_host(&l, &g, 10);
            let ob = b.step_host(&l, &g, 10);
            assert_eq!(oa.selected, ob.selected, "diverged at step {s}");
            assert_eq!(oa.weights, ob.weights);
        }
        // arity mismatch rejected
        let mut c = AdaSelection::new(AdaConfig {
            candidates: vec![Arm::Kernel(Method::BigLoss)],
            ..AdaConfig::default()
        });
        assert!(c.restore(a.snapshot()).is_err());
    }

    #[test]
    fn restore_maps_arms_by_id_and_normalizes() {
        // a snapshot written with the arms in a different order restores to
        // the right slots, and denormalized weights are renormalized
        let mut ada = AdaSelection::new(AdaConfig::default()); // big+small+uniform
        let snap = AdaSnapshot {
            w: vec![0.2, 0.4, 0.6], // sums to 1.2, not 3.0
            prev_loss: Some(vec![10.0, 20.0, 30.0]),
            t: 7,
            ids: Some(vec![
                "uniform".to_string(),
                "small_loss".to_string(),
                "big_loss".to_string(),
            ]),
        };
        ada.restore(snap).unwrap();
        assert_eq!(ada.iteration(), 7);
        let w = ada.weights();
        let sum: f32 = w.iter().sum();
        assert!((sum - 3.0).abs() < 1e-4, "{w:?}");
        // big_loss carried 0.6, small_loss 0.4, uniform 0.2 — order preserved
        assert!(w[0] > w[1] && w[1] > w[2], "{w:?}");
        assert_eq!(
            ada.last_method_losses(),
            Some(&[30.0f32, 20.0, 10.0][..])
        );

        // unknown arm id rejected
        let bad = AdaSnapshot {
            w: vec![1.0, 1.0, 1.0],
            prev_loss: None,
            t: 1,
            ids: Some(vec![
                "big_loss".to_string(),
                "small_loss".to_string(),
                "obftf".to_string(),
            ]),
        };
        assert!(ada.restore(bad).is_err());

        // legacy positional snapshot (no ids) still loads
        let legacy = AdaSnapshot {
            w: vec![1.0, 1.0, 1.0],
            prev_loss: None,
            t: 3,
            ids: None,
        };
        ada.restore(legacy).unwrap();
        assert_eq!(ada.iteration(), 3);
    }

    #[test]
    fn kernel_weights_gated_on_pool_membership() {
        let ada = AdaSelection::new(AdaConfig::default());
        let w = ada.kernel_weights().expect("all-kernel pool");
        assert_eq!(w[Method::BigLoss.index()], 1.0);
        assert_eq!(w[Method::Coreset2.index()], 0.0);

        let mixed = AdaSelection::new(AdaConfig {
            candidates: vec![Arm::Kernel(Method::BigLoss), Arm::Obftf],
            ..AdaConfig::default()
        });
        assert!(mixed.kernel_weights().is_none());
    }

    #[test]
    fn boost_weight_shifts_and_renormalizes() {
        let mut ada = AdaSelection::new(AdaConfig::default());
        ada.boost_weight(1, 2.0);
        let w = ada.weights().to_vec();
        let sum: f32 = w.iter().sum();
        assert!((sum - 3.0).abs() < 1e-4, "{w:?}");
        assert!(w[1] > w[0] && w[1] > w[2], "{w:?}");
        // degenerate inputs are ignored
        ada.boost_weight(99, 2.0);
        ada.boost_weight(0, f32::NAN);
        ada.boost_weight(0, 0.0);
        assert_eq!(ada.weights(), &w[..]);
    }

    #[test]
    fn registry_arms_join_the_pool() {
        // obftf + selective-backprop arms step without kernel support and
        // keep weights normalized
        let mut ada = AdaSelection::new(AdaConfig {
            candidates: vec![
                Arm::Kernel(Method::BigLoss),
                Arm::Obftf,
                Arm::SelectiveBackprop,
            ],
            ..AdaConfig::default()
        });
        for s in 0..20 {
            let (l, g) = batch(s, 64);
            let out = ada.step_host(&l, &g, 13);
            assert_eq!(out.selected.len(), 13);
            let sum: f32 = ada.weights().iter().sum();
            assert!((sum - 3.0).abs() < 1e-3);
        }
        let snap = ada.snapshot();
        assert_eq!(
            snap.ids.as_deref(),
            Some(&[
                "big_loss".to_string(),
                "obftf".to_string(),
                "selective-backprop".to_string()
            ][..])
        );
    }

    #[test]
    fn lr_scale_speeds_weight_movement() {
        // identical loss sequences; the boosted policy's weights must move
        // farther from uniform than the base policy's
        let spread = |scale: f32| {
            let mut ada = AdaSelection::new(AdaConfig {
                beta: 0.5,
                ..AdaConfig::default()
            });
            ada.set_lr_scale(scale);
            let mut rng = Pcg64::new(21);
            for t in 0..20 {
                let osc = if t % 2 == 0 { 4.0 } else { 1.0 };
                let loss: Vec<f32> = (0..32)
                    .map(|i| if i < 16 { 0.1 } else { osc + rng.next_f32() * 0.1 })
                    .collect();
                ada.step_host(&loss, &vec![1.0; 32], 8);
            }
            ada.weights()
                .iter()
                .map(|&w| (w - 1.0).abs())
                .fold(0.0f32, f32::max)
        };
        assert!(spread(4.0) > spread(1.0), "boost did not speed adaptation");
        // degenerate scales fall back to 1.0
        let mut ada = AdaSelection::new(AdaConfig::default());
        ada.set_lr_scale(0.0);
        assert_eq!(ada.lr_scale, 1.0);
        ada.set_lr_scale(f32::NAN);
        assert_eq!(ada.lr_scale, 1.0);
    }

    #[test]
    fn merge_snapshots_weighted_mean() {
        let a = AdaSnapshot { w: vec![2.0, 1.0, 0.0], prev_loss: Some(vec![1.0, 2.0, 3.0]), t: 5, ids: None };
        let b = AdaSnapshot { w: vec![0.0, 1.0, 2.0], prev_loss: Some(vec![3.0, 2.0, 1.0]), t: 9, ids: None };
        let m = merge_snapshots(&[a.clone(), b.clone()], &[1.0, 1.0]).unwrap();
        assert_eq!(m.t, 9);
        let w = &m.w;
        assert!((w[0] - 1.0).abs() < 1e-5 && (w[1] - 1.0).abs() < 1e-5 && (w[2] - 1.0).abs() < 1e-5, "{w:?}");
        assert_eq!(m.prev_loss, Some(vec![2.0, 2.0, 2.0]));

        // asymmetric weights pull toward the heavier node
        let m = merge_snapshots(&[a.clone(), b.clone()], &[3.0, 1.0]).unwrap();
        assert!(m.w[0] > m.w[2], "{:?}", m.w);

        // any missing prev_loss clears it
        let c = AdaSnapshot { w: vec![1.0, 1.0, 1.0], prev_loss: None, t: 0, ids: None };
        let m = merge_snapshots(&[a.clone(), c], &[1.0, 1.0]).unwrap();
        assert_eq!(m.prev_loss, None);

        // arity / weight errors
        let bad = AdaSnapshot { w: vec![1.0], prev_loss: None, t: 0, ids: None };
        assert!(merge_snapshots(&[a.clone(), bad], &[1.0, 1.0]).is_err());
        assert!(merge_snapshots(&[a.clone()], &[0.0]).is_err());
        assert!(merge_snapshots(&[], &[]).is_err());
        assert!(merge_snapshots(&[a.clone()], &[1.0, 1.0]).is_err());

        // id-carrying snapshots must agree on slot order
        let with_ids = |ids: [&str; 3]| AdaSnapshot {
            w: vec![1.0, 1.0, 1.0],
            prev_loss: None,
            t: 1,
            ids: Some(ids.iter().map(|s| s.to_string()).collect()),
        };
        let x = with_ids(["big_loss", "obftf", "uniform"]);
        let y = with_ids(["big_loss", "obftf", "uniform"]);
        let merged = merge_snapshots(&[x.clone(), y], &[1.0, 1.0]).unwrap();
        assert_eq!(merged.ids, x.ids);
        let z = with_ids(["obftf", "big_loss", "uniform"]);
        assert!(merge_snapshots(&[x.clone(), z], &[1.0, 1.0]).is_err());
        // legacy (None) merges positionally with id-carrying peers; the
        // merged snapshot keeps the first ids seen
        let merged = merge_snapshots(&[a, x.clone()], &[1.0, 1.0]).unwrap();
        assert_eq!(merged.ids, x.ids);
    }

    #[test]
    fn empty_k_is_fine() {
        let (l, g) = batch(6, 16);
        let mut ada = AdaSelection::new(AdaConfig::default());
        let out = ada.step_host(&l, &g, 0);
        assert!(out.selected.is_empty());
    }
}

//! The candidate subsampling methods (paper §3.1) and their α transforms.
//!
//! `Method::ALL` order is FROZEN and must match the L1 score kernel's
//! `METHOD_ORDER` (checked against `artifacts/manifest.json` at runtime and
//! in integration tests).

use crate::util::stats;

/// The seven candidate methods of §3.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    Uniform,
    BigLoss,
    SmallLoss,
    GradNorm,
    AdaBoost,
    Coreset1,
    Coreset2,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Uniform,
        Method::BigLoss,
        Method::SmallLoss,
        Method::GradNorm,
        Method::AdaBoost,
        Method::Coreset1,
        Method::Coreset2,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Method::Uniform => "uniform",
            Method::BigLoss => "big_loss",
            Method::SmallLoss => "small_loss",
            Method::GradNorm => "grad_norm",
            Method::AdaBoost => "adaboost",
            Method::Coreset1 => "coreset1",
            Method::Coreset2 => "coreset2",
        }
    }

    pub fn from_name(s: &str) -> anyhow::Result<Method> {
        Method::ALL
            .iter()
            .copied()
            .find(|m| m.name() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown method '{s}'"))
    }

    /// Row index in the kernel's alpha matrix.
    pub fn index(self) -> usize {
        Method::ALL.iter().position(|&m| m == self).unwrap()
    }
}

/// AdaBoost half-log-odds statistic over max-normalized losses (eq. 1).
pub fn adaboost_stat(loss: &[f32]) -> Vec<f32> {
    let max = loss.iter().cloned().fold(f32::MIN, f32::max).max(0.0) + 1e-9;
    loss.iter()
        .map(|&l| {
            let lh = (l / max).clamp(0.0, 1.0 - 1e-3);
            0.5 * ((1.0 + lh) / (1.0 - lh)).ln()
        })
        .collect()
}

/// Coreset distance-to-batch-mean statistic.
pub fn dev_stat(loss: &[f32]) -> Vec<f32> {
    let m = stats::mean(loss);
    loss.iter().map(|&l| (l - m).abs()).collect()
}

/// α_i^m: softmax over the standardized ordering statistic — the exact
/// pure-rust mirror of the L1 score kernel (see kernels/score.py).
pub fn alpha(method: Method, loss: &[f32], gnorm: &[f32]) -> Vec<f32> {
    let b = loss.len();
    let mut stat: Vec<f32> = match method {
        Method::Uniform => return vec![1.0 / b as f32; b],
        Method::BigLoss => loss.to_vec(),
        Method::SmallLoss => loss.iter().map(|&l| -l).collect(),
        Method::GradNorm => gnorm.to_vec(),
        Method::AdaBoost => adaboost_stat(loss),
        Method::Coreset1 => dev_stat(loss),
        Method::Coreset2 => dev_stat(loss).iter().map(|&d| -d).collect(),
    };
    stats::standardize(&mut stat, 1e-6);
    stats::softmax(&mut stat);
    stat
}

/// All seven alphas, `Method::ALL` order (rows).
pub fn all_alphas(loss: &[f32], gnorm: &[f32]) -> Vec<Vec<f32>> {
    Method::ALL
        .iter()
        .map(|&m| alpha(m, loss, gnorm))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f32>, Vec<f32>) {
        (
            vec![0.1, 2.0, 0.5, 1.0, 4.0, 0.2],
            vec![1.0, 0.5, 2.0, 0.1, 0.3, 1.5],
        )
    }

    #[test]
    fn names_round_trip() {
        for m in Method::ALL {
            assert_eq!(Method::from_name(m.name()).unwrap(), m);
        }
        assert!(Method::from_name("nope").is_err());
    }

    #[test]
    fn alphas_are_simplex() {
        let (l, g) = toy();
        for m in Method::ALL {
            let a = alpha(m, &l, &g);
            assert_eq!(a.len(), l.len());
            let sum: f32 = a.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "{m:?} sum={sum}");
            assert!(a.iter().all(|&x| x >= 0.0), "{m:?}");
        }
    }

    #[test]
    fn big_loss_ranks_by_loss() {
        let (l, g) = toy();
        let a = alpha(Method::BigLoss, &l, &g);
        let max_i = l
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(
            a.iter()
                .enumerate()
                .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                .unwrap()
                .0,
            max_i
        );
    }

    #[test]
    fn small_is_reverse_of_big() {
        let (l, g) = toy();
        let big = alpha(Method::BigLoss, &l, &g);
        let small = alpha(Method::SmallLoss, &l, &g);
        let ord_big: Vec<usize> = crate::util::topk::argsort_desc(&big);
        let mut ord_small: Vec<usize> = crate::util::topk::argsort_desc(&small);
        ord_small.reverse();
        assert_eq!(ord_big, ord_small);
    }

    #[test]
    fn gradnorm_uses_gnorm_not_loss() {
        let (l, g) = toy();
        let a = alpha(Method::GradNorm, &l, &g);
        // sample 2 has the highest gnorm
        let max_i = a
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_i, 2);
    }

    #[test]
    fn adaboost_monotone_in_loss() {
        let (l, _) = toy();
        let s = adaboost_stat(&l);
        let mut idx: Vec<usize> = (0..l.len()).collect();
        idx.sort_by(|&a, &b| l[a].partial_cmp(&l[b]).unwrap());
        for w in idx.windows(2) {
            assert!(s[w[0]] <= s[w[1]] + 1e-7);
        }
    }

    #[test]
    fn coreset2_favors_near_mean() {
        let (l, g) = toy();
        let a = alpha(Method::Coreset2, &l, &g);
        let m = stats::mean(&l);
        let closest = l
            .iter()
            .enumerate()
            .min_by(|x, y| {
                (x.1 - m).abs().partial_cmp(&(y.1 - m).abs()).unwrap()
            })
            .unwrap()
            .0;
        let max_a = a
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_a, closest);
    }

    #[test]
    fn frozen_order_matches_kernel() {
        let names: Vec<&str> = Method::ALL.iter().map(|m| m.name()).collect();
        assert_eq!(
            names,
            vec![
                "uniform",
                "big_loss",
                "small_loss",
                "grad_norm",
                "adaboost",
                "coreset1",
                "coreset2"
            ]
        );
    }
}
